//! K-ary sum tree over priorities (paper §IV-C, Figs 3–6).
//!
//! The tree is stored *implicitly* in a single cache-line-aligned array:
//! level ℓ occupies a contiguous run of `K^ℓ` nodes, so every group of K
//! siblings (all children of one parent) starts on a cache-line boundary
//! provided `K % C == 0`, where `C = 16` f32 nodes per 64-byte line. The
//! root is padded to a full group of `K` slots exactly as in Fig 6.
//!
//! Values are stored as `AtomicU32` holding f32 bits with `Relaxed`
//! ordering. On x86-64 these compile to plain loads/stores, so the layout
//! and speed match the paper's C++ while keeping Rust's data-race rules
//! intact: the paper *deliberately* allows benign read/write races between
//! sampling and interior-node updates (§IV-D3, "write after read ...
//! little impact in practice"), which would be UB with plain `f32`.
//!
//! Thread-safety discipline is supplied by the caller
//! ([`crate::replay::prioritized`] implements the two-lock protocol of
//! Algorithm 3); all methods here take `&self` and are individually atomic
//! per node but not across nodes.

use crate::util::aligned::{AlignedBox, CACHE_LINE};
use std::sync::atomic::{AtomicU32, Ordering};

/// Number of f32 nodes per cache line.
pub const NODES_PER_LINE: usize = CACHE_LINE / std::mem::size_of::<f32>();

#[inline(always)]
fn load(a: &AtomicU32) -> f32 {
    f32::from_bits(a.load(Ordering::Relaxed))
}

#[inline(always)]
fn store(a: &AtomicU32, v: f32) {
    a.store(v.to_bits(), Ordering::Relaxed)
}

/// K-ary sum tree with the paper's implicit cache-aligned layout.
pub struct KArySumTree {
    /// Fan-out K. Power of two, `K % NODES_PER_LINE == 0` unless K == 2
    /// (the binary configuration used as the Fig 9 baseline).
    fanout: usize,
    /// Leaf capacity (number of priorities), padded up to `K^(H-1)`.
    capacity: usize,
    /// Requested (un-padded) capacity.
    logical_capacity: usize,
    /// Offset of each level in `nodes`; `level_off[0]` is the root.
    level_off: Vec<usize>,
    /// Number of levels (root = level 0, leaves = level H-1).
    height: usize,
    /// The node array. Level ℓ lives at `level_off[ℓ] ..`.
    nodes: AlignedBox<AtomicU32>,
    /// Optional parallel min tree (same implicit layout as `nodes`),
    /// allocated only via [`Self::new_with_min`] for buffers running a
    /// `LowestPriority` remover. Leaf encoding maps unsampleable
    /// (zero-priority) leaves — and the padding beyond the logical
    /// capacity — to `+inf` so they are never selected as victims.
    min_nodes: Option<AlignedBox<AtomicU32>>,
}

/// Min-tree leaf encoding: zero (unsampleable) leaves read as `+inf`.
#[inline(always)]
fn min_enc(v: f32) -> f32 {
    if v > 0.0 {
        v
    } else {
        f32::INFINITY
    }
}

impl KArySumTree {
    /// Build a tree with the given leaf capacity and fan-out.
    ///
    /// `fanout` must be ≥ 2. For fan-outs ≥ `NODES_PER_LINE` the layout is
    /// cache-aligned per the paper; smaller fan-outs are permitted for the
    /// baseline comparisons.
    pub fn new(capacity: usize, fanout: usize) -> Self {
        assert!(capacity > 0, "capacity must be positive");
        assert!(fanout >= 2, "fanout must be >= 2");
        // Height so that fanout^(height-1) >= capacity.
        let mut leaves = 1usize;
        let mut height = 1usize;
        while leaves < capacity {
            leaves = leaves.checked_mul(fanout).expect("tree too large");
            height += 1;
        }
        // Level sizes: 1 (padded to a full group), fanout, fanout^2, ...
        // Padding the root group keeps every *group* aligned when
        // fanout % NODES_PER_LINE == 0 (Fig 6).
        let mut level_off = Vec::with_capacity(height);
        let mut off = 0usize;
        let mut width = 1usize;
        for lvl in 0..height {
            level_off.push(off);
            let alloc_width = if lvl == 0 { fanout } else { width };
            off += alloc_width;
            width *= fanout;
        }
        let nodes = AlignedBox::zeroed(off);
        Self {
            fanout,
            capacity: leaves,
            logical_capacity: capacity,
            level_off,
            height,
            nodes,
            min_nodes: None,
        }
    }

    /// Build a tree that additionally tracks the minimum positive leaf
    /// per sibling group, so a `LowestPriority` remover can find its
    /// victim in Θ((log_K N)·K) instead of a full leaf scan. Every node
    /// starts at `+inf` (= empty).
    pub fn new_with_min(capacity: usize, fanout: usize) -> Self {
        let mut t = Self::new(capacity, fanout);
        let min = AlignedBox::zeroed(t.nodes.len());
        for slot in min.iter() {
            store(slot, f32::INFINITY);
        }
        t.min_nodes = Some(min);
        t
    }

    /// Whether this tree maintains the parallel min tree.
    pub fn tracks_min(&self) -> bool {
        self.min_nodes.is_some()
    }

    /// Fan-out K.
    pub fn fanout(&self) -> usize {
        self.fanout
    }

    /// Padded leaf capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Leaf capacity as requested by the caller.
    pub fn logical_capacity(&self) -> usize {
        self.logical_capacity
    }

    /// Tree height (number of levels).
    pub fn height(&self) -> usize {
        self.height
    }

    /// Total number of allocated node slots (for space-complexity tests).
    pub fn node_slots(&self) -> usize {
        self.nodes.len()
    }

    #[inline(always)]
    fn leaf_slot(&self, idx: usize) -> &AtomicU32 {
        debug_assert!(idx < self.capacity);
        &self.nodes[self.level_off[self.height - 1] + idx]
    }

    /// Σ of all priorities: the root value, Θ(1) (paper §IV-C3).
    #[inline]
    pub fn total(&self) -> f32 {
        load(&self.nodes[0])
    }

    /// Priority of leaf `idx`, Θ(1) via direct indexing (paper §IV-C1).
    #[inline]
    pub fn get(&self, idx: usize) -> f32 {
        load(self.leaf_slot(idx))
    }

    /// Set leaf `idx` to `value` and return `Δ = value - old` WITHOUT
    /// propagating. First half of Algorithm 3's split update: the caller
    /// holds `last_level_lock` (and `global_tree_lock`) around this.
    #[inline]
    pub fn set_leaf(&self, idx: usize, value: f32) -> f32 {
        debug_assert!(value >= 0.0, "priorities are non-negative");
        let slot = self.leaf_slot(idx);
        let old = load(slot);
        store(slot, value);
        if let Some(min) = &self.min_nodes {
            store(&min[self.level_off[self.height - 1] + idx], min_enc(value));
        }
        value - old
    }

    /// Propagate `delta` from leaf `idx`'s parent chain to the root.
    /// Second half of Algorithm 3's split update: the caller holds only
    /// `global_tree_lock` around this (leaf lock already released).
    ///
    /// With min tracking enabled, the interior min nodes along the same
    /// path are recomputed from their K children (mins cannot be
    /// updated incrementally). The `delta == 0` early return is safe
    /// for the min tree too: zero delta means the leaf value — and
    /// hence its min encoding — did not change.
    pub fn propagate(&self, idx: usize, delta: f32) {
        if delta == 0.0 {
            return;
        }
        let mut i = idx;
        // Walk levels H-2 .. 0 (all interior levels including the root).
        for lvl in (0..self.height - 1).rev() {
            let parent = i / self.fanout;
            if let Some(min) = &self.min_nodes {
                let base = self.level_off[lvl + 1] + parent * self.fanout;
                let mut m = f32::INFINITY;
                for c in 0..self.fanout {
                    m = m.min(load(&min[base + c]));
                }
                store(&min[self.level_off[lvl] + parent], m);
            }
            i = parent;
            let slot = &self.nodes[self.level_off[lvl] + i];
            store(slot, load(slot) + delta);
        }
    }

    /// Lowest-priority sampleable leaf, via min-tree descent: the leaf
    /// with the smallest strictly-positive priority (ties break to the
    /// lowest index). `None` when min tracking is disabled or no leaf
    /// holds positive priority. Callers hold `global_tree_lock` so the
    /// descent is consistent with concurrent updates.
    pub fn min_leaf(&self) -> Option<(usize, f32)> {
        let min = self.min_nodes.as_ref()?;
        if !load(&min[0]).is_finite() {
            return None;
        }
        let mut i = 0usize;
        for lvl in 1..self.height {
            let base = self.level_off[lvl] + i * self.fanout;
            let mut best = 0usize;
            let mut best_v = f32::INFINITY;
            for c in 0..self.fanout {
                let v = load(&min[base + c]);
                if v < best_v {
                    best_v = v;
                    best = c;
                }
            }
            i = i * self.fanout + best;
        }
        Some((i, load(&min[self.level_off[self.height - 1] + i])))
    }

    /// Convenience: UPDATEVALUE of Algorithm 2 (set + propagate).
    /// Θ(log_K N).
    pub fn update(&self, idx: usize, value: f32) {
        let delta = self.set_leaf(idx, value);
        self.propagate(idx, delta);
    }

    /// GETPREFIXSUMIDX of Algorithm 2: smallest leaf index whose prefix
    /// sum of priorities is ≥ `prefix`. `prefix` must be in
    /// `[0, total()]`; values beyond the total clamp to the last non-zero
    /// leaf. Returns `(leaf_index, leaf_priority)`.
    ///
    /// Θ((log_K N)·K) node visits, with K/C cache misses per level thanks
    /// to the aligned group layout (paper §IV-C5b).
    pub fn prefix_sum_index(&self, mut prefix: f32) -> (usize, f32) {
        let mut i = 0usize; // node index within its level
        for lvl in 1..self.height {
            let base = self.level_off[lvl] + i * self.fanout;
            // Single forward scan of the K children (contiguous,
            // cache-aligned): pick the first strictly-positive child whose
            // running sum crosses `prefix`. The last strictly-positive
            // child seen so far doubles as the fallback for fp drift /
            // beyond-total clamping, so zero-priority children are never
            // descended into while the subtree holds positive mass — with
            // no rescans of the sibling group.
            let mut partial = 0.0f32;
            let mut chosen = usize::MAX;
            let mut chosen_before = 0.0f32;
            let mut last_pos = usize::MAX;
            let mut last_pos_before = 0.0f32;
            for child in 0..self.fanout {
                let v = load(&self.nodes[base + child]);
                if v > 0.0 {
                    last_pos = child;
                    last_pos_before = partial;
                    if partial + v >= prefix {
                        chosen = child;
                        chosen_before = partial;
                        break;
                    }
                }
                partial += v;
            }
            let (child, before) = if chosen != usize::MAX {
                (chosen, chosen_before)
            } else if last_pos != usize::MAX {
                // No crossing (prefix beyond the subtree total): clamp to
                // the last strictly-positive child.
                (last_pos, last_pos_before)
            } else {
                // Subtree transiently all-zero (benign race with a lazy
                // insert); descend rightmost like the historical behavior.
                (self.fanout - 1, partial)
            };
            prefix -= before;
            i = i * self.fanout + child;
        }
        (i, self.get(i))
    }

    /// Recompute every interior node from the leaves. Used to (a) squash
    /// accumulated floating-point drift on long runs and (b) verify the
    /// tree invariant in tests. Callers must hold exclusive access (both
    /// locks in the Alg-3 protocol).
    pub fn rebuild(&self) {
        for lvl in (0..self.height - 1).rev() {
            let width = self.level_width(lvl);
            for i in 0..width {
                let base = self.level_off[lvl + 1] + i * self.fanout;
                let mut s = 0.0f32;
                for c in 0..self.fanout {
                    s += load(&self.nodes[base + c]);
                }
                store(&self.nodes[self.level_off[lvl] + i], s);
                if let Some(min) = &self.min_nodes {
                    let mut m = f32::INFINITY;
                    for c in 0..self.fanout {
                        m = m.min(load(&min[base + c]));
                    }
                    store(&min[self.level_off[lvl] + i], m);
                }
            }
        }
    }

    /// Number of *logical* nodes at a level (1 at the root, K at level 1…).
    pub fn level_width(&self, lvl: usize) -> usize {
        let mut w = 1usize;
        for _ in 0..lvl {
            w *= self.fanout;
        }
        w
    }

    /// Maximum absolute deviation between each interior node and the sum
    /// of its children — the tree invariant (0 in a quiescent tree up to
    /// fp error). Test/diagnostic helper.
    pub fn invariant_error(&self) -> f32 {
        let mut worst = 0.0f32;
        for lvl in 0..self.height - 1 {
            let width = self.level_width(lvl);
            for i in 0..width {
                let base = self.level_off[lvl + 1] + i * self.fanout;
                let mut s = 0.0f32;
                for c in 0..self.fanout {
                    s += load(&self.nodes[base + c]);
                }
                let v = load(&self.nodes[self.level_off[lvl] + i]);
                let scale = v.abs().max(s.abs()).max(1.0);
                worst = worst.max((v - s).abs() / scale);
            }
        }
        worst
    }

    /// Check the Fig-6 alignment property: every sibling group starts on a
    /// cache-line boundary (meaningful when `fanout % NODES_PER_LINE == 0`).
    pub fn groups_cache_aligned(&self) -> bool {
        if self.fanout % NODES_PER_LINE != 0 {
            return false;
        }
        let base = self.nodes.as_ptr() as usize;
        if base % CACHE_LINE != 0 {
            return false;
        }
        // Each level starts at an offset that's a multiple of the fanout,
        // hence of NODES_PER_LINE, hence 64-byte aligned; groups are K
        // consecutive nodes so every group inherits the alignment.
        self.level_off
            .iter()
            .all(|&off| (base + off * 4) % CACHE_LINE == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn single_leaf_roundtrip() {
        let t = KArySumTree::new(1, 4);
        t.update(0, 2.5);
        assert_eq!(t.get(0), 2.5);
        assert_eq!(t.total(), 2.5);
        assert_eq!(t.prefix_sum_index(1.0), (0, 2.5));
    }

    #[test]
    fn totals_match_leaf_sum_across_fanouts() {
        for fanout in [2usize, 4, 16, 64, 256] {
            let n = 1000;
            let t = KArySumTree::new(n, fanout);
            let mut rng = Rng::new(5);
            let mut expect = 0.0f64;
            for i in 0..n {
                let p = rng.f32();
                t.update(i, p);
                expect += p as f64;
            }
            let total = t.total() as f64;
            assert!(
                (total - expect).abs() / expect < 1e-4,
                "fanout {fanout}: {total} vs {expect}"
            );
            assert!(t.invariant_error() < 1e-4);
        }
    }

    #[test]
    fn prefix_sum_matches_linear_scan() {
        for fanout in [2usize, 4, 16, 64] {
            let n = 257;
            let t = KArySumTree::new(n, fanout);
            let mut rng = Rng::new(77);
            let mut prios = vec![0.0f32; n];
            for i in 0..n {
                prios[i] = rng.f32() * 2.0;
                t.update(i, prios[i]);
            }
            let total: f32 = prios.iter().sum();
            for trial in 0..500 {
                let x = (trial as f32 / 500.0) * total;
                let (idx, _) = t.prefix_sum_index(x);
                // Linear-scan oracle.
                let mut acc = 0.0f32;
                let mut expect = n - 1;
                for (i, &p) in prios.iter().enumerate() {
                    acc += p;
                    if acc >= x && p > 0.0 {
                        expect = i;
                        break;
                    }
                }
                // Allow off-by-small due to independent fp summation order.
                let lo = expect.saturating_sub(1);
                let hi = (expect + 1).min(n - 1);
                assert!(
                    (lo..=hi).contains(&idx),
                    "fanout {fanout} x {x}: got {idx}, oracle {expect}"
                );
            }
        }
    }

    #[test]
    fn never_samples_zero_priority_leaf() {
        let t = KArySumTree::new(64, 4);
        // Only odd leaves get priority.
        for i in (1..64).step_by(2) {
            t.update(i, 1.0);
        }
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            let x = rng.f32() * t.total();
            let (idx, p) = t.prefix_sum_index(x);
            assert!(p > 0.0, "sampled zero-priority leaf {idx}");
            assert_eq!(idx % 2, 1);
        }
    }

    #[test]
    fn sampling_distribution_proportional_to_priority() {
        let n = 16;
        let t = KArySumTree::new(n, 16);
        for i in 0..n {
            t.update(i, (i + 1) as f32);
        }
        let total: f32 = (1..=n as u32).sum::<u32>() as f32;
        let mut rng = Rng::new(123);
        let trials = 200_000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            let x = rng.f32() * total;
            let (idx, _) = t.prefix_sum_index(x);
            counts[idx] += 1;
        }
        for i in 0..n {
            let expect = (i + 1) as f64 / total as f64;
            let got = counts[i] as f64 / trials as f64;
            assert!(
                (got - expect).abs() < 0.01,
                "leaf {i}: got {got:.4} expect {expect:.4}"
            );
        }
    }

    #[test]
    fn space_complexity_shrinks_with_fanout() {
        // Θ(N + (N-1)/(K-1)) interior nodes: higher K ⇒ fewer slots
        // (§IV-C5a), modulo padding of the last level.
        let n = 4096;
        let s2 = KArySumTree::new(n, 2).node_slots();
        let s16 = KArySumTree::new(n, 16).node_slots();
        let s64 = KArySumTree::new(n, 64).node_slots();
        assert!(s2 > s16 && s16 > s64, "{s2} {s16} {s64}");
    }

    #[test]
    fn layout_cache_aligned_for_paper_fanouts() {
        for fanout in [16usize, 32, 64, 128, 256] {
            let t = KArySumTree::new(1000, fanout);
            assert!(t.groups_cache_aligned(), "fanout {fanout}");
        }
        // Binary baseline is deliberately unaligned.
        assert!(!KArySumTree::new(1000, 2).groups_cache_aligned());
    }

    #[test]
    fn update_overwrite_and_decrease() {
        let t = KArySumTree::new(10, 4);
        t.update(3, 5.0);
        t.update(3, 1.5);
        assert_eq!(t.get(3), 1.5);
        assert!((t.total() - 1.5).abs() < 1e-6);
    }

    #[test]
    fn rebuild_squashes_drift() {
        let t = KArySumTree::new(1000, 64);
        let mut rng = Rng::new(99);
        for _ in 0..50_000 {
            let i = rng.below_usize(1000);
            t.update(i, rng.f32());
        }
        t.rebuild();
        assert!(t.invariant_error() < 1e-6);
    }

    #[test]
    fn min_tracking_follows_updates() {
        let t = KArySumTree::new_with_min(100, 16);
        assert!(t.tracks_min());
        assert_eq!(t.min_leaf(), None); // empty tree: all +inf
        t.update(7, 3.0);
        t.update(42, 0.5);
        t.update(99, 2.0);
        assert_eq!(t.min_leaf(), Some((42, 0.5)));
        t.update(42, 9.0);
        assert_eq!(t.min_leaf(), Some((99, 2.0)));
        // Zeroed (unsampleable) leaves leave the min tree entirely.
        t.update(99, 0.0);
        assert_eq!(t.min_leaf(), Some((7, 3.0)));
        t.update(7, 0.0);
        t.update(42, 0.0);
        assert_eq!(t.min_leaf(), None);
        // Sums were maintained alongside.
        assert!(t.total().abs() < 1e-6);
    }

    #[test]
    fn min_tracking_ties_rebuild_and_default_off() {
        let t = KArySumTree::new_with_min(64, 4);
        for i in 0..64 {
            t.update(i, 1.0);
        }
        // Uniform priorities: the tie breaks to the lowest index.
        assert_eq!(t.min_leaf(), Some((0, 1.0)));
        t.update(0, 2.0);
        t.update(17, 0.25);
        t.rebuild();
        assert_eq!(t.min_leaf(), Some((17, 0.25)));
        assert!(t.invariant_error() < 1e-5);
        // Plain trees never pay for min tracking.
        let plain = KArySumTree::new(8, 4);
        plain.update(3, 1.0);
        assert!(!plain.tracks_min());
        assert_eq!(plain.min_leaf(), None);
    }

    #[test]
    fn prefix_beyond_total_clamps() {
        let t = KArySumTree::new(8, 4);
        t.update(2, 1.0);
        t.update(5, 2.0);
        let (idx, p) = t.prefix_sum_index(t.total() * 10.0);
        assert!(p > 0.0);
        assert!(idx == 5, "got {idx}");
    }
}
