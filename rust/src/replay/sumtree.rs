//! K-ary sum tree over priorities (paper §IV-C, Figs 3–6).
//!
//! The tree is stored *implicitly* in a single cache-line-aligned array:
//! level ℓ occupies a contiguous run of `K^ℓ` nodes, so every group of K
//! siblings (all children of one parent) starts on a cache-line boundary
//! provided `K % C == 0`, where `C = 16` f32 nodes per 64-byte line. The
//! root is padded to a full group of `K` slots exactly as in Fig 6.
//!
//! Values are stored as `AtomicU32` holding f32 bits with `Relaxed`
//! ordering. On x86-64 these compile to plain loads/stores, so the layout
//! and speed match the paper's C++ while keeping Rust's data-race rules
//! intact: the paper *deliberately* allows benign read/write races between
//! sampling and interior-node updates (§IV-D3, "write after read ...
//! little impact in practice"), which would be UB with plain `f32`.
//!
//! Thread-safety discipline is supplied by the caller
//! ([`crate::replay::prioritized`] implements the two-lock protocol of
//! Algorithm 3); all methods here take `&self` and are individually atomic
//! per node but not across nodes.

use crate::util::aligned::{AlignedBox, CACHE_LINE};
use std::sync::atomic::{AtomicU32, Ordering};

/// Number of f32 nodes per cache line.
pub const NODES_PER_LINE: usize = CACHE_LINE / std::mem::size_of::<f32>();

#[inline(always)]
fn load(a: &AtomicU32) -> f32 {
    f32::from_bits(a.load(Ordering::Relaxed))
}

#[inline(always)]
fn store(a: &AtomicU32, v: f32) {
    a.store(v.to_bits(), Ordering::Relaxed)
}

/// Hint the hardware prefetcher at a node about to be scanned. A pure
/// hint: any address is safe to prefetch, and the fallback on
/// non-x86-64 targets is a no-op.
#[inline(always)]
#[allow(unused_variables)]
fn prefetch(slot: &AtomicU32) {
    #[cfg(target_arch = "x86_64")]
    // Safety: prefetch never faults; it is advisory for any address.
    unsafe {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<_MM_HINT_T0>(slot as *const AtomicU32 as *const i8);
    }
}

/// K-ary sum tree with the paper's implicit cache-aligned layout.
pub struct KArySumTree {
    /// Fan-out K. Power of two, `K % NODES_PER_LINE == 0` unless K == 2
    /// (the binary configuration used as the Fig 9 baseline).
    fanout: usize,
    /// Leaf capacity (number of priorities), padded up to `K^(H-1)`.
    capacity: usize,
    /// Requested (un-padded) capacity.
    logical_capacity: usize,
    /// Offset of each level in `nodes`; `level_off[0]` is the root.
    level_off: Vec<usize>,
    /// Number of levels (root = level 0, leaves = level H-1).
    height: usize,
    /// The node array. Level ℓ lives at `level_off[ℓ] ..`.
    nodes: AlignedBox<AtomicU32>,
    /// Optional parallel min tree (same implicit layout as `nodes`),
    /// allocated only via [`Self::new_with_min`] for buffers running a
    /// `LowestPriority` remover. Leaf encoding maps unsampleable
    /// (zero-priority) leaves — and the padding beyond the logical
    /// capacity — to `+inf` so they are never selected as victims.
    min_nodes: Option<AlignedBox<AtomicU32>>,
}

/// Min-tree leaf encoding: zero (unsampleable) leaves read as `+inf`.
#[inline(always)]
fn min_enc(v: f32) -> f32 {
    if v > 0.0 {
        v
    } else {
        f32::INFINITY
    }
}

/// Scan one sibling group for the first strictly-positive child whose
/// running sum crosses `prefix`, returning `(child, sum before child)`.
///
/// The scan is chunked by cache line (paper §IV-C5b): each 16-node line
/// is summed as a block — with the *next* line prefetched while this one
/// is summed — and only the line containing the crossing is examined
/// child-by-child. Groups smaller than a line degrade to one block of
/// group size, i.e. the plain scalar scan.
///
/// Clamp semantics match the scalar scan: with `prefix` beyond the group
/// total the last strictly-positive child wins, and an all-zero group
/// (benign race with a lazy insert) falls back to the rightmost child.
fn pick_child(group: &[AtomicU32], prefix: f32) -> (usize, f32) {
    let k = group.len();
    let mut partial = 0.0f32;
    // Last line that held a strictly-positive child, and the running sum
    // at its start — revisited only on the beyond-total clamp path.
    let mut pos_line = usize::MAX;
    let mut pos_line_partial = 0.0f32;
    let mut c = 0usize;
    while c < k {
        let end = (c + NODES_PER_LINE).min(k);
        if end < k {
            prefetch(&group[end]); // next sibling line, overlapped with this sum
        }
        let mut line_sum = 0.0f32;
        let mut any_pos = false;
        for slot in &group[c..end] {
            let v = load(slot);
            line_sum += v;
            any_pos |= v > 0.0;
        }
        if any_pos {
            if partial + line_sum >= prefix {
                // With non-negative children the crossing child is in this
                // line (the last positive child's running sum reaches the
                // line total, which crossed).
                return scan_line(group, c, end, partial, prefix);
            }
            pos_line = c;
            pos_line_partial = partial;
        }
        partial += line_sum;
        c = end;
    }
    if pos_line != usize::MAX {
        // `prefix` beyond the subtree total (top-level clamp, fp drift or
        // a poisoned block sum): take the LAST strictly-positive child.
        let end = (pos_line + NODES_PER_LINE).min(k);
        let mut p = pos_line_partial;
        let mut child = k - 1;
        let mut before = p;
        for (j, slot) in group[pos_line..end].iter().enumerate() {
            let v = load(slot);
            if v > 0.0 {
                child = pos_line + j;
                before = p;
            }
            p += v;
        }
        (child, before)
    } else {
        // Subtree transiently all-zero (benign race with a lazy insert);
        // descend rightmost like the historical behavior.
        (k - 1, partial)
    }
}

/// Child-by-child scan of `group[c..end]`, the line holding the crossing.
#[inline]
fn scan_line(
    group: &[AtomicU32],
    c: usize,
    end: usize,
    mut partial: f32,
    prefix: f32,
) -> (usize, f32) {
    let mut last_pos = usize::MAX;
    let mut last_pos_before = 0.0f32;
    for (j, slot) in group[c..end].iter().enumerate() {
        let v = load(slot);
        if v > 0.0 {
            last_pos = c + j;
            last_pos_before = partial;
            if partial + v >= prefix {
                return (c + j, partial);
            }
        }
        partial += v;
    }
    if last_pos != usize::MAX {
        // Reachable only when fp drift or a concurrent update defeats the
        // block-level test; clamp to the line's last positive child.
        (last_pos, last_pos_before)
    } else {
        (end - 1, partial)
    }
}

impl KArySumTree {
    /// Build a tree with the given leaf capacity and fan-out.
    ///
    /// `fanout` must be ≥ 2. For fan-outs ≥ `NODES_PER_LINE` the layout is
    /// cache-aligned per the paper; smaller fan-outs are permitted for the
    /// baseline comparisons.
    pub fn new(capacity: usize, fanout: usize) -> Self {
        assert!(capacity > 0, "capacity must be positive");
        assert!(fanout >= 2, "fanout must be >= 2");
        // Height so that fanout^(height-1) >= capacity.
        let mut leaves = 1usize;
        let mut height = 1usize;
        while leaves < capacity {
            leaves = leaves.checked_mul(fanout).expect("tree too large");
            height += 1;
        }
        // Level sizes: 1 (padded to a full group), fanout, fanout^2, ...
        // Padding the root group keeps every *group* aligned when
        // fanout % NODES_PER_LINE == 0 (Fig 6).
        let mut level_off = Vec::with_capacity(height);
        let mut off = 0usize;
        let mut width = 1usize;
        for lvl in 0..height {
            level_off.push(off);
            let alloc_width = if lvl == 0 { fanout } else { width };
            off += alloc_width;
            width *= fanout;
        }
        let nodes = AlignedBox::zeroed(off);
        Self {
            fanout,
            capacity: leaves,
            logical_capacity: capacity,
            level_off,
            height,
            nodes,
            min_nodes: None,
        }
    }

    /// Build a tree that additionally tracks the minimum positive leaf
    /// per sibling group, so a `LowestPriority` remover can find its
    /// victim in Θ((log_K N)·K) instead of a full leaf scan. Every node
    /// starts at `+inf` (= empty).
    pub fn new_with_min(capacity: usize, fanout: usize) -> Self {
        let mut t = Self::new(capacity, fanout);
        let min = AlignedBox::zeroed(t.nodes.len());
        for slot in min.iter() {
            store(slot, f32::INFINITY);
        }
        t.min_nodes = Some(min);
        t
    }

    /// Whether this tree maintains the parallel min tree.
    pub fn tracks_min(&self) -> bool {
        self.min_nodes.is_some()
    }

    /// Fan-out K.
    pub fn fanout(&self) -> usize {
        self.fanout
    }

    /// Padded leaf capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Leaf capacity as requested by the caller.
    pub fn logical_capacity(&self) -> usize {
        self.logical_capacity
    }

    /// Tree height (number of levels).
    pub fn height(&self) -> usize {
        self.height
    }

    /// Total number of allocated node slots (for space-complexity tests).
    pub fn node_slots(&self) -> usize {
        self.nodes.len()
    }

    #[inline(always)]
    fn leaf_slot(&self, idx: usize) -> &AtomicU32 {
        debug_assert!(idx < self.capacity);
        &self.nodes[self.level_off[self.height - 1] + idx]
    }

    /// Σ of all priorities: the root value, Θ(1) (paper §IV-C3).
    #[inline]
    pub fn total(&self) -> f32 {
        load(&self.nodes[0])
    }

    /// Priority of leaf `idx`, Θ(1) via direct indexing (paper §IV-C1).
    #[inline]
    pub fn get(&self, idx: usize) -> f32 {
        load(self.leaf_slot(idx))
    }

    /// Set leaf `idx` to `value` and return `Δ = value - old` WITHOUT
    /// propagating. First half of Algorithm 3's split update: the caller
    /// holds `last_level_lock` (and `global_tree_lock`) around this.
    #[inline]
    pub fn set_leaf(&self, idx: usize, value: f32) -> f32 {
        debug_assert!(
            value.is_finite() && value >= 0.0,
            "priorities are finite and non-negative"
        );
        // Release-build last line of defense: a NaN stored here would
        // poison every interior sum up to the root permanently, and
        // ±inf/negative values corrupt the sampling distribution for the
        // whole table. Map them to 0 (unsampleable) instead.
        let value = if value.is_finite() && value >= 0.0 {
            value
        } else {
            0.0
        };
        let slot = self.leaf_slot(idx);
        let old = load(slot);
        store(slot, value);
        if let Some(min) = &self.min_nodes {
            store(&min[self.level_off[self.height - 1] + idx], min_enc(value));
        }
        value - old
    }

    /// Propagate `delta` from leaf `idx`'s parent chain to the root.
    /// Second half of Algorithm 3's split update: the caller holds only
    /// `global_tree_lock` around this (leaf lock already released).
    ///
    /// With min tracking enabled, the interior min nodes along the same
    /// path are recomputed from their K children (mins cannot be
    /// updated incrementally). The `delta == 0` early return is safe
    /// for the min tree too: zero delta means the leaf value — and
    /// hence its min encoding — did not change. The min recompute stops
    /// at the first level whose group minimum comes out unchanged: if a
    /// group's min did not move, no ancestor's min can have moved either,
    /// so only the sums still need the delta above that point.
    pub fn propagate(&self, idx: usize, delta: f32) {
        if delta == 0.0 {
            return;
        }
        let fanout = self.fanout;
        let mut i = idx;
        let mut min_live = self.min_nodes.is_some();
        // Walk levels H-2 .. 0 (all interior levels including the root).
        for lvl in (0..self.height - 1).rev() {
            let parent = i / fanout;
            if min_live {
                let min = self.min_nodes.as_ref().unwrap();
                let base = self.level_off[lvl + 1] + parent * fanout;
                let mut m = f32::INFINITY;
                for c in 0..fanout {
                    m = m.min(load(&min[base + c]));
                }
                let slot = &min[self.level_off[lvl] + parent];
                // Bitwise compare is exact here: min encodings are +inf or
                // strictly-positive finite values, never -0.0.
                if load(slot).to_bits() == m.to_bits() {
                    min_live = false;
                } else {
                    store(slot, m);
                }
            }
            i = parent;
            let slot = &self.nodes[self.level_off[lvl] + i];
            store(slot, load(slot) + delta);
        }
    }

    /// Lowest-priority sampleable leaf, via min-tree descent: the leaf
    /// with the smallest strictly-positive priority (ties break to the
    /// lowest index). `None` when min tracking is disabled or no leaf
    /// holds positive priority. Callers hold `global_tree_lock` so the
    /// descent is consistent with concurrent updates.
    pub fn min_leaf(&self) -> Option<(usize, f32)> {
        let min = self.min_nodes.as_ref()?;
        if !load(&min[0]).is_finite() {
            return None;
        }
        let mut i = 0usize;
        for lvl in 1..self.height {
            let base = self.level_off[lvl] + i * self.fanout;
            let mut best = 0usize;
            let mut best_v = f32::INFINITY;
            for c in 0..self.fanout {
                let v = load(&min[base + c]);
                if v < best_v {
                    best_v = v;
                    best = c;
                }
            }
            i = i * self.fanout + best;
        }
        Some((i, load(&min[self.level_off[self.height - 1] + i])))
    }

    /// Convenience: UPDATEVALUE of Algorithm 2 (set + propagate).
    /// Θ(log_K N).
    pub fn update(&self, idx: usize, value: f32) {
        let delta = self.set_leaf(idx, value);
        self.propagate(idx, delta);
    }

    /// GETPREFIXSUMIDX of Algorithm 2: smallest leaf index whose prefix
    /// sum of priorities is ≥ `prefix`. `prefix` must be in
    /// `[0, total()]`; values beyond the total clamp to the last non-zero
    /// leaf. Returns `(leaf_index, leaf_priority)`.
    ///
    /// Θ((log_K N)·K) node visits, with K/C cache misses per level thanks
    /// to the aligned group layout (paper §IV-C5b). The K-child scan runs
    /// cache-line by cache-line: each 16-node line is summed as a block
    /// (prefetching the next sibling line while it is summed) and only
    /// the line containing the crossing is examined child-by-child.
    pub fn prefix_sum_index(&self, mut prefix: f32) -> (usize, f32) {
        let fanout = self.fanout;
        let mut i = 0usize; // node index within its level
        for lvl in 1..self.height {
            let row = i * fanout; // index of node i's first child
            let base = self.level_off[lvl] + row;
            let (child, before) = pick_child(&self.nodes[base..base + fanout], prefix);
            // Start pulling the chosen child's own sibling group while the
            // bookkeeping below retires, so the next level's scan begins
            // with its first line already in flight.
            if lvl + 1 < self.height {
                prefetch(&self.nodes[self.level_off[lvl + 1] + (row + child) * fanout]);
            }
            // Clamp: the all-zero fallback (or fp drift / a poisoned node)
            // can make `before` exceed `prefix`; a negative — or NaN —
            // prefix would deterministically bias every deeper level
            // toward its first positive child.
            prefix = (prefix - before).max(0.0);
            i = row + child;
        }
        (i, self.get(i))
    }

    /// Recompute every interior node from the leaves. Used to (a) squash
    /// accumulated floating-point drift on long runs and (b) verify the
    /// tree invariant in tests. Callers must hold exclusive access (both
    /// locks in the Alg-3 protocol).
    pub fn rebuild(&self) {
        for lvl in (0..self.height - 1).rev() {
            let width = self.level_width(lvl);
            for i in 0..width {
                let base = self.level_off[lvl + 1] + i * self.fanout;
                let mut s = 0.0f32;
                for c in 0..self.fanout {
                    s += load(&self.nodes[base + c]);
                }
                store(&self.nodes[self.level_off[lvl] + i], s);
                if let Some(min) = &self.min_nodes {
                    let mut m = f32::INFINITY;
                    for c in 0..self.fanout {
                        m = m.min(load(&min[base + c]));
                    }
                    store(&min[self.level_off[lvl] + i], m);
                }
            }
        }
    }

    /// Number of *logical* nodes at a level (1 at the root, K at level 1…).
    pub fn level_width(&self, lvl: usize) -> usize {
        let mut w = 1usize;
        for _ in 0..lvl {
            w *= self.fanout;
        }
        w
    }

    /// Maximum absolute deviation between each interior node and the sum
    /// of its children — the tree invariant (0 in a quiescent tree up to
    /// fp error). Test/diagnostic helper.
    pub fn invariant_error(&self) -> f32 {
        let mut worst = 0.0f32;
        for lvl in 0..self.height - 1 {
            let width = self.level_width(lvl);
            for i in 0..width {
                let base = self.level_off[lvl + 1] + i * self.fanout;
                let mut s = 0.0f32;
                for c in 0..self.fanout {
                    s += load(&self.nodes[base + c]);
                }
                let v = load(&self.nodes[self.level_off[lvl] + i]);
                let scale = v.abs().max(s.abs()).max(1.0);
                worst = worst.max((v - s).abs() / scale);
            }
        }
        worst
    }

    /// Check the Fig-6 alignment property: every sibling group starts on a
    /// cache-line boundary (meaningful when `fanout % NODES_PER_LINE == 0`).
    pub fn groups_cache_aligned(&self) -> bool {
        if self.fanout % NODES_PER_LINE != 0 {
            return false;
        }
        let base = self.nodes.as_ptr() as usize;
        if base % CACHE_LINE != 0 {
            return false;
        }
        // Each level starts at an offset that's a multiple of the fanout,
        // hence of NODES_PER_LINE, hence 64-byte aligned; groups are K
        // consecutive nodes so every group inherits the alignment.
        self.level_off
            .iter()
            .all(|&off| (base + off * 4) % CACHE_LINE == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn single_leaf_roundtrip() {
        let t = KArySumTree::new(1, 4);
        t.update(0, 2.5);
        assert_eq!(t.get(0), 2.5);
        assert_eq!(t.total(), 2.5);
        assert_eq!(t.prefix_sum_index(1.0), (0, 2.5));
    }

    #[test]
    fn totals_match_leaf_sum_across_fanouts() {
        for fanout in [2usize, 4, 16, 64, 256] {
            let n = 1000;
            let t = KArySumTree::new(n, fanout);
            let mut rng = Rng::new(5);
            let mut expect = 0.0f64;
            for i in 0..n {
                let p = rng.f32();
                t.update(i, p);
                expect += p as f64;
            }
            let total = t.total() as f64;
            assert!(
                (total - expect).abs() / expect < 1e-4,
                "fanout {fanout}: {total} vs {expect}"
            );
            assert!(t.invariant_error() < 1e-4);
        }
    }

    #[test]
    fn prefix_sum_matches_linear_scan() {
        for fanout in [2usize, 4, 16, 64] {
            let n = 257;
            let t = KArySumTree::new(n, fanout);
            let mut rng = Rng::new(77);
            let mut prios = vec![0.0f32; n];
            for i in 0..n {
                prios[i] = rng.f32() * 2.0;
                t.update(i, prios[i]);
            }
            let total: f32 = prios.iter().sum();
            for trial in 0..500 {
                let x = (trial as f32 / 500.0) * total;
                let (idx, _) = t.prefix_sum_index(x);
                // Linear-scan oracle.
                let mut acc = 0.0f32;
                let mut expect = n - 1;
                for (i, &p) in prios.iter().enumerate() {
                    acc += p;
                    if acc >= x && p > 0.0 {
                        expect = i;
                        break;
                    }
                }
                // Allow off-by-small due to independent fp summation order.
                let lo = expect.saturating_sub(1);
                let hi = (expect + 1).min(n - 1);
                assert!(
                    (lo..=hi).contains(&idx),
                    "fanout {fanout} x {x}: got {idx}, oracle {expect}"
                );
            }
        }
    }

    #[test]
    fn never_samples_zero_priority_leaf() {
        let t = KArySumTree::new(64, 4);
        // Only odd leaves get priority.
        for i in (1..64).step_by(2) {
            t.update(i, 1.0);
        }
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            let x = rng.f32() * t.total();
            let (idx, p) = t.prefix_sum_index(x);
            assert!(p > 0.0, "sampled zero-priority leaf {idx}");
            assert_eq!(idx % 2, 1);
        }
    }

    #[test]
    fn sampling_distribution_proportional_to_priority() {
        let n = 16;
        let t = KArySumTree::new(n, 16);
        for i in 0..n {
            t.update(i, (i + 1) as f32);
        }
        let total: f32 = (1..=n as u32).sum::<u32>() as f32;
        let mut rng = Rng::new(123);
        let trials = 200_000;
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            let x = rng.f32() * total;
            let (idx, _) = t.prefix_sum_index(x);
            counts[idx] += 1;
        }
        for i in 0..n {
            let expect = (i + 1) as f64 / total as f64;
            let got = counts[i] as f64 / trials as f64;
            assert!(
                (got - expect).abs() < 0.01,
                "leaf {i}: got {got:.4} expect {expect:.4}"
            );
        }
    }

    #[test]
    fn space_complexity_shrinks_with_fanout() {
        // Θ(N + (N-1)/(K-1)) interior nodes: higher K ⇒ fewer slots
        // (§IV-C5a), modulo padding of the last level.
        let n = 4096;
        let s2 = KArySumTree::new(n, 2).node_slots();
        let s16 = KArySumTree::new(n, 16).node_slots();
        let s64 = KArySumTree::new(n, 64).node_slots();
        assert!(s2 > s16 && s16 > s64, "{s2} {s16} {s64}");
    }

    #[test]
    fn layout_cache_aligned_for_paper_fanouts() {
        for fanout in [16usize, 32, 64, 128, 256] {
            let t = KArySumTree::new(1000, fanout);
            assert!(t.groups_cache_aligned(), "fanout {fanout}");
        }
        // Binary baseline is deliberately unaligned.
        assert!(!KArySumTree::new(1000, 2).groups_cache_aligned());
    }

    #[test]
    fn update_overwrite_and_decrease() {
        let t = KArySumTree::new(10, 4);
        t.update(3, 5.0);
        t.update(3, 1.5);
        assert_eq!(t.get(3), 1.5);
        assert!((t.total() - 1.5).abs() < 1e-6);
    }

    #[test]
    fn rebuild_squashes_drift() {
        let t = KArySumTree::new(1000, 64);
        let mut rng = Rng::new(99);
        for _ in 0..50_000 {
            let i = rng.below_usize(1000);
            t.update(i, rng.f32());
        }
        t.rebuild();
        assert!(t.invariant_error() < 1e-6);
    }

    #[test]
    fn min_tracking_follows_updates() {
        let t = KArySumTree::new_with_min(100, 16);
        assert!(t.tracks_min());
        assert_eq!(t.min_leaf(), None); // empty tree: all +inf
        t.update(7, 3.0);
        t.update(42, 0.5);
        t.update(99, 2.0);
        assert_eq!(t.min_leaf(), Some((42, 0.5)));
        t.update(42, 9.0);
        assert_eq!(t.min_leaf(), Some((99, 2.0)));
        // Zeroed (unsampleable) leaves leave the min tree entirely.
        t.update(99, 0.0);
        assert_eq!(t.min_leaf(), Some((7, 3.0)));
        t.update(7, 0.0);
        t.update(42, 0.0);
        assert_eq!(t.min_leaf(), None);
        // Sums were maintained alongside.
        assert!(t.total().abs() < 1e-6);
    }

    #[test]
    fn min_tracking_ties_rebuild_and_default_off() {
        let t = KArySumTree::new_with_min(64, 4);
        for i in 0..64 {
            t.update(i, 1.0);
        }
        // Uniform priorities: the tie breaks to the lowest index.
        assert_eq!(t.min_leaf(), Some((0, 1.0)));
        t.update(0, 2.0);
        t.update(17, 0.25);
        t.rebuild();
        assert_eq!(t.min_leaf(), Some((17, 0.25)));
        assert!(t.invariant_error() < 1e-5);
        // Plain trees never pay for min tracking.
        let plain = KArySumTree::new(8, 4);
        plain.update(3, 1.0);
        assert!(!plain.tracks_min());
        assert_eq!(plain.min_leaf(), None);
    }

    #[test]
    fn poisoned_interior_node_does_not_derail_descent() {
        // A NaN interior node (e.g. written by a buggy caller before the
        // decode/table-surface validation existed) makes `before` NaN for
        // the level that scans it. Without the `(prefix - before).max(0.0)`
        // clamp the NaN propagates into `prefix` and every deeper level
        // degrades to its *last* positive child; with the clamp the
        // descent recovers deterministically at the next level.
        let t = KArySumTree::new(64, 4); // height 4: root, 4, 16, 64
        t.update(1, 0.5); // under L2 node 0
        t.update(8, 0.3); // under L2 node 2
        t.update(9, 1.0); // under L2 node 2
        // Poison L2 node 1 (its subtree holds zero mass).
        store(&t.nodes[t.level_off[2] + 1], f32::NAN);
        let (idx, p) = t.prefix_sum_index(1.7);
        assert_eq!(idx, 8, "clamped descent picks the first positive leaf");
        assert_eq!(p, 0.3);
    }

    #[test]
    fn all_zero_fallback_stays_in_range() {
        let t = KArySumTree::new(16, 4);
        t.update(15, 5.0);
        // Tear the leaf like a lazy insert: zero it WITHOUT propagating,
        // so interior levels still claim the mass.
        let delta = t.set_leaf(15, 0.0);
        let (idx, p) = t.prefix_sum_index(3.0);
        // The descent lands in the now all-zero subtree and must fall
        // back in-range (rightmost leaf of the claimed subtree).
        assert_eq!(idx, 15);
        assert_eq!(p, 0.0);
        // Completing the split update restores the invariant.
        t.propagate(15, delta);
        assert_eq!(t.total(), 0.0);
        assert!(t.invariant_error() < 1e-6);
    }

    #[test]
    fn set_leaf_sanitizes_in_release_builds() {
        // The debug_assert fires in debug builds, so exercise the
        // release-path sanitization only when it is compiled out.
        if cfg!(debug_assertions) {
            return;
        }
        let t = KArySumTree::new(8, 4);
        t.update(0, 1.0);
        t.update(1, f32::NAN);
        t.update(2, f32::INFINITY);
        t.update(3, -4.0);
        assert_eq!(t.get(1), 0.0);
        assert_eq!(t.get(2), 0.0);
        assert_eq!(t.get(3), 0.0);
        assert!((t.total() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn min_plane_skip_matches_bruteforce_under_churn() {
        // The propagate() min recompute stops at the first level whose
        // group minimum is unchanged; a mirrored brute-force min after
        // every update proves the skip never goes stale.
        let t = KArySumTree::new_with_min(256, 16);
        let mut rng = Rng::new(41);
        let mut mirror = vec![0.0f32; 256];
        for step in 0..400 {
            let i = rng.below_usize(256);
            // Mix removals with small and large priorities so group
            // minima frequently stay unchanged and the skip is exercised.
            let v = match step % 4 {
                0 => 0.0,
                1 => 0.5 + rng.f32(),
                _ => 10.0 + rng.f32(),
            };
            t.update(i, v);
            mirror[i] = v;
            let mut best: Option<(usize, f32)> = None;
            for (j, &p) in mirror.iter().enumerate() {
                if p > 0.0 && best.is_none_or(|(_, bv)| p < bv) {
                    best = Some((j, p));
                }
            }
            assert_eq!(t.min_leaf(), best, "step {step}");
        }
        assert!(t.invariant_error() < 1e-4);
    }

    #[test]
    fn prefix_beyond_total_clamps() {
        let t = KArySumTree::new(8, 4);
        t.update(2, 1.0);
        t.update(5, 2.0);
        let (idx, p) = t.prefix_sum_index(t.total() * 10.0);
        assert!(p > 0.0);
        assert!(idx == 5, "got {idx}");
    }
}
