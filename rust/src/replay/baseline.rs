//! Baseline replay buffer: classic binary sum tree behind ONE global lock
//! (the comparator in paper §VI-D / Fig 9, and the buffer used by our
//! RLlib-substitute baseline framework in Fig 8).
//!
//! Everything — leaf writes, propagation, descent, storage copies — runs
//! inside the single mutex, which is exactly what makes it scale poorly:
//! the critical section includes the O(row) memory copy that the paper's
//! lazy writing moves outside.

use super::remover::{EvictReason, Remover, RemoverSpec};
use super::snapshot::{BufferState, ShardState};
use super::storage::{SampleBatch, Transition, TransitionStore};
use super::ReplayBuffer;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Mutex;

/// Classic 2N-array binary sum tree (no cache-alignment, no level
/// padding) — the "textbook" PER implementation.
pub struct BinarySumTree {
    /// nodes[1] is the root; leaves at nodes[cap..cap+cap].
    nodes: Vec<f32>,
    cap: usize,
}

impl BinarySumTree {
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.next_power_of_two();
        Self { nodes: vec![0.0; 2 * cap], cap }
    }

    pub fn total(&self) -> f32 {
        self.nodes[1]
    }

    pub fn get(&self, idx: usize) -> f32 {
        self.nodes[self.cap + idx]
    }

    pub fn update(&mut self, idx: usize, value: f32) {
        let mut i = self.cap + idx;
        let delta = value - self.nodes[i];
        while i >= 1 {
            self.nodes[i] += delta;
            i /= 2;
        }
    }

    /// Overwrite every leaf (zeroing those past `leaves.len()`) and
    /// recompute all interior sums bottom-up — exact assignment with no
    /// incremental fp drift, used by checkpoint restore.
    pub fn assign(&mut self, leaves: &[f32]) {
        assert!(leaves.len() <= self.cap);
        for slot in self.nodes[self.cap..].iter_mut() {
            *slot = 0.0;
        }
        self.nodes[self.cap..self.cap + leaves.len()].copy_from_slice(leaves);
        for i in (1..self.cap).rev() {
            self.nodes[i] = self.nodes[2 * i] + self.nodes[2 * i + 1];
        }
    }

    pub fn prefix_sum_index(&self, mut prefix: f32) -> (usize, f32) {
        let mut i = 1usize;
        while i < self.cap {
            let left = self.nodes[2 * i];
            if prefix <= left && left > 0.0 {
                i *= 2;
            } else {
                prefix -= left;
                i = 2 * i + 1;
            }
        }
        // Clamp to a non-zero leaf (fp drift guard), scanning left.
        let mut leaf = i - self.cap;
        while leaf > 0 && self.nodes[self.cap + leaf] <= 0.0 {
            leaf -= 1;
        }
        (leaf, self.nodes[self.cap + leaf])
    }
}

struct Inner {
    tree: BinarySumTree,
    cursor: usize,
    max_priority: f32,
}

/// Binary tree + single global lock buffer.
pub struct GlobalLockReplay {
    inner: Mutex<Inner>,
    store: TransitionStore,
    capacity: usize,
    alpha: f32,
    beta: f32,
    /// Eviction policy + per-slot sample counts. Victim selection runs
    /// under the same global lock as everything else, so even the O(N)
    /// `LowestPriority` scan needs no extra coordination.
    remover: Remover,
}

impl GlobalLockReplay {
    pub fn new(capacity: usize, obs_dim: usize, act_dim: usize, alpha: f32, beta: f32) -> Self {
        Self::with_remover(capacity, obs_dim, act_dim, alpha, beta, RemoverSpec::Fifo)
    }

    /// Build with an explicit eviction policy.
    pub fn with_remover(
        capacity: usize,
        obs_dim: usize,
        act_dim: usize,
        alpha: f32,
        beta: f32,
        remove: RemoverSpec,
    ) -> Self {
        Self {
            inner: Mutex::new(Inner {
                tree: BinarySumTree::new(capacity),
                cursor: 0,
                max_priority: 1.0,
            }),
            store: TransitionStore::new(capacity, obs_dim, act_dim),
            capacity,
            alpha,
            beta,
            remover: Remover::new(remove, capacity),
        }
    }

    fn transform(&self, td: f32) -> f32 {
        (td.max(0.0) + super::prioritized::PRIORITY_EPS).powf(self.alpha)
    }

    /// Pick the slot an insert lands in, given the pre-increment cursor.
    /// Caller holds the global lock, so the tree scan is consistent.
    fn pick_slot(&self, g: &Inner, cur: usize) -> (usize, Option<EvictReason>) {
        if cur < self.capacity {
            return (cur, None);
        }
        match self.remover.spec() {
            RemoverSpec::Fifo => (cur % self.capacity, Some(EvictReason::Fifo)),
            RemoverSpec::Lifo => (self.capacity - 1, Some(EvictReason::Lifo)),
            RemoverSpec::LowestPriority => {
                // O(N) argmin over the leaves; ties -> first (oldest slot).
                let mut best = 0usize;
                let mut best_p = f32::INFINITY;
                for i in 0..self.capacity {
                    let p = g.tree.get(i);
                    if p < best_p {
                        best_p = p;
                        best = i;
                    }
                }
                (best, Some(EvictReason::LowestPriority))
            }
            RemoverSpec::MaxTimesSampled(_) => match self.remover.pick_ripe() {
                Some(slot) => (slot, Some(EvictReason::MaxSampled)),
                None => (cur % self.capacity, Some(EvictReason::Fifo)),
            },
        }
    }
}

impl ReplayBuffer for GlobalLockReplay {
    fn name(&self) -> &'static str {
        "baseline-binary-global-lock"
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn len(&self) -> usize {
        let g = self.inner.lock().unwrap();
        g.cursor.min(self.capacity)
    }

    fn insert_from(&self, _actor_id: usize, t: &Transition) -> Option<EvictReason> {
        // Entire insertion — including the data copy — under the lock.
        let mut g = self.inner.lock().unwrap();
        let cur = g.cursor;
        g.cursor += 1;
        let (slot, reason) = self.pick_slot(&g, cur);
        self.store.write(slot, t);
        self.remover.on_insert(slot);
        let mp = g.max_priority;
        g.tree.update(slot, mp);
        reason
    }

    fn sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> bool {
        out.clear();
        let g = self.inner.lock().unwrap();
        let n = g.cursor.min(self.capacity);
        if n == 0 || batch == 0 {
            return false;
        }
        let total = g.tree.total();
        if !(total > 0.0) {
            return false;
        }
        let seg = total / batch as f32;
        for j in 0..batch {
            let x = (j as f32 + rng.f32()) * seg;
            let (idx, p) = g.tree.prefix_sum_index(x);
            out.indices.push(idx);
            out.priorities.push(p);
        }
        let nf = n as f32;
        let mut wmax = 0.0f32;
        for &p in &out.priorities {
            let pr = (p / total).max(f32::MIN_POSITIVE);
            let w = (nf * pr).powf(-self.beta);
            out.is_weights.push(w);
            wmax = wmax.max(w);
        }
        for w in &mut out.is_weights {
            *w /= wmax;
        }
        // Row copies also under the lock — the baseline's sin.
        for i in 0..out.indices.len() {
            self.store.read_into(out.indices[i], out);
        }
        true
    }

    fn total_priority(&self) -> f32 {
        self.inner.lock().unwrap().tree.total()
    }

    fn update_priorities(&self, indices: &[usize], td_abs: &[f32]) {
        let mut g = self.inner.lock().unwrap();
        for (&idx, &td) in indices.iter().zip(td_abs) {
            let p = self.transform(td);
            if p > g.max_priority {
                g.max_priority = p;
            }
            g.tree.update(idx, p);
        }
    }

    /// Everything lives behind the one lock, so the capture is trivially
    /// consistent: one shard, leaf priorities read off the binary tree.
    fn snapshot_state(&self) -> Option<BufferState> {
        let g = self.inner.lock().unwrap();
        let len = g.cursor.min(self.capacity);
        let mut priorities = Vec::with_capacity(len);
        let mut rows = Vec::with_capacity(len);
        for i in 0..len {
            priorities.push(g.tree.get(i));
            rows.push(self.store.read(i));
        }
        Some(BufferState {
            impl_name: self.name().to_string(),
            capacity: self.capacity,
            obs_dim: self.store.obs_dim(),
            act_dim: self.store.act_dim(),
            shards: vec![ShardState {
                cursor: g.cursor as u64,
                max_priority: g.max_priority,
                priorities,
                sample_counts: self.remover.counts_snapshot(len),
                rows,
            }],
        })
    }

    fn remover(&self) -> RemoverSpec {
        self.remover.spec()
    }

    fn note_sampled(&self, indices: &[usize]) {
        self.remover.note_sampled(indices);
    }

    fn max_sample_count(&self) -> u32 {
        self.remover.max_count(self.len())
    }

    fn validate_state(&self, state: &BufferState) -> Result<()> {
        state.check_header(
            self.name(),
            self.capacity,
            self.store.obs_dim(),
            self.store.act_dim(),
            1,
        )?;
        state.shards[0].validate(
            self.name(),
            self.capacity,
            self.store.obs_dim(),
            self.store.act_dim(),
        )
    }

    fn restore_state(&self, state: &BufferState) -> Result<()> {
        self.validate_state(state)?;
        let s = &state.shards[0];
        let mut g = self.inner.lock().unwrap();
        for (i, row) in s.rows.iter().enumerate() {
            self.store.write(i, row);
        }
        g.tree.assign(&s.priorities);
        g.cursor = s.cursor as usize;
        g.max_priority = s.max_priority.max(f32::MIN_POSITIVE);
        self.remover.restore_counts(&s.sample_counts);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_tree_prefix_sum_oracle() {
        let mut t = BinarySumTree::new(100);
        let prios: Vec<f32> = (0..100).map(|i| (i % 7) as f32 + 0.5).collect();
        for (i, &p) in prios.iter().enumerate() {
            t.update(i, p);
        }
        let total: f32 = prios.iter().sum();
        assert!((t.total() - total).abs() < 1e-3);
        for k in 0..200 {
            let x = (k as f32 / 200.0) * total;
            let (idx, p) = t.prefix_sum_index(x);
            assert!(p > 0.0);
            let mut acc = 0.0;
            let mut expect = 99;
            for (i, &q) in prios.iter().enumerate() {
                acc += q;
                if acc >= x {
                    expect = i;
                    break;
                }
            }
            assert!(
                (idx as i64 - expect as i64).abs() <= 1,
                "x={x} idx={idx} expect={expect}"
            );
        }
    }

    #[test]
    fn buffer_basic_flow() {
        let b = GlobalLockReplay::new(64, 2, 1, 0.6, 0.4);
        for i in 0..32 {
            b.insert(&Transition {
                obs: vec![i as f32, 0.0],
                action: vec![0.0],
                next_obs: vec![i as f32 + 1.0, 0.0],
                reward: i as f32,
                done: false,
            });
        }
        assert_eq!(b.len(), 32);
        let mut rng = Rng::new(1);
        let mut out = SampleBatch::default();
        assert!(b.sample(8, &mut rng, &mut out));
        assert_eq!(out.len(), 8);
        b.update_priorities(&out.indices.clone(), &vec![0.5; 8]);
    }

    #[test]
    fn lowest_priority_scan_picks_argmin_leaf() {
        let tr = |v: f32| Transition {
            obs: vec![v, 0.0],
            action: vec![0.0],
            next_obs: vec![0.0, 0.0],
            reward: v,
            done: false,
        };
        let b = GlobalLockReplay::with_remover(4, 2, 1, 0.6, 0.4, RemoverSpec::LowestPriority);
        assert_eq!(b.remover(), RemoverSpec::LowestPriority);
        for i in 0..4 {
            assert_eq!(b.insert(&tr(i as f32)), None);
        }
        // Give slot 2 the smallest priority, then slot 0 the next-smallest.
        b.update_priorities(&[0, 1, 2, 3], &[1.0, 5.0, 0.1, 3.0]);
        assert_eq!(
            b.insert(&tr(10.0)),
            Some(EvictReason::LowestPriority),
            "full buffer must evict"
        );
        assert_eq!(b.store.read(2).reward, 10.0);
        // The fresh row re-entered at max priority, so slot 0 is now the min.
        assert_eq!(b.insert(&tr(11.0)), Some(EvictReason::LowestPriority));
        assert_eq!(b.store.read(0).reward, 11.0);
    }
}
