//! Sharded prioritized replay: S independent K-ary sum-tree shards.
//!
//! The single-tree [`PrioritizedReplay`] implements the paper's two-lock
//! protocol, but every insert, sample and priority update still funnels
//! through ONE `global_tree_lock` — the first serialization point to
//! saturate as actors and learners multiply. This wrapper composes `S`
//! complete shard primitives (each with its own tree, storage segment,
//! lock pair, write cursor and [`LockStats`]) so concurrent workers hit
//! disjoint locks:
//!
//! * **Insert routing** — actor affinity: actor `a` writes shard
//!   `a % S` ([`ReplayBuffer::insert_from`]), so the common case of A
//!   concurrent actors takes A disjoint lock pairs. Anonymous inserts
//!   round-robin.
//! * **Two-level sampling** — level 1 picks the shard for each stratum
//!   draw proportional to its root total via a lock-free S-way prefix
//!   scan over the atomic roots (a root read is one relaxed atomic
//!   load); level 2 runs all of a shard's stratified descents under ONE
//!   acquisition of that shard's global lock
//!   ([`PrioritizedReplay::descend_batch`]). A transition's overall
//!   sampling probability stays proportional to its priority:
//!   P(shard) · P(leaf | shard) = (T_s / T) · (p_i / T_s) = p_i / T.
//! * **Batched priority feedback** —
//!   [`Self::update_priorities_batched`] groups `(index, |TD|)` pairs by
//!   shard and applies each group under a single global+leaf acquisition
//!   pair ([`PrioritizedReplay::update_transformed_batch`]): one lock
//!   acquisition per *shard touched* per batch instead of one per index.
//!
//! Global leaf index `g` maps to shard `g / shard_capacity`, local slot
//! `g % shard_capacity`; sampled indices are global, so learners feed
//! TD errors back with no API change.

use super::prioritized::{LockStatsSnapshot, PrioritizedConfig, PrioritizedReplay};
use super::remover::{EvictReason, RemoverSpec};
use super::snapshot::BufferState;
use super::storage::{SampleBatch, Transition};
use super::ReplayBuffer;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// S independent prioritized shards behind the [`ReplayBuffer`] trait.
pub struct ShardedPrioritizedReplay {
    shards: Vec<PrioritizedReplay>,
    shard_capacity: usize,
    beta: f32,
    /// Round-robin cursor for inserts without an actor id.
    round_robin: AtomicUsize,
    /// Wrapper-level sample-op counter (one per [`ReplayBuffer::sample`]
    /// call, like the single-tree buffer — the per-shard descents under
    /// one sample would otherwise inflate the merged count up to S-fold).
    samples: AtomicU64,
}

impl ShardedPrioritizedReplay {
    /// Build from a [`PrioritizedConfig`]; `cfg.shards` sub-trees share
    /// `cfg.capacity` evenly (rounded up, so the effective capacity is
    /// `ceil(capacity / S) * S`).
    pub fn new(cfg: PrioritizedConfig) -> Self {
        Self::with_remover(cfg, RemoverSpec::Fifo)
    }

    /// Build with an explicit eviction policy, applied per shard (each
    /// shard primitive evicts within its own slot range).
    pub fn with_remover(cfg: PrioritizedConfig, remove: RemoverSpec) -> Self {
        let s = cfg.shards.max(1);
        assert!(
            cfg.capacity > s,
            "capacity {} too small for {s} shards",
            cfg.capacity
        );
        let shard_capacity = cfg.capacity.div_ceil(s);
        let shards = (0..s)
            .map(|_| {
                PrioritizedReplay::with_remover(
                    PrioritizedConfig {
                        capacity: shard_capacity,
                        shards: 1,
                        ..cfg.clone()
                    },
                    remove,
                )
            })
            .collect();
        Self {
            shards,
            shard_capacity,
            beta: cfg.beta,
            round_robin: AtomicUsize::new(0),
            samples: AtomicU64::new(0),
        }
    }

    /// Number of shards S.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Leaf capacity of each shard.
    pub fn shard_capacity(&self) -> usize {
        self.shard_capacity
    }

    /// Direct access to one shard (tests / benches / stats).
    pub fn shard(&self, s: usize) -> &PrioritizedReplay {
        &self.shards[s]
    }

    /// Enable hold-time timing on every shard's [`LockStats`].
    pub fn enable_timing(&self) {
        for s in &self.shards {
            s.stats.enable_timing();
        }
    }

    /// Merged snapshot: field-wise sum of every shard's [`LockStats`],
    /// plus the wrapper-level sample-op count (shards do not count their
    /// descents as samples — see [`PrioritizedReplay::descend_batch`]).
    pub fn merged_stats(&self) -> LockStatsSnapshot {
        let mut m = LockStatsSnapshot::default();
        for s in &self.shards {
            m.accumulate(&s.stats.snapshot());
        }
        m.samples += self.samples.load(Ordering::Relaxed);
        m
    }

    /// Σ of all priorities across shards (S relaxed root reads, no lock).
    pub fn total_priority(&self) -> f32 {
        self.shards.iter().map(|s| s.total_priority()).sum()
    }

    /// Max running priority across shards.
    pub fn max_priority(&self) -> f32 {
        self.shards
            .iter()
            .map(|s| s.max_priority())
            .fold(0.0f32, f32::max)
    }

    /// Squash fp drift in every shard (takes each shard's locks in turn).
    pub fn rebuild_trees(&self) {
        for s in &self.shards {
            s.rebuild_tree();
        }
    }

    /// Worst per-shard tree invariant error (diagnostics / tests).
    pub fn invariant_error(&self) -> f32 {
        self.shards
            .iter()
            .map(|s| s.tree().invariant_error())
            .fold(0.0f32, f32::max)
    }

    #[inline]
    fn shard_of(&self, global_idx: usize) -> (usize, usize) {
        (
            global_idx / self.shard_capacity,
            global_idx % self.shard_capacity,
        )
    }

    /// The new batched priority-feedback API: group `(global index,
    /// |TD|)` pairs by shard, then apply each group under one lock
    /// acquisition pair on its shard.
    pub fn update_priorities_batched(&self, pairs: &[(usize, f32)]) {
        self.update_grouped(pairs.iter().copied());
    }

    /// Shared grouping core for the batched update paths (avoids the
    /// intermediate pair Vec on the trait route).
    fn update_grouped(&self, pairs: impl Iterator<Item = (usize, f32)>) {
        let s_count = self.shards.len();
        let mut buckets: Vec<Vec<(usize, f32)>> = vec![Vec::new(); s_count];
        for (idx, td) in pairs {
            let (s, local) = self.shard_of(idx);
            // Match the single-tree buffer, which panics on an
            // out-of-bounds leaf index — never silently drop feedback.
            assert!(s < s_count, "priority index {idx} out of range");
            buckets[s].push((local, self.shards[s].transform_priority(td)));
        }
        for (s, bucket) in buckets.iter().enumerate() {
            if !bucket.is_empty() {
                self.shards[s].update_transformed_batch(bucket);
            }
        }
    }
}

impl ReplayBuffer for ShardedPrioritizedReplay {
    fn name(&self) -> &'static str {
        "pal-sharded"
    }

    fn capacity(&self) -> usize {
        self.shards.len() * self.shard_capacity
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    /// Anonymous insert: round-robin over shards (keeps single-producer
    /// callers load-balanced) — overriding the trait's actor-0 default,
    /// which would pile every unattributed insert onto shard 0. Actor
    /// loops use [`Self::insert_from`].
    fn insert(&self, t: &Transition) -> Option<EvictReason> {
        let s = self.round_robin.fetch_add(1, Ordering::Relaxed) % self.shards.len();
        self.shards[s].insert(t)
    }

    /// Actor-affinity routing: actor `a` always writes shard `a % S`, so
    /// concurrent actors take disjoint lock pairs.
    fn insert_from(&self, actor_id: usize, t: &Transition) -> Option<EvictReason> {
        let s = actor_id % self.shards.len();
        self.shards[s].insert_from(actor_id, t)
    }

    /// State-merge insert: same affinity routing, with the carried
    /// priority forwarded to the shard primitive.
    fn insert_with_priority(
        &self,
        actor_id: usize,
        t: &Transition,
        priority: f32,
    ) -> Option<EvictReason> {
        let s = actor_id % self.shards.len();
        self.shards[s].insert_with_priority(actor_id, t, priority)
    }

    fn total_priority(&self) -> f32 {
        ShardedPrioritizedReplay::total_priority(self)
    }

    fn remover(&self) -> RemoverSpec {
        self.shards[0].remover()
    }

    /// Route global sampled indices back to their shard's counts.
    fn note_sampled(&self, indices: &[usize]) {
        for &g in indices {
            let (s, local) = self.shard_of(g);
            self.shards[s].note_sampled(&[local]);
        }
    }

    fn max_sample_count(&self) -> u32 {
        self.shards.iter().map(|s| s.max_sample_count()).max().unwrap_or(0)
    }

    /// Two-level stratified sampling (see module docs). Returns `true`
    /// only with a full batch; all row copies run outside every lock.
    fn sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> bool {
        self.samples.fetch_add(1, Ordering::Relaxed);
        out.clear();
        if batch == 0 {
            return false;
        }
        let n_total = self.len();
        if n_total == 0 {
            return false;
        }
        let s_count = self.shards.len();
        // Level 1: lock-free prefix scan over the atomic shard roots.
        let totals: Vec<f32> = self.shards.iter().map(|s| s.total_priority()).collect();
        let total: f32 = totals.iter().sum();
        if !(total > 0.0) {
            return false;
        }
        // Stratified draws over the GLOBAL priority mass, bucketed by the
        // shard whose root interval contains each draw. Skipping
        // zero-total shards while tracking the last positive one mirrors
        // the in-shard descent's never-sample-zero guarantee.
        let seg = total / batch as f32;
        let mut buckets: Vec<Vec<f32>> = vec![Vec::new(); s_count];
        for j in 0..batch {
            let x = (j as f32 + rng.f32()) * seg;
            let mut sel = usize::MAX;
            let mut sel_before = 0.0f32;
            let mut acc = 0.0f32;
            for (k, &t) in totals.iter().enumerate() {
                if t > 0.0 {
                    sel = k;
                    sel_before = acc;
                    if acc + t >= x {
                        break;
                    }
                }
                acc += t;
            }
            if sel == usize::MAX {
                return false; // unreachable: total > 0 implies a positive shard
            }
            buckets[sel].push(x - sel_before);
        }
        // Level 2: per selected shard, ONE lock acquisition runs all of
        // that shard's descents.
        let mut retry: Vec<f32> = Vec::new();
        for (s, bucket) in buckets.iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let start = out.indices.len();
            if self.shards[s].descend_batch(bucket, &mut out.indices, &mut out.priorities) {
                for idx in &mut out.indices[start..] {
                    *idx += s * self.shard_capacity; // local → global
                }
            } else {
                // The shard drained between the lock-free scan and the
                // lock (benign race with in-flight lazy inserts): re-aim
                // these strata at the currently heaviest shard.
                retry.extend_from_slice(bucket);
            }
        }
        if !retry.is_empty() {
            let heaviest = self
                .shards
                .iter()
                .enumerate()
                .filter(|(_, s)| s.total_priority() > 0.0)
                .max_by(|a, b| {
                    a.1.total_priority()
                        .partial_cmp(&b.1.total_priority())
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .map(|(k, _)| k);
            let Some(s) = heaviest else {
                out.clear();
                return false;
            };
            let start = out.indices.len();
            // Out-of-range prefixes clamp to the shard's last positive
            // leaf inside the descent.
            if !self.shards[s].descend_batch(&retry, &mut out.indices, &mut out.priorities) {
                out.clear();
                return false;
            }
            for idx in &mut out.indices[start..] {
                *idx += s * self.shard_capacity;
            }
        }
        // Importance weights: the single-tree formula with the merged
        // total and merged length (shared helper — see fill_is_weights).
        super::fill_is_weights(out, n_total as f32, total, self.beta);
        // Row copies outside all locks (lazy-writing guarantee per shard).
        for i in 0..out.indices.len() {
            let (s, local) = self.shard_of(out.indices[i]);
            self.shards[s].copy_row_into(local, out);
        }
        true
    }

    /// Trait-level priority feedback routes through the batched grouping
    /// core directly (no intermediate pair Vec).
    fn update_priorities(&self, indices: &[usize], td_abs: &[f32]) {
        debug_assert_eq!(indices.len(), td_abs.len());
        self.update_grouped(indices.iter().copied().zip(td_abs.iter().copied()));
    }

    /// One [`super::ShardState`] per shard, each captured under that
    /// shard's lock pair, so the per-shard slot layout created by
    /// actor-affinity routing survives the round trip exactly.
    fn snapshot_state(&self) -> Option<BufferState> {
        let (obs_dim, act_dim) = self.shards[0].dims();
        Some(BufferState {
            impl_name: self.name().to_string(),
            capacity: self.capacity(),
            obs_dim,
            act_dim,
            shards: self.shards.iter().map(PrioritizedReplay::snapshot_shard).collect(),
        })
    }

    /// Validates EVERY shard before anything mutates, so a corrupt
    /// shard entry can never leave the buffer half-restored.
    fn validate_state(&self, state: &BufferState) -> Result<()> {
        let (obs_dim, act_dim) = self.shards[0].dims();
        state.check_header(
            self.name(),
            self.capacity(),
            obs_dim,
            act_dim,
            self.shards.len(),
        )?;
        for (s, shard_state) in self.shards.iter().zip(&state.shards) {
            s.validate_shard(shard_state)?;
        }
        Ok(())
    }

    fn restore_state(&self, state: &BufferState) -> Result<()> {
        self.validate_state(state)?;
        for (s, shard_state) in self.shards.iter().zip(&state.shards) {
            s.apply_shard(shard_state);
        }
        // Anonymous round-robin inserts restart from shard 0; affinity
        // routing (`insert_from`) is position-independent either way.
        self.round_robin.store(0, Ordering::Relaxed);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(capacity: usize, fanout: usize, shards: usize) -> PrioritizedConfig {
        PrioritizedConfig {
            capacity,
            obs_dim: 3,
            act_dim: 2,
            fanout,
            alpha: 0.6,
            beta: 0.4,
            lazy_writing: true,
            shards,
        }
    }

    fn mk(capacity: usize, fanout: usize, shards: usize) -> ShardedPrioritizedReplay {
        ShardedPrioritizedReplay::new(cfg(capacity, fanout, shards))
    }

    fn tr(v: f32) -> Transition {
        Transition {
            obs: vec![v; 3],
            action: vec![v; 2],
            next_obs: vec![v + 1.0; 3],
            reward: v,
            done: false,
        }
    }

    #[test]
    fn capacity_splits_evenly_and_rounds_up() {
        let b = mk(128, 16, 4);
        assert_eq!(b.shard_count(), 4);
        assert_eq!(b.shard_capacity(), 32);
        assert_eq!(b.capacity(), 128);
        let odd = mk(100, 16, 3);
        assert_eq!(odd.shard_capacity(), 34);
        assert_eq!(odd.capacity(), 102);
    }

    #[test]
    fn actor_affinity_routes_to_disjoint_shards() {
        let b = mk(64, 16, 4);
        for a in 0..4 {
            for i in 0..5 {
                b.insert_from(a, &tr((a * 100 + i) as f32));
            }
        }
        for s in 0..4 {
            assert_eq!(b.shard(s).len(), 5, "shard {s}");
            assert_eq!(b.shard(s).stats.snapshot().inserts, 5);
        }
        assert_eq!(b.len(), 20);
    }

    #[test]
    fn round_robin_insert_balances_shards() {
        let b = mk(64, 16, 4);
        for i in 0..32 {
            b.insert(&tr(i as f32));
        }
        for s in 0..4 {
            assert_eq!(b.shard(s).len(), 8, "shard {s}");
        }
    }

    #[test]
    fn sample_returns_full_consistent_batch() {
        let b = mk(128, 16, 4);
        for i in 0..96 {
            b.insert(&tr(i as f32));
        }
        let mut rng = Rng::new(1);
        let mut out = SampleBatch::with_capacity(32, 3, 2);
        assert!(b.sample(32, &mut rng, &mut out));
        assert_eq!(out.len(), 32);
        assert_eq!(out.obs.len(), 32 * 3);
        assert_eq!(out.is_weights.len(), 32);
        for (j, &idx) in out.indices.iter().enumerate() {
            assert!(idx < b.capacity());
            assert!(out.priorities[j] > 0.0);
            // Row self-consistency: obs[0] == reward by construction.
            assert_eq!(out.obs[j * 3], out.reward[j]);
            assert!(out.is_weights[j] > 0.0 && out.is_weights[j] <= 1.0 + 1e-6);
        }
    }

    #[test]
    fn empty_and_partial_shard_sampling() {
        let b = mk(64, 16, 4);
        let mut rng = Rng::new(2);
        let mut out = SampleBatch::default();
        assert!(!b.sample(8, &mut rng, &mut out));
        // Only actor 2's shard has data; sampling must still work.
        for i in 0..10 {
            b.insert_from(2, &tr(i as f32));
        }
        assert!(b.sample(8, &mut rng, &mut out));
        assert_eq!(out.len(), 8);
        for &idx in &out.indices {
            let shard = idx / b.shard_capacity();
            assert_eq!(shard, 2, "index {idx} not in shard 2");
        }
    }

    #[test]
    fn batched_update_takes_one_lock_pair_per_shard() {
        let b = mk(64, 16, 4);
        for i in 0..64 {
            b.insert(&tr(i as f32));
        }
        let before = b.merged_stats();
        // 64 updates spanning all 4 shards.
        let idx: Vec<usize> = (0..64).collect();
        let tds: Vec<f32> = (0..64).map(|i| 0.1 + i as f32).collect();
        b.update_priorities(&idx, &tds);
        let after = b.merged_stats();
        assert_eq!(after.updates - before.updates, 64);
        // One global + one leaf acquisition per shard touched — not 64.
        assert_eq!(after.global_acquisitions - before.global_acquisitions, 4);
        assert_eq!(after.leaf_acquisitions - before.leaf_acquisitions, 4);
        // Priorities landed on the right shard-local leaves.
        for g in 0..64usize {
            let (s, local) = (g / b.shard_capacity(), g % b.shard_capacity());
            let expect = b.shard(s).transform_priority(tds[g]);
            assert!((b.shard(s).get_priority(local) - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn priority_update_biases_two_level_sampling() {
        let b = mk(64, 16, 4);
        for i in 0..64 {
            b.insert(&tr(i as f32));
        }
        // Give global index 37 (shard 2) overwhelming priority.
        let idx: Vec<usize> = (0..64).collect();
        let mut tds = vec![0.001f32; 64];
        tds[37] = 1000.0;
        b.update_priorities(&idx, &tds);
        let mut rng = Rng::new(3);
        let mut out = SampleBatch::default();
        let mut hits = 0;
        for _ in 0..50 {
            b.sample(8, &mut rng, &mut out);
            hits += out.indices.iter().filter(|&&i| i == 37).count();
        }
        assert!(hits > 300, "index 37 sampled only {hits}/400 times");
    }

    /// Acceptance: the two-level scheme still samples every transition
    /// with probability proportional to its priority, within the same
    /// tolerance as `sampling_distribution_proportional_to_priority` in
    /// the sum-tree tests (|got − expect| < 0.01).
    #[test]
    fn two_level_sampling_distribution_proportional_to_priority() {
        let n = 16usize;
        let b = mk(n, 16, 4);
        for i in 0..n {
            b.insert(&tr(i as f32));
        }
        // Deterministic priorities on the global leaves: p(g) ∝ g + 1.
        let idx: Vec<usize> = (0..n).collect();
        let tds: Vec<f32> = (0..n).map(|g| (g + 1) as f32).collect();
        b.update_priorities(&idx, &tds);
        // Expected distribution from the actual transformed priorities.
        let probs: Vec<f64> = (0..n)
            .map(|g| {
                let (s, local) = (g / b.shard_capacity(), g % b.shard_capacity());
                b.shard(s).get_priority(local) as f64
            })
            .collect();
        let total: f64 = probs.iter().sum();
        let mut rng = Rng::new(123);
        let mut out = SampleBatch::default();
        let rounds = 12_500;
        let batch = 16;
        let mut counts = vec![0usize; n];
        for _ in 0..rounds {
            assert!(b.sample(batch, &mut rng, &mut out));
            for &g in &out.indices {
                counts[g] += 1;
            }
        }
        let trials = (rounds * batch) as f64;
        for g in 0..n {
            let expect = probs[g] / total;
            let got = counts[g] as f64 / trials;
            assert!(
                (got - expect).abs() < 0.01,
                "leaf {g}: got {got:.4} expect {expect:.4}"
            );
        }
    }

    #[test]
    fn merged_stats_equal_sum_of_shard_snapshots() {
        let b = mk(64, 16, 4);
        b.enable_timing();
        for a in 0..8 {
            for i in 0..8 {
                b.insert_from(a, &tr((a * 8 + i) as f32));
            }
        }
        let mut rng = Rng::new(5);
        let mut out = SampleBatch::default();
        for _ in 0..10 {
            b.sample(16, &mut rng, &mut out);
            let idx = out.indices.clone();
            b.update_priorities(&idx, &vec![0.5; idx.len()]);
        }
        let merged = b.merged_stats();
        let mut manual = LockStatsSnapshot::default();
        for s in 0..b.shard_count() {
            manual.accumulate(&b.shard(s).stats.snapshot());
        }
        assert_eq!(merged.inserts, 64);
        assert_eq!(merged.inserts, manual.inserts);
        assert_eq!(merged.updates, manual.updates);
        assert_eq!(merged.global_acquisitions, manual.global_acquisitions);
        assert_eq!(merged.leaf_acquisitions, manual.leaf_acquisitions);
        // One sample op per wrapper sample() call (shards count none).
        assert_eq!(merged.samples, 10);
        assert_eq!(manual.samples, 0);
        assert!(merged.storage_copy_ns > 0);
    }

    #[test]
    fn shard_count_one_degenerates_to_single_tree() {
        let b = mk(32, 16, 1);
        assert_eq!(b.shard_count(), 1);
        assert_eq!(b.capacity(), 32);
        for i in 0..32 {
            b.insert(&tr(i as f32));
        }
        let mut rng = Rng::new(6);
        let mut out = SampleBatch::default();
        assert!(b.sample(16, &mut rng, &mut out));
        assert_eq!(out.len(), 16);
    }
}
