//! Emulated third-party replay buffers for the Fig 11 plug-in experiment.
//!
//! The paper plugs its C++ buffer into tianshou (CPython-extension
//! buffer), PFRL and rlpyt (pure-Python buffers) and reports 1.1x–2.1x
//! end-to-end speedups. We cannot run those Python frameworks on the
//! request path, so we emulate the *structural* costs of their buffer
//! implementations in Rust:
//!
//! * [`NaiveScanReplay`] — "pure Python" style (PFRL / rlpyt): priorities
//!   live behind one heap indirection each (emulating PyObject boxing /
//!   pointer chasing) and sampling does an O(N) cumulative scan, which is
//!   what a numpy-free Python implementation effectively does.
//! * [`PyBindBinaryReplay`] — "CPython extension" style (tianshou): a
//!   proper binary sum tree, but every public operation pays a fixed
//!   binding-crossing overhead (argument boxing/unboxing emulated by a
//!   calibrated pointer-chase), and the tree is the unaligned textbook
//!   layout.
//!
//! The constants are documented and deliberately conservative; the Fig 11
//! bench reports its speedups relative to these emulations.

use super::baseline::BinarySumTree;
use super::remover::{EvictReason, Remover, RemoverSpec};
use super::storage::{SampleBatch, Transition, TransitionStore};
use super::ReplayBuffer;
use crate::util::rng::Rng;
use std::sync::Mutex;

/// Shared victim selection for the emulated buffers. `cur` is the
/// pre-increment monotone cursor; `prio` reads a slot's current priority
/// (caller holds the buffer's mutex, so the read is consistent).
fn pick_victim(
    remover: &Remover,
    capacity: usize,
    cur: usize,
    prio: impl Fn(usize) -> f64,
) -> (usize, Option<EvictReason>) {
    if cur < capacity {
        return (cur, None);
    }
    match remover.spec() {
        RemoverSpec::Fifo => (cur % capacity, Some(EvictReason::Fifo)),
        RemoverSpec::Lifo => (capacity - 1, Some(EvictReason::Lifo)),
        RemoverSpec::LowestPriority => {
            // O(N) argmin; ties -> first (oldest slot).
            let mut best = 0usize;
            let mut best_p = f64::INFINITY;
            for i in 0..capacity {
                let p = prio(i);
                if p < best_p {
                    best_p = p;
                    best = i;
                }
            }
            (best, Some(EvictReason::LowestPriority))
        }
        RemoverSpec::MaxTimesSampled(_) => match remover.pick_ripe() {
            Some(slot) => (slot, Some(EvictReason::MaxSampled)),
            None => (cur % capacity, Some(EvictReason::Fifo)),
        },
    }
}

/// Number of dependent pointer hops emulating one Python→C crossing
/// (attribute lookups, arg tuple unpack, refcount traffic). ~6 random-ish
/// L1/L2 loads ≈ 30–60 ns, a conservative stand-in for the µs-scale real
/// CPython overhead — so measured speedups are a *lower* bound.
const BINDING_HOPS: usize = 6;

/// A chunk of memory used to emulate interpreter pointer-chasing.
struct ChaseArena {
    next: Vec<u32>,
    cursor: std::cell::Cell<u32>,
}

// The arena is only touched under the owning buffer's mutex.
unsafe impl Sync for ChaseArena {}

impl ChaseArena {
    fn new(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut next: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut next);
        Self { next, cursor: std::cell::Cell::new(0) }
    }

    #[inline]
    fn chase(&self, hops: usize) {
        let mut c = self.cursor.get();
        for _ in 0..hops {
            c = self.next[c as usize % self.next.len()];
        }
        self.cursor.set(c);
    }
}

struct NaiveInner {
    /// One heap box per priority — emulates PyFloat objects.
    priorities: Vec<Box<f64>>,
    cursor: usize,
    max_priority: f64,
}

/// "Pure Python"-style buffer: boxed priorities + O(N) scan sampling.
pub struct NaiveScanReplay {
    inner: Mutex<NaiveInner>,
    store: TransitionStore,
    capacity: usize,
    alpha: f32,
    beta: f32,
    remover: Remover,
}

impl NaiveScanReplay {
    pub fn new(capacity: usize, obs_dim: usize, act_dim: usize, alpha: f32, beta: f32) -> Self {
        Self::with_remover(capacity, obs_dim, act_dim, alpha, beta, RemoverSpec::Fifo)
    }

    /// Build with an explicit eviction policy.
    pub fn with_remover(
        capacity: usize,
        obs_dim: usize,
        act_dim: usize,
        alpha: f32,
        beta: f32,
        remove: RemoverSpec,
    ) -> Self {
        Self {
            inner: Mutex::new(NaiveInner {
                priorities: (0..capacity).map(|_| Box::new(0.0)).collect(),
                cursor: 0,
                max_priority: 1.0,
            }),
            store: TransitionStore::new(capacity, obs_dim, act_dim),
            capacity,
            alpha,
            beta,
            remover: Remover::new(remove, capacity),
        }
    }
}

impl ReplayBuffer for NaiveScanReplay {
    fn name(&self) -> &'static str {
        "emulated-pure-python"
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().cursor.min(self.capacity)
    }

    fn insert_from(&self, _actor_id: usize, t: &Transition) -> Option<EvictReason> {
        let mut g = self.inner.lock().unwrap();
        let cur = g.cursor;
        g.cursor += 1;
        let (slot, reason) =
            pick_victim(&self.remover, self.capacity, cur, |i| *g.priorities[i]);
        self.store.write(slot, t);
        self.remover.on_insert(slot);
        let mp = g.max_priority;
        *g.priorities[slot] = mp;
        reason
    }

    fn sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> bool {
        out.clear();
        let g = self.inner.lock().unwrap();
        let n = g.cursor.min(self.capacity);
        if n == 0 || batch == 0 {
            return false;
        }
        // O(N) boxed total, then O(N) scan per draw — the naive structure.
        let total: f64 = g.priorities[..n].iter().map(|p| **p).sum();
        if !(total > 0.0) {
            return false;
        }
        for _ in 0..batch {
            let x = rng.f64() * total;
            let mut acc = 0.0;
            let mut idx = n - 1;
            for (i, p) in g.priorities[..n].iter().enumerate() {
                acc += **p;
                if acc >= x && **p > 0.0 {
                    idx = i;
                    break;
                }
            }
            out.indices.push(idx);
            out.priorities.push(*g.priorities[idx] as f32);
        }
        let nf = n as f32;
        let mut wmax = 0.0f32;
        for &p in &out.priorities {
            let pr = (p as f64 / total).max(1e-30) as f32;
            let w = (nf * pr).powf(-self.beta);
            out.is_weights.push(w);
            wmax = wmax.max(w);
        }
        for w in &mut out.is_weights {
            *w /= wmax;
        }
        for i in 0..out.indices.len() {
            self.store.read_into(out.indices[i], out);
        }
        true
    }

    fn update_priorities(&self, indices: &[usize], td_abs: &[f32]) {
        let mut g = self.inner.lock().unwrap();
        for (&idx, &td) in indices.iter().zip(td_abs) {
            let p =
                ((td.max(0.0) + super::prioritized::PRIORITY_EPS) as f64).powf(self.alpha as f64);
            if p > g.max_priority {
                g.max_priority = p;
            }
            *g.priorities[idx] = p;
        }
    }

    fn remover(&self) -> RemoverSpec {
        self.remover.spec()
    }

    fn note_sampled(&self, indices: &[usize]) {
        self.remover.note_sampled(indices);
    }

    fn max_sample_count(&self) -> u32 {
        self.remover.max_count(self.len())
    }
}

struct BindInner {
    tree: BinarySumTree,
    cursor: usize,
    max_priority: f32,
}

/// "CPython extension"-style buffer: real binary sum tree + per-call
/// binding overhead.
pub struct PyBindBinaryReplay {
    inner: Mutex<BindInner>,
    arena: ChaseArena,
    store: TransitionStore,
    capacity: usize,
    alpha: f32,
    beta: f32,
    remover: Remover,
}

impl PyBindBinaryReplay {
    pub fn new(capacity: usize, obs_dim: usize, act_dim: usize, alpha: f32, beta: f32) -> Self {
        Self::with_remover(capacity, obs_dim, act_dim, alpha, beta, RemoverSpec::Fifo)
    }

    /// Build with an explicit eviction policy.
    pub fn with_remover(
        capacity: usize,
        obs_dim: usize,
        act_dim: usize,
        alpha: f32,
        beta: f32,
        remove: RemoverSpec,
    ) -> Self {
        Self {
            inner: Mutex::new(BindInner {
                tree: BinarySumTree::new(capacity),
                cursor: 0,
                max_priority: 1.0,
            }),
            arena: ChaseArena::new(1 << 16, 0xBEEF),
            store: TransitionStore::new(capacity, obs_dim, act_dim),
            capacity,
            alpha,
            beta,
            remover: Remover::new(remove, capacity),
        }
    }
}

impl ReplayBuffer for PyBindBinaryReplay {
    fn name(&self) -> &'static str {
        "emulated-cpython-binding"
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().cursor.min(self.capacity)
    }

    fn insert_from(&self, _actor_id: usize, t: &Transition) -> Option<EvictReason> {
        let mut g = self.inner.lock().unwrap();
        self.arena.chase(BINDING_HOPS);
        let cur = g.cursor;
        g.cursor += 1;
        let (slot, reason) =
            pick_victim(&self.remover, self.capacity, cur, |i| g.tree.get(i) as f64);
        self.store.write(slot, t);
        self.remover.on_insert(slot);
        let mp = g.max_priority;
        g.tree.update(slot, mp);
        reason
    }

    fn sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> bool {
        out.clear();
        let g = self.inner.lock().unwrap();
        let n = g.cursor.min(self.capacity);
        if n == 0 || batch == 0 {
            return false;
        }
        let total = g.tree.total();
        if !(total > 0.0) {
            return false;
        }
        for _ in 0..batch {
            // Per-draw binding crossing (tianshou calls into the
            // extension once per sampled index).
            self.arena.chase(BINDING_HOPS);
            let x = rng.f32() * total;
            let (idx, p) = g.tree.prefix_sum_index(x);
            out.indices.push(idx);
            out.priorities.push(p);
        }
        let nf = n as f32;
        let mut wmax = 0.0f32;
        for &p in &out.priorities {
            let pr = (p / total).max(f32::MIN_POSITIVE);
            let w = (nf * pr).powf(-self.beta);
            out.is_weights.push(w);
            wmax = wmax.max(w);
        }
        for w in &mut out.is_weights {
            *w /= wmax;
        }
        for i in 0..out.indices.len() {
            self.store.read_into(out.indices[i], out);
        }
        true
    }

    fn update_priorities(&self, indices: &[usize], td_abs: &[f32]) {
        let mut g = self.inner.lock().unwrap();
        for (&idx, &td) in indices.iter().zip(td_abs) {
            self.arena.chase(BINDING_HOPS);
            let p = (td.max(0.0) + super::prioritized::PRIORITY_EPS).powf(self.alpha);
            if p > g.max_priority {
                g.max_priority = p;
            }
            g.tree.update(idx, p);
        }
    }

    fn remover(&self) -> RemoverSpec {
        self.remover.spec()
    }

    fn note_sampled(&self, indices: &[usize]) {
        self.remover.note_sampled(indices);
    }

    fn max_sample_count(&self) -> u32 {
        self.remover.max_count(self.len())
    }
}

struct PyTreeInner {
    tree: BinarySumTree,
    cursor: usize,
    max_priority: f32,
}

/// "Python sum-tree" buffer (PFRL / rlpyt style): the right O(log N)
/// algorithm, but every tree-node visit pays an interpreter-dispatch
/// emulation (pointer chase), the way a pure-Python `SumTree` class pays
/// attribute lookups and boxed arithmetic per node.
pub struct PySumTreeReplay {
    inner: Mutex<PyTreeInner>,
    arena: ChaseArena,
    store: TransitionStore,
    capacity: usize,
    alpha: f32,
    beta: f32,
    remover: Remover,
}

/// Pointer hops per simulated interpreter bytecode region. One visited
/// tree node in pure Python costs ~0.5–2 µs (LOAD_ATTR, BINARY_OP,
/// refcounts); 30 dependent hops ≈ 150–400 ns — again a conservative
/// lower bound.
const PY_NODE_HOPS: usize = 30;

impl PySumTreeReplay {
    pub fn new(capacity: usize, obs_dim: usize, act_dim: usize, alpha: f32, beta: f32) -> Self {
        Self::with_remover(capacity, obs_dim, act_dim, alpha, beta, RemoverSpec::Fifo)
    }

    /// Build with an explicit eviction policy.
    pub fn with_remover(
        capacity: usize,
        obs_dim: usize,
        act_dim: usize,
        alpha: f32,
        beta: f32,
        remove: RemoverSpec,
    ) -> Self {
        Self {
            inner: Mutex::new(PyTreeInner {
                tree: BinarySumTree::new(capacity),
                cursor: 0,
                max_priority: 1.0,
            }),
            arena: ChaseArena::new(1 << 16, 0xFACE),
            store: TransitionStore::new(capacity, obs_dim, act_dim),
            capacity,
            alpha,
            beta,
            remover: Remover::new(remove, capacity),
        }
    }

    fn tree_depth(&self) -> usize {
        self.capacity.next_power_of_two().trailing_zeros() as usize + 1
    }
}

impl ReplayBuffer for PySumTreeReplay {
    fn name(&self) -> &'static str {
        "emulated-python-sumtree"
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn len(&self) -> usize {
        self.inner.lock().unwrap().cursor.min(self.capacity)
    }

    fn insert_from(&self, _actor_id: usize, t: &Transition) -> Option<EvictReason> {
        let mut g = self.inner.lock().unwrap();
        // Update path: depth node visits, each interpreter-priced.
        self.arena.chase(PY_NODE_HOPS * self.tree_depth());
        let cur = g.cursor;
        g.cursor += 1;
        let (slot, reason) =
            pick_victim(&self.remover, self.capacity, cur, |i| g.tree.get(i) as f64);
        self.store.write(slot, t);
        self.remover.on_insert(slot);
        let mp = g.max_priority;
        g.tree.update(slot, mp);
        reason
    }

    fn sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> bool {
        out.clear();
        let g = self.inner.lock().unwrap();
        let n = g.cursor.min(self.capacity);
        if n == 0 || batch == 0 {
            return false;
        }
        let total = g.tree.total();
        if !(total > 0.0) {
            return false;
        }
        for _ in 0..batch {
            // Descent: depth node visits at interpreter prices.
            self.arena.chase(PY_NODE_HOPS * self.tree_depth());
            let x = rng.f32() * total;
            let (idx, p) = g.tree.prefix_sum_index(x);
            out.indices.push(idx);
            out.priorities.push(p);
        }
        let nf = n as f32;
        let mut wmax = 0.0f32;
        for &p in &out.priorities {
            let pr = (p / total).max(f32::MIN_POSITIVE);
            let w = (nf * pr).powf(-self.beta);
            out.is_weights.push(w);
            wmax = wmax.max(w);
        }
        for w in &mut out.is_weights {
            *w /= wmax;
        }
        for i in 0..out.indices.len() {
            self.store.read_into(out.indices[i], out);
        }
        true
    }

    fn update_priorities(&self, indices: &[usize], td_abs: &[f32]) {
        let mut g = self.inner.lock().unwrap();
        for (&idx, &td) in indices.iter().zip(td_abs) {
            self.arena.chase(PY_NODE_HOPS * self.tree_depth());
            let p = (td.max(0.0) + super::prioritized::PRIORITY_EPS).powf(self.alpha);
            if p > g.max_priority {
                g.max_priority = p;
            }
            g.tree.update(idx, p);
        }
    }

    fn remover(&self) -> RemoverSpec {
        self.remover.spec()
    }

    fn note_sampled(&self, indices: &[usize]) {
        self.remover.note_sampled(indices);
    }

    fn max_sample_count(&self) -> u32 {
        self.remover.max_count(self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(v: f32) -> Transition {
        Transition {
            obs: vec![v, v],
            action: vec![v],
            next_obs: vec![v, v],
            reward: v,
            done: false,
        }
    }

    #[test]
    fn naive_scan_samples_proportionally() {
        let b = NaiveScanReplay::new(32, 2, 1, 1.0, 0.4);
        for i in 0..32 {
            b.insert(&tr(i as f32));
        }
        let idx: Vec<usize> = (0..32).collect();
        let mut tds = vec![0.0f32; 32];
        tds[9] = 100.0;
        b.update_priorities(&idx, &tds);
        let mut rng = Rng::new(2);
        let mut out = SampleBatch::default();
        let mut hits = 0;
        for _ in 0..40 {
            assert!(b.sample(8, &mut rng, &mut out));
            hits += out.indices.iter().filter(|&&i| i == 9).count();
        }
        assert!(hits > 250, "{hits}");
    }

    #[test]
    fn naive_scan_lowest_priority_evicts_boxed_argmin() {
        let b = NaiveScanReplay::with_remover(4, 2, 1, 1.0, 0.4, RemoverSpec::LowestPriority);
        assert_eq!(b.remover(), RemoverSpec::LowestPriority);
        for i in 0..4 {
            assert_eq!(b.insert(&tr(i as f32)), None);
        }
        b.update_priorities(&[0, 1, 2, 3], &[2.0, 0.5, 4.0, 3.0]);
        // Slot 1 holds the smallest boxed priority, so it's the victim.
        assert_eq!(b.insert(&tr(9.0)), Some(EvictReason::LowestPriority));
        assert_eq!(b.store.read(1).reward, 9.0);
    }

    #[test]
    fn pybind_binary_flow() {
        let b = PyBindBinaryReplay::new(64, 2, 1, 0.6, 0.4);
        for i in 0..64 {
            b.insert(&tr(i as f32));
        }
        let mut rng = Rng::new(3);
        let mut out = SampleBatch::default();
        assert!(b.sample(16, &mut rng, &mut out));
        assert_eq!(out.len(), 16);
        b.update_priorities(&out.indices.clone(), &vec![1.0; 16]);
    }
}
