//! Plain-data snapshot types for checkpointable replay buffers.
//!
//! A [`BufferState`] is everything a buffer needs to reproduce its
//! sampling behavior after a restart: per-shard ring contents in slot
//! order, leaf priorities, the monotone write cursor (so FIFO eviction
//! continues at the right slot) and the running max priority (so new
//! inserts arrive at the right priority). Interior sum-tree nodes are
//! deliberately NOT part of the state — restore rebuilds them from the
//! leaves ([`crate::replay::sumtree::KArySumTree::rebuild`]), so a
//! corrupted or stale interior sum can never be smuggled in from disk.
//!
//! Single-tree buffers are the `shards.len() == 1` special case; the
//! sharded buffer stores one [`ShardState`] per shard so actor-affinity
//! slot layout survives the round trip exactly.

use super::storage::Transition;
use anyhow::{bail, Result};

/// State of one shard: ring slots `0..len` plus cursor/max-priority.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardState {
    /// Monotone insertion counter (next slot = `cursor % capacity`).
    pub cursor: u64,
    /// Running max transformed priority (1.0 for non-prioritized rings).
    pub max_priority: f32,
    /// Leaf priorities of the occupied slots, in slot order.
    pub priorities: Vec<f32>,
    /// Times each occupied slot has been handed out by `try_sample`,
    /// in slot order (all zero for buffers without a
    /// `MaxTimesSampled` remover; legacy v1 checkpoints restore as
    /// zeros).
    pub sample_counts: Vec<u32>,
    /// Stored transitions of the occupied slots, in slot order.
    pub rows: Vec<Transition>,
}

impl ShardState {
    /// Occupied slot count.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Σ of the stored leaf priorities (f64 to keep the test-side
    /// comparison independent of summation order).
    pub fn total_priority(&self) -> f64 {
        self.priorities.iter().map(|&p| p as f64).sum()
    }

    /// Structural validation against a shard's geometry. `kind` names
    /// the buffer in error messages.
    pub fn validate(
        &self,
        kind: &str,
        capacity: usize,
        obs_dim: usize,
        act_dim: usize,
    ) -> Result<()> {
        if self.priorities.len() != self.rows.len() {
            bail!(
                "{kind}: shard state has {} priorities for {} rows",
                self.priorities.len(),
                self.rows.len()
            );
        }
        if self.sample_counts.len() != self.rows.len() {
            bail!(
                "{kind}: shard state has {} sample counts for {} rows",
                self.sample_counts.len(),
                self.rows.len()
            );
        }
        if self.rows.len() > capacity {
            bail!(
                "{kind}: shard state holds {} rows but the shard capacity is {capacity}",
                self.rows.len()
            );
        }
        let expect_len = (self.cursor as usize).min(capacity);
        if self.rows.len() != expect_len {
            bail!(
                "{kind}: shard cursor {} implies {} occupied slots, state has {}",
                self.cursor,
                expect_len,
                self.rows.len()
            );
        }
        if !self.max_priority.is_finite() || self.max_priority < 0.0 {
            bail!("{kind}: invalid max priority {}", self.max_priority);
        }
        for (i, p) in self.priorities.iter().enumerate() {
            if !p.is_finite() || *p < 0.0 {
                bail!("{kind}: invalid priority {p} at slot {i}");
            }
        }
        for (i, row) in self.rows.iter().enumerate() {
            if row.obs.len() != obs_dim
                || row.next_obs.len() != obs_dim
                || row.action.len() != act_dim
            {
                bail!(
                    "{kind}: row {i} dims obs={}/{} act={} do not match buffer dims \
                     obs={obs_dim} act={act_dim}",
                    row.obs.len(),
                    row.next_obs.len(),
                    row.action.len()
                );
            }
        }
        Ok(())
    }
}

/// Serializable state of one whole replay buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct BufferState {
    /// [`crate::replay::ReplayBuffer::name`] of the impl that captured
    /// the state; restore refuses a different implementation.
    pub impl_name: String,
    /// Total leaf capacity across shards.
    pub capacity: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    pub shards: Vec<ShardState>,
}

impl BufferState {
    /// Total occupied slots across shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(ShardState::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(ShardState::is_empty)
    }

    /// Σ of all stored leaf priorities across shards.
    pub fn total_priority(&self) -> f64 {
        self.shards.iter().map(ShardState::total_priority).sum()
    }

    /// Cheap cross-impl checks shared by every `validate_state` impl.
    pub fn check_header(
        &self,
        impl_name: &str,
        capacity: usize,
        obs_dim: usize,
        act_dim: usize,
        shard_count: usize,
    ) -> Result<()> {
        if self.impl_name != impl_name {
            bail!(
                "buffer state was captured from `{}` but this buffer is `{impl_name}`",
                self.impl_name
            );
        }
        if self.capacity != capacity {
            bail!(
                "{impl_name}: state capacity {} does not match buffer capacity {capacity}",
                self.capacity
            );
        }
        if self.obs_dim != obs_dim || self.act_dim != act_dim {
            bail!(
                "{impl_name}: state dims obs={} act={} do not match buffer dims \
                 obs={obs_dim} act={act_dim}",
                self.obs_dim,
                self.act_dim
            );
        }
        if self.shards.len() != shard_count {
            bail!(
                "{impl_name}: state has {} shards, buffer has {shard_count}",
                self.shards.len()
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: f32) -> Transition {
        Transition {
            obs: vec![v, v],
            action: vec![v],
            next_obs: vec![v, v],
            reward: v,
            done: false,
        }
    }

    fn shard(n: usize) -> ShardState {
        ShardState {
            cursor: n as u64,
            max_priority: 1.0,
            priorities: vec![0.5; n],
            sample_counts: vec![0; n],
            rows: (0..n).map(|i| row(i as f32)).collect(),
        }
    }

    #[test]
    fn validate_accepts_consistent_state() {
        assert!(shard(4).validate("test", 8, 2, 1).is_ok());
        // Wrapped cursor: 12 inserts into capacity 8 leaves 8 rows.
        let mut s = shard(8);
        s.cursor = 12;
        assert!(s.validate("test", 8, 2, 1).is_ok());
    }

    #[test]
    fn validate_rejects_each_inconsistency() {
        let mut s = shard(4);
        s.priorities.pop();
        assert!(s.validate("test", 8, 2, 1).is_err());

        let mut s = shard(4);
        s.sample_counts.pop();
        assert!(s.validate("test", 8, 2, 1).is_err());

        let s = shard(9);
        assert!(s.validate("test", 8, 2, 1).is_err());

        let mut s = shard(4);
        s.cursor = 7; // cursor says 7 rows, state has 4
        assert!(s.validate("test", 8, 2, 1).is_err());

        let mut s = shard(4);
        s.priorities[2] = f32::NAN;
        assert!(s.validate("test", 8, 2, 1).is_err());

        let mut s = shard(4);
        s.priorities[1] = -1.0;
        assert!(s.validate("test", 8, 2, 1).is_err());

        let mut s = shard(4);
        s.rows[3].obs.push(0.0);
        assert!(s.validate("test", 8, 2, 1).is_err());

        let s = shard(4);
        assert!(s.validate("test", 8, 3, 1).is_err());
    }

    #[test]
    fn buffer_state_header_checks() {
        let b = BufferState {
            impl_name: "pal-kary".into(),
            capacity: 8,
            obs_dim: 2,
            act_dim: 1,
            shards: vec![shard(4)],
        };
        assert!(b.check_header("pal-kary", 8, 2, 1, 1).is_ok());
        assert!(b.check_header("uniform-ring", 8, 2, 1, 1).is_err());
        assert!(b.check_header("pal-kary", 16, 2, 1, 1).is_err());
        assert!(b.check_header("pal-kary", 8, 3, 1, 1).is_err());
        assert!(b.check_header("pal-kary", 8, 2, 1, 2).is_err());
        assert_eq!(b.len(), 4);
        assert!((b.total_priority() - 2.0).abs() < 1e-9);
    }
}
