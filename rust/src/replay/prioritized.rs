//! Thread-safe prioritized replay buffer (paper §IV-D, Algorithm 3).
//!
//! Two locks synchronize the K-ary sum tree:
//!
//! * `last_level_lock` — guards reads/writes of the leaf level;
//! * `global_tree_lock` — guards whole-tree mutations and the prefix-sum
//!   descent.
//!
//! Priority update takes **both** (global first, then last-level; the
//! leaf lock is released before interior-node propagation), priority
//! retrieval takes only the leaf lock, sampling takes only the global
//! lock — so retrieval runs concurrently with interior propagation,
//! exactly as Algorithm 3 prescribes.
//!
//! **Lazy writing** (§IV-D2): insertion (i) atomically zeroes the slot's
//! priority, (ii) copies the transition into storage with *no lock held*,
//! (iii) restores the slot to the running maximum priority. A
//! zero-priority leaf is never returned by the descent, so sampling can
//! proceed concurrently with the bulk data copy.

use super::remover::{EvictReason, Remover, RemoverSpec};
use super::snapshot::{BufferState, ShardState};
use super::storage::{SampleBatch, Transition, TransitionStore};
use super::sumtree::KArySumTree;
use super::ReplayBuffer;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Small constant added to |TD| before the α exponent so no transition
/// starves (Schaul et al. 2016).
pub const PRIORITY_EPS: f32 = 1e-6;

/// Per-lock, per-operation instrumentation used to regenerate Table I and
/// the §Perf numbers. Counting is always on (one relaxed `fetch_add`);
/// hold-time timing only when `timing_enabled` is set.
#[derive(Default)]
pub struct LockStats {
    pub timing_enabled: AtomicBool,
    pub global_acquisitions: AtomicU64,
    /// Nanoseconds the global tree lock was actually HELD (timer starts
    /// after acquisition). Contention shows up in `global_wait_ns`, not
    /// here — conflating the two inflates the Fig-1/Fig-8 story.
    pub global_held_ns: AtomicU64,
    /// Nanoseconds spent WAITING to acquire the global tree lock.
    pub global_wait_ns: AtomicU64,
    pub leaf_acquisitions: AtomicU64,
    /// Nanoseconds the last-level (leaf) lock was actually held.
    pub leaf_held_ns: AtomicU64,
    /// Nanoseconds spent waiting to acquire the last-level lock.
    pub leaf_wait_ns: AtomicU64,
    pub inserts: AtomicU64,
    pub samples: AtomicU64,
    pub retrievals: AtomicU64,
    pub updates: AtomicU64,
    /// Nanoseconds spent copying transition data (outside any lock).
    pub storage_copy_ns: AtomicU64,
}

impl LockStats {
    pub fn enable_timing(&self) {
        self.timing_enabled.store(true, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> LockStatsSnapshot {
        LockStatsSnapshot {
            global_acquisitions: self.global_acquisitions.load(Ordering::Relaxed),
            global_held_ns: self.global_held_ns.load(Ordering::Relaxed),
            global_wait_ns: self.global_wait_ns.load(Ordering::Relaxed),
            leaf_acquisitions: self.leaf_acquisitions.load(Ordering::Relaxed),
            leaf_held_ns: self.leaf_held_ns.load(Ordering::Relaxed),
            leaf_wait_ns: self.leaf_wait_ns.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            samples: self.samples.load(Ordering::Relaxed),
            retrievals: self.retrievals.load(Ordering::Relaxed),
            updates: self.updates.load(Ordering::Relaxed),
            storage_copy_ns: self.storage_copy_ns.load(Ordering::Relaxed),
        }
    }
}

/// Plain-data snapshot of [`LockStats`].
#[derive(Clone, Copy, Debug, Default)]
pub struct LockStatsSnapshot {
    pub global_acquisitions: u64,
    pub global_held_ns: u64,
    pub global_wait_ns: u64,
    pub leaf_acquisitions: u64,
    pub leaf_held_ns: u64,
    pub leaf_wait_ns: u64,
    pub inserts: u64,
    pub samples: u64,
    pub retrievals: u64,
    pub updates: u64,
    pub storage_copy_ns: u64,
}

impl LockStatsSnapshot {
    /// Field-wise accumulation — used to build the merged view across the
    /// shards of a [`super::ShardedPrioritizedReplay`].
    pub fn accumulate(&mut self, other: &LockStatsSnapshot) {
        self.global_acquisitions += other.global_acquisitions;
        self.global_held_ns += other.global_held_ns;
        self.global_wait_ns += other.global_wait_ns;
        self.leaf_acquisitions += other.leaf_acquisitions;
        self.leaf_held_ns += other.leaf_held_ns;
        self.leaf_wait_ns += other.leaf_wait_ns;
        self.inserts += other.inserts;
        self.samples += other.samples;
        self.retrievals += other.retrievals;
        self.updates += other.updates;
        self.storage_copy_ns += other.storage_copy_ns;
    }
}

/// Configuration for [`PrioritizedReplay`].
#[derive(Clone, Debug)]
pub struct PrioritizedConfig {
    pub capacity: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    /// Sum-tree fan-out K (paper recommends K % 16 == 0; see Fig 9).
    pub fanout: usize,
    /// Priority exponent α: P(i) = (|TD_i| + ε)^α.
    pub alpha: f32,
    /// Importance-weight exponent β.
    pub beta: f32,
    /// Lazy writing (§IV-D2). `false` keeps the global lock held across
    /// the storage copy — the ablation knob for the design-choice bench.
    pub lazy_writing: bool,
    /// Number of independent sub-tree shards when the config is consumed
    /// by [`super::ShardedPrioritizedReplay`] (capacity is split evenly
    /// across them). The single-tree [`PrioritizedReplay`] — which *is*
    /// the S=1 shard primitive — ignores this field.
    pub shards: usize,
}

impl Default for PrioritizedConfig {
    fn default() -> Self {
        Self {
            capacity: 1 << 20,
            obs_dim: 4,
            act_dim: 1,
            fanout: 64,
            alpha: 0.6,
            beta: 0.4,
            lazy_writing: true,
            shards: 1,
        }
    }
}

/// The paper's parallel prioritized replay buffer.
pub struct PrioritizedReplay {
    tree: KArySumTree,
    store: TransitionStore,
    global_tree_lock: Mutex<()>,
    last_level_lock: Mutex<()>,
    /// Monotone insertion counter. While `cursor < capacity` the slot is
    /// the cursor itself; past that the [`Remover`] picks the victim
    /// (FIFO — slot = cursor % capacity — by default). Occupancy is
    /// always the prefix `[0, min(cursor, capacity))`.
    write_cursor: AtomicUsize,
    /// Eviction policy + per-slot sample counts.
    remover: Remover,
    /// Running max of *transformed* priorities, as f32 bits.
    max_priority: AtomicU32,
    alpha: f32,
    beta: f32,
    capacity: usize,
    lazy_writing: bool,
    pub stats: LockStats,
}

/// Timer handoff at lock acquisition: record the elapsed WAIT time
/// (`started` → now) into `wait_counter` and return the HELD-timer start.
/// `None` in (timing disabled) ⇒ `None` out. Call immediately after the
/// `lock()` returns, with `started` captured immediately before it.
#[inline]
fn note_acquired(wait_counter: &AtomicU64, started: Option<Instant>) -> Option<Instant> {
    started.map(|w0| {
        let t0 = Instant::now();
        wait_counter.fetch_add(t0.duration_since(w0).as_nanos() as u64, Ordering::Relaxed);
        t0
    })
}

#[inline(always)]
fn f32_bits_max(cell: &AtomicU32, v: f32) {
    // CAS-max over f32 bits (valid because priorities are non-negative,
    // and non-negative f32s order identically to their bit patterns).
    let mut cur = cell.load(Ordering::Relaxed);
    while f32::from_bits(cur) < v {
        match cell.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed)
        {
            Ok(_) => break,
            Err(c) => cur = c,
        }
    }
}

impl PrioritizedReplay {
    pub fn new(cfg: PrioritizedConfig) -> Self {
        Self::with_remover(cfg, RemoverSpec::Fifo)
    }

    /// Build with an explicit eviction policy. A `LowestPriority`
    /// remover allocates the sum tree's parallel min tree so victim
    /// lookup is a Θ((log_K N)·K) descent instead of a leaf scan.
    pub fn with_remover(cfg: PrioritizedConfig, remove: RemoverSpec) -> Self {
        assert!(cfg.capacity > 1);
        assert!(cfg.alpha >= 0.0 && cfg.beta >= 0.0);
        let tree = if remove == RemoverSpec::LowestPriority {
            KArySumTree::new_with_min(cfg.capacity, cfg.fanout)
        } else {
            KArySumTree::new(cfg.capacity, cfg.fanout)
        };
        Self {
            tree,
            store: TransitionStore::new(cfg.capacity, cfg.obs_dim, cfg.act_dim),
            global_tree_lock: Mutex::new(()),
            last_level_lock: Mutex::new(()),
            write_cursor: AtomicUsize::new(0),
            remover: Remover::new(remove, cfg.capacity),
            max_priority: AtomicU32::new(1.0f32.to_bits()),
            alpha: cfg.alpha,
            beta: cfg.beta,
            capacity: cfg.capacity,
            lazy_writing: cfg.lazy_writing,
            stats: LockStats::default(),
        }
    }

    /// Allocate the insert slot: the next free slot while filling, the
    /// remover's victim once full. Callers hold `global_tree_lock` so
    /// victim selection (min-tree descent / ripe-queue pop) is
    /// consistent with concurrent priority updates and two inserts can
    /// never pick the same lowest-priority victim (the chosen leaf is
    /// zeroed before the lock is released).
    fn pick_slot_locked(&self) -> (usize, Option<EvictReason>) {
        let cur = self.write_cursor.fetch_add(1, Ordering::Relaxed);
        if cur < self.capacity {
            return (cur, None);
        }
        match self.remover.spec() {
            RemoverSpec::Fifo => (cur % self.capacity, Some(EvictReason::Fifo)),
            RemoverSpec::Lifo => (self.capacity - 1, Some(EvictReason::Lifo)),
            RemoverSpec::LowestPriority => match self.tree.min_leaf() {
                Some((idx, _)) if idx < self.capacity => {
                    (idx, Some(EvictReason::LowestPriority))
                }
                // No sampleable leaf (e.g. every slot mid-lazy-write):
                // fall back to the ring slot.
                _ => (cur % self.capacity, Some(EvictReason::Fifo)),
            },
            RemoverSpec::MaxTimesSampled(_) => match self.remover.pick_ripe() {
                Some(slot) => (slot, Some(EvictReason::MaxSampled)),
                None => (cur % self.capacity, Some(EvictReason::Fifo)),
            },
        }
    }

    /// P(i) = (|TD| + ε)^α.
    #[inline]
    pub fn transform_priority(&self, td_abs: f32) -> f32 {
        (td_abs.max(0.0) + PRIORITY_EPS).powf(self.alpha)
    }

    fn timing(&self) -> bool {
        self.stats.timing_enabled.load(Ordering::Relaxed)
    }

    /// Algorithm 3 PRIORITYUPDATE: both locks for the leaf write, global
    /// only for interior propagation. `priority` is already transformed.
    fn locked_priority_update(&self, idx: usize, priority: f32) {
        let timing = self.timing();
        let w0 = timing.then(Instant::now);
        let _global = self.global_tree_lock.lock().unwrap();
        let t0 = note_acquired(&self.stats.global_wait_ns, w0);
        self.stats.global_acquisitions.fetch_add(1, Ordering::Relaxed);
        let delta;
        {
            let w1 = timing.then(Instant::now);
            let _leaf = self.last_level_lock.lock().unwrap();
            let t1 = note_acquired(&self.stats.leaf_wait_ns, w1);
            self.stats.leaf_acquisitions.fetch_add(1, Ordering::Relaxed);
            delta = self.tree.set_leaf(idx, priority);
            if let Some(t1) = t1 {
                self.stats
                    .leaf_held_ns
                    .fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        } // leaf lock released before interior propagation (Alg 3 line 5)
        self.tree.propagate(idx, delta);
        if let Some(t0) = t0 {
            self.stats
                .global_held_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }

    /// Algorithm 3 PRIORITYRETRIEVAL: leaf lock only.
    pub fn get_priority(&self, idx: usize) -> f32 {
        self.stats.retrievals.fetch_add(1, Ordering::Relaxed);
        let timing = self.timing();
        let w0 = timing.then(Instant::now);
        let _leaf = self.last_level_lock.lock().unwrap();
        let t0 = note_acquired(&self.stats.leaf_wait_ns, w0);
        self.stats.leaf_acquisitions.fetch_add(1, Ordering::Relaxed);
        let p = self.tree.get(idx);
        if let Some(t0) = t0 {
            self.stats
                .leaf_held_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        p
    }

    /// Σ of all priorities (root read; no lock needed — single atomic).
    pub fn total_priority(&self) -> f32 {
        self.tree.total()
    }

    /// Current running maximum transformed priority.
    pub fn max_priority(&self) -> f32 {
        f32::from_bits(self.max_priority.load(Ordering::Relaxed))
    }

    /// Squash accumulated fp drift (takes both locks exclusively).
    pub fn rebuild_tree(&self) {
        let _global = self.global_tree_lock.lock().unwrap();
        let _leaf = self.last_level_lock.lock().unwrap();
        self.tree.rebuild();
    }

    /// Direct access to the tree (benchmarks).
    pub fn tree(&self) -> &KArySumTree {
        &self.tree
    }

    /// Copy one stored row into a batch. Takes no lock: with lazy writing
    /// the zero-priority guard keeps half-written rows out of sampling,
    /// so row copies are safe after the descent has released the lock.
    pub fn copy_row_into(&self, idx: usize, out: &mut SampleBatch) {
        self.store.read_into(idx, out);
    }

    /// Storage dims `(obs_dim, act_dim)`.
    pub fn dims(&self) -> (usize, usize) {
        (self.store.obs_dim(), self.store.act_dim())
    }

    /// Capture this tree + storage segment as one [`ShardState`]. Takes
    /// both locks, so the captured leaves and cursor are mutually
    /// consistent; a lazy insert whose data copy is in flight at capture
    /// time shows up — exactly as in live sampling — as a zero-priority
    /// slot that can never be drawn until it is overwritten.
    ///
    /// The O(occupied) row copy runs under both locks, stalling this
    /// shard's writers and samplers for the duration — acceptable for
    /// periodic checkpoints (rare, and sharding bounds the stall to one
    /// shard at a time); a flat-memcpy capture that defers per-row
    /// structuring past the unlock is the known optimization if
    /// checkpoint cadence ever becomes hot.
    pub fn snapshot_shard(&self) -> ShardState {
        let _global = self.global_tree_lock.lock().unwrap();
        let _leaf = self.last_level_lock.lock().unwrap();
        let cursor = self.write_cursor.load(Ordering::Relaxed);
        let len = cursor.min(self.capacity);
        let mut priorities = Vec::with_capacity(len);
        let mut rows = Vec::with_capacity(len);
        for i in 0..len {
            priorities.push(self.tree.get(i));
            rows.push(self.store.read(i));
        }
        ShardState {
            cursor: cursor as u64,
            max_priority: self.max_priority(),
            priorities,
            sample_counts: self.remover.counts_snapshot(len),
            rows,
        }
    }

    /// Structural validation of a shard state against this buffer's
    /// geometry (no mutation).
    pub fn validate_shard(&self, s: &ShardState) -> Result<()> {
        s.validate(self.name(), self.capacity, self.store.obs_dim(), self.store.act_dim())
    }

    /// Overwrite this shard with a validated state: rows into storage,
    /// priorities onto the leaves (slots beyond the state's length are
    /// zeroed), then a full [`KArySumTree::rebuild`] so every interior
    /// sum is recomputed from the leaves rather than trusted from disk.
    /// Callers must run [`Self::validate_shard`] first.
    pub(crate) fn apply_shard(&self, s: &ShardState) {
        let _global = self.global_tree_lock.lock().unwrap();
        let _leaf = self.last_level_lock.lock().unwrap();
        for (i, row) in s.rows.iter().enumerate() {
            self.store.write(i, row);
        }
        for (i, &p) in s.priorities.iter().enumerate() {
            self.tree.set_leaf(i, p);
        }
        for i in s.priorities.len()..self.capacity {
            self.tree.set_leaf(i, 0.0);
        }
        self.tree.rebuild();
        self.write_cursor.store(s.cursor as usize, Ordering::Relaxed);
        self.remover.restore_counts(&s.sample_counts);
        self.max_priority
            .store(s.max_priority.max(f32::MIN_POSITIVE).to_bits(), Ordering::Relaxed);
    }

    /// Validate + apply one shard state (the single-tree restore path).
    pub fn restore_shard(&self, s: &ShardState) -> Result<()> {
        self.validate_shard(s)?;
        self.apply_shard(s);
        Ok(())
    }

    /// Two-level sampling support: run the prefix-sum descents for every
    /// value in `prefixes` under ONE `global_tree_lock` acquisition,
    /// appending `(leaf_index, priority)` pairs to the output vectors.
    /// Returns `false` — appending nothing — when the tree holds no
    /// positive mass at lock time (the caller re-routes those strata).
    /// Does NOT bump the `samples` counter: this is a sampling primitive,
    /// and the wrapper counts one sample op per batch, keeping merged
    /// stats comparable with the single-tree buffer's.
    pub fn descend_batch(
        &self,
        prefixes: &[f32],
        out_indices: &mut Vec<usize>,
        out_priorities: &mut Vec<f32>,
    ) -> bool {
        if prefixes.is_empty() {
            return true;
        }
        let timing = self.timing();
        let w0 = timing.then(Instant::now);
        let _global = self.global_tree_lock.lock().unwrap();
        let t0 = note_acquired(&self.stats.global_wait_ns, w0);
        self.stats.global_acquisitions.fetch_add(1, Ordering::Relaxed);
        if !(self.tree.total() > 0.0) {
            return false;
        }
        for &x in prefixes {
            let (idx, p) = self.tree.prefix_sum_index(x);
            out_indices.push(idx);
            out_priorities.push(p);
        }
        if let Some(t0) = t0 {
            self.stats
                .global_held_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        true
    }

    /// Algorithm 3 PRIORITYUPDATE over a batch of already-transformed
    /// priorities, amortized: ONE global and ONE leaf acquisition for the
    /// whole batch instead of one pair per index. The leaf lock is still
    /// released before interior propagation (Alg 3 line 5), so priority
    /// retrieval overlaps the propagation exactly as in the per-index
    /// path.
    pub fn update_transformed_batch(&self, pairs: &[(usize, f32)]) {
        if pairs.is_empty() {
            return;
        }
        self.stats
            .updates
            .fetch_add(pairs.len() as u64, Ordering::Relaxed);
        for &(_, p) in pairs {
            f32_bits_max(&self.max_priority, p);
        }
        let timing = self.timing();
        let w0 = timing.then(Instant::now);
        let _global = self.global_tree_lock.lock().unwrap();
        let t0 = note_acquired(&self.stats.global_wait_ns, w0);
        self.stats.global_acquisitions.fetch_add(1, Ordering::Relaxed);
        let mut deltas: Vec<(usize, f32)> = Vec::with_capacity(pairs.len());
        {
            let w1 = timing.then(Instant::now);
            let _leaf = self.last_level_lock.lock().unwrap();
            let t1 = note_acquired(&self.stats.leaf_wait_ns, w1);
            self.stats.leaf_acquisitions.fetch_add(1, Ordering::Relaxed);
            for &(idx, p) in pairs {
                deltas.push((idx, self.tree.set_leaf(idx, p)));
            }
            if let Some(t1) = t1 {
                self.stats
                    .leaf_held_ns
                    .fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
        } // leaf lock released before interior propagation (Alg 3 line 5)
        for &(idx, delta) in &deltas {
            self.tree.propagate(idx, delta);
        }
        if let Some(t0) = t0 {
            self.stats
                .global_held_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }

    /// Algorithm 3 SAMPLE, batched: the prefix-sum descents run under ONE
    /// global-lock acquisition (amortizing the lock), the row copies run
    /// after release — zero-priority guard makes that safe. Stratified
    /// sampling: draw j-th sample from segment [jT/B, (j+1)T/B).
    fn sample_indices(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> bool {
        let timing = self.timing();
        let w0 = timing.then(Instant::now);
        let _global = self.global_tree_lock.lock().unwrap();
        let t0 = note_acquired(&self.stats.global_wait_ns, w0);
        self.stats.global_acquisitions.fetch_add(1, Ordering::Relaxed);
        let total = self.tree.total();
        if !(total > 0.0) {
            return false;
        }
        let seg = total / batch as f32;
        for j in 0..batch {
            let x = (j as f32 + rng.f32()) * seg;
            let (idx, p) = self.tree.prefix_sum_index(x);
            out.indices.push(idx);
            out.priorities.push(p);
        }
        if let Some(t0) = t0 {
            self.stats
                .global_held_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        true
    }

    /// The shared insert body behind both trait entry points: `pri`
    /// carries a migrated item's already-transformed priority; `None` is
    /// the live-training path, where the row arrives at the running
    /// maximum (read at make-sampleable time, as always).
    fn insert_impl(&self, t: &Transition, pri: Option<f32>) -> Option<EvictReason> {
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        let timing = self.timing();
        if !self.lazy_writing {
            let w0 = timing.then(Instant::now);
            let _global = self.global_tree_lock.lock().unwrap();
            let t0 = note_acquired(&self.stats.global_wait_ns, w0);
            self.stats.global_acquisitions.fetch_add(1, Ordering::Relaxed);
            let (slot, reason) = self.pick_slot_locked();
            let delta;
            {
                let w1 = timing.then(Instant::now);
                let _leaf = self.last_level_lock.lock().unwrap();
                let t1 = note_acquired(&self.stats.leaf_wait_ns, w1);
                self.stats.leaf_acquisitions.fetch_add(1, Ordering::Relaxed);
                self.store.write(slot, t); // copy INSIDE the locks
                delta = self
                    .tree
                    .set_leaf(slot, pri.unwrap_or_else(|| self.max_priority()));
                if let Some(t1) = t1 {
                    self.stats
                        .leaf_held_ns
                        .fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            }
            self.tree.propagate(slot, delta);
            self.remover.on_insert(slot);
            if let Some(t0) = t0 {
                self.stats
                    .global_held_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            return reason;
        }
        // (i) pick the slot and zero its priority under ONE global
        // acquisition so the slot cannot be sampled — or re-picked as a
        // lowest-priority victim — while the copy is in flight...
        let (slot, reason) = {
            let w0 = timing.then(Instant::now);
            let _global = self.global_tree_lock.lock().unwrap();
            let t0 = note_acquired(&self.stats.global_wait_ns, w0);
            self.stats.global_acquisitions.fetch_add(1, Ordering::Relaxed);
            let (slot, reason) = self.pick_slot_locked();
            let delta;
            {
                let w1 = timing.then(Instant::now);
                let _leaf = self.last_level_lock.lock().unwrap();
                let t1 = note_acquired(&self.stats.leaf_wait_ns, w1);
                self.stats.leaf_acquisitions.fetch_add(1, Ordering::Relaxed);
                delta = self.tree.set_leaf(slot, 0.0);
                if let Some(t1) = t1 {
                    self.stats
                        .leaf_held_ns
                        .fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
                }
            } // leaf lock released before interior propagation (Alg 3 line 5)
            self.tree.propagate(slot, delta);
            if let Some(t0) = t0 {
                self.stats
                    .global_held_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            }
            (slot, reason)
        };
        self.remover.on_insert(slot);
        // (ii) ...bulk-copy the transition with NO lock held...
        let t0 = timing.then(Instant::now);
        self.store.write(slot, t);
        if let Some(t0) = t0 {
            self.stats
                .storage_copy_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        // (iii) ...then make it sampleable, at the carried priority for a
        // migrated row, at the running max for a live one.
        self.locked_priority_update(slot, pri.unwrap_or_else(|| self.max_priority()));
        reason
    }
}

impl ReplayBuffer for PrioritizedReplay {
    fn name(&self) -> &'static str {
        "pal-kary"
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn len(&self) -> usize {
        self.write_cursor.load(Ordering::Relaxed).min(self.capacity)
    }

    /// Lazy-writing insertion (§IV-D2 / Algorithm 3 INSERT); with
    /// `lazy_writing = false`, the ablation path holds the global tree
    /// lock across the whole insertion including the storage copy.
    ///
    /// Victim selection is folded into the FIRST global acquisition
    /// (slot pick + leaf zero under one lock), so an insert still costs
    /// exactly two global acquisitions regardless of remover.
    fn insert_from(&self, _actor_id: usize, t: &Transition) -> Option<EvictReason> {
        self.insert_impl(t, None)
    }

    /// State-merge insert: the row becomes sampleable at the carried
    /// (already-transformed) priority instead of the running maximum.
    fn insert_with_priority(
        &self,
        _actor_id: usize,
        t: &Transition,
        priority: f32,
    ) -> Option<EvictReason> {
        // Same guard as the table surface: a NaN/inf/negative leaf would
        // poison interior sums up to the root.
        let p = if priority.is_finite() && priority >= 0.0 { priority } else { 0.0 };
        self.insert_impl(t, Some(p))
    }

    fn sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> bool {
        self.stats.samples.fetch_add(1, Ordering::Relaxed);
        out.clear();
        if self.len() == 0 || batch == 0 {
            return false;
        }
        if !self.sample_indices(batch, rng, out) {
            return false;
        }
        // Importance weights (shared formula — see fill_is_weights).
        super::fill_is_weights(out, self.len() as f32, self.total_priority(), self.beta);
        // Row copies outside the lock (lazy-writing guarantee).
        for i in 0..out.indices.len() {
            let idx = out.indices[i];
            self.store.read_into(idx, out);
        }
        true
    }

    /// Algorithm 3 PRIORITYUPDATE over a batch of |TD| errors, routed
    /// through the lock-amortized batched path (one global + one leaf
    /// acquisition per call instead of one pair per index).
    fn update_priorities(&self, indices: &[usize], td_abs: &[f32]) {
        debug_assert_eq!(indices.len(), td_abs.len());
        let pairs: Vec<(usize, f32)> = indices
            .iter()
            .zip(td_abs)
            .map(|(&idx, &td)| (idx, self.transform_priority(td)))
            .collect();
        self.update_transformed_batch(&pairs);
    }

    fn total_priority(&self) -> f32 {
        PrioritizedReplay::total_priority(self)
    }

    fn remover(&self) -> RemoverSpec {
        self.remover.spec()
    }

    fn note_sampled(&self, indices: &[usize]) {
        self.remover.note_sampled(indices);
    }

    fn max_sample_count(&self) -> u32 {
        self.remover.max_count(self.len())
    }

    fn snapshot_state(&self) -> Option<BufferState> {
        Some(BufferState {
            impl_name: self.name().to_string(),
            capacity: self.capacity,
            obs_dim: self.store.obs_dim(),
            act_dim: self.store.act_dim(),
            shards: vec![self.snapshot_shard()],
        })
    }

    fn validate_state(&self, state: &BufferState) -> Result<()> {
        state.check_header(
            self.name(),
            self.capacity,
            self.store.obs_dim(),
            self.store.act_dim(),
            1,
        )?;
        self.validate_shard(&state.shards[0])
    }

    fn restore_state(&self, state: &BufferState) -> Result<()> {
        self.validate_state(state)?;
        self.apply_shard(&state.shards[0]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn mk(capacity: usize, fanout: usize) -> PrioritizedReplay {
        PrioritizedReplay::new(PrioritizedConfig {
            capacity,
            obs_dim: 3,
            act_dim: 2,
            fanout,
            alpha: 0.6,
            beta: 0.4,
            lazy_writing: true,
            shards: 1,
        })
    }

    fn tr(v: f32) -> Transition {
        Transition {
            obs: vec![v; 3],
            action: vec![v; 2],
            next_obs: vec![v + 1.0; 3],
            reward: v,
            done: false,
        }
    }

    #[test]
    fn insert_then_sample_returns_data() {
        let b = mk(128, 16);
        for i in 0..50 {
            b.insert(&tr(i as f32));
        }
        assert_eq!(b.len(), 50);
        let mut rng = Rng::new(1);
        let mut out = SampleBatch::with_capacity(16, 3, 2);
        assert!(b.sample(16, &mut rng, &mut out));
        assert_eq!(out.len(), 16);
        assert_eq!(out.obs.len(), 16 * 3);
        assert_eq!(out.is_weights.len(), 16);
        // Every sampled row must be one of the inserted transitions.
        for (j, &idx) in out.indices.iter().enumerate() {
            assert!(idx < 50);
            let v = out.obs[j * 3];
            assert_eq!(out.reward[j], v);
        }
    }

    #[test]
    fn empty_buffer_sample_fails() {
        let b = mk(16, 16);
        let mut rng = Rng::new(1);
        let mut out = SampleBatch::default();
        assert!(!b.sample(4, &mut rng, &mut out));
    }

    #[test]
    fn fifo_eviction_wraps() {
        let b = mk(8, 16);
        for i in 0..20 {
            b.insert(&tr(i as f32));
        }
        assert_eq!(b.len(), 8);
        // Slots hold the last 8 transitions (12..20) in ring order.
        let mut rng = Rng::new(2);
        let mut out = SampleBatch::default();
        assert!(b.sample(8, &mut rng, &mut out));
        for j in 0..out.len() {
            assert!(out.reward[j] >= 12.0);
        }
    }

    #[test]
    fn priority_update_biases_sampling() {
        let b = mk(64, 16);
        for i in 0..64 {
            b.insert(&tr(i as f32));
        }
        // Give slot 7 overwhelming priority.
        let idx: Vec<usize> = (0..64).collect();
        let mut tds = vec![0.001f32; 64];
        tds[7] = 1000.0;
        b.update_priorities(&idx, &tds);
        let mut rng = Rng::new(3);
        let mut out = SampleBatch::default();
        let mut hits = 0;
        for _ in 0..50 {
            b.sample(8, &mut rng, &mut out);
            hits += out.indices.iter().filter(|&&i| i == 7).count();
        }
        assert!(hits > 300, "slot 7 sampled only {hits}/400 times");
    }

    #[test]
    fn importance_weights_normalized_and_inverse() {
        let b = mk(32, 16);
        for i in 0..32 {
            b.insert(&tr(i as f32));
        }
        let idx: Vec<usize> = (0..32).collect();
        let tds: Vec<f32> = (0..32).map(|i| 0.1 + i as f32).collect();
        b.update_priorities(&idx, &tds);
        let mut rng = Rng::new(4);
        let mut out = SampleBatch::default();
        assert!(b.sample(32, &mut rng, &mut out));
        assert!(out.is_weights.iter().all(|&w| w > 0.0 && w <= 1.0 + 1e-6));
        // Higher priority ⇒ lower weight.
        for j in 0..out.len() {
            for k in 0..out.len() {
                if out.priorities[j] > out.priorities[k] * 1.01 {
                    assert!(out.is_weights[j] <= out.is_weights[k] + 1e-4);
                }
            }
        }
    }

    #[test]
    fn get_priority_matches_update() {
        let b = mk(16, 16);
        for i in 0..16 {
            b.insert(&tr(i as f32));
        }
        b.update_priorities(&[5], &[2.0]);
        let expect = b.transform_priority(2.0);
        assert!((b.get_priority(5) - expect).abs() < 1e-6);
    }

    #[test]
    fn max_priority_tracks_updates() {
        let b = mk(16, 16);
        b.insert(&tr(0.0));
        assert_eq!(b.max_priority(), 1.0);
        b.update_priorities(&[0], &[10.0]);
        let p = b.transform_priority(10.0);
        assert!((b.max_priority() - p).abs() < 1e-6);
        // New inserts arrive at the running max.
        b.insert(&tr(1.0));
        assert!((b.get_priority(1) - p).abs() < 1e-5);
    }

    #[test]
    fn batched_update_amortizes_locks() {
        let b = mk(64, 16);
        for i in 0..64 {
            b.insert(&tr(i as f32));
        }
        let before = b.stats.snapshot();
        let idx: Vec<usize> = (0..64).collect();
        let tds: Vec<f32> = (0..64).map(|i| 0.1 + i as f32).collect();
        b.update_priorities(&idx, &tds);
        let after = b.stats.snapshot();
        // One global + one leaf acquisition for the whole 64-pair batch.
        assert_eq!(after.global_acquisitions - before.global_acquisitions, 1);
        assert_eq!(after.leaf_acquisitions - before.leaf_acquisitions, 1);
        assert_eq!(after.updates - before.updates, 64);
        // Values land exactly as in the per-index path.
        for (i, &td) in tds.iter().enumerate() {
            assert!((b.get_priority(i) - b.transform_priority(td)).abs() < 1e-6);
        }
    }

    #[test]
    fn batched_update_handles_duplicate_indices() {
        let b = mk(16, 16);
        for i in 0..16 {
            b.insert(&tr(i as f32));
        }
        b.update_priorities(&[3, 3, 3], &[5.0, 1.0, 2.0]);
        let expect = b.transform_priority(2.0); // last write wins
        assert!((b.get_priority(3) - expect).abs() < 1e-6);
        // Per-pair deltas must sum to final-initial WITHOUT a rebuild.
        assert!(b.tree().invariant_error() < 1e-4);
    }

    #[test]
    fn descend_batch_matches_priorities() {
        let b = mk(64, 16);
        for i in 0..64 {
            b.insert(&tr(i as f32));
        }
        let total = b.total_priority();
        let prefixes: Vec<f32> = (0..8).map(|j| (j as f32 + 0.5) / 8.0 * total).collect();
        let mut idx = Vec::new();
        let mut pri = Vec::new();
        assert!(b.descend_batch(&prefixes, &mut idx, &mut pri));
        assert_eq!(idx.len(), 8);
        for (&i, &p) in idx.iter().zip(&pri) {
            assert!(i < 64);
            assert!(p > 0.0);
            assert!((b.get_priority(i) - p).abs() < 1e-6);
        }
    }

    #[test]
    fn concurrent_insert_sample_update_stress() {
        // 2 inserters + 1 sampler + 1 updater over a shared buffer; the
        // invariant (root ≈ Σ leaves after quiescence) must survive.
        let b = Arc::new(mk(1024, 64));
        for i in 0..512 {
            b.insert(&tr(i as f32));
        }
        std::thread::scope(|s| {
            for t in 0..2 {
                let b = Arc::clone(&b);
                s.spawn(move || {
                    for i in 0..2000 {
                        b.insert(&tr((t * 10_000 + i) as f32));
                    }
                });
            }
            {
                let b = Arc::clone(&b);
                s.spawn(move || {
                    let mut rng = Rng::new(7);
                    let mut out = SampleBatch::default();
                    for _ in 0..500 {
                        if b.sample(32, &mut rng, &mut out) {
                            assert_eq!(out.len(), 32);
                            // No zero-priority row must ever be sampled.
                            assert!(out.priorities.iter().all(|&p| p > 0.0));
                        }
                    }
                });
            }
            {
                let b = Arc::clone(&b);
                s.spawn(move || {
                    let mut rng = Rng::new(8);
                    for _ in 0..500 {
                        let idx: Vec<usize> =
                            (0..16).map(|_| rng.below_usize(512)).collect();
                        let tds: Vec<f32> = (0..16).map(|_| rng.f32() * 5.0).collect();
                        b.update_priorities(&idx, &tds);
                    }
                });
            }
        });
        // After quiescence the tree invariant holds up to fp drift.
        b.rebuild_tree();
        assert!(b.tree().invariant_error() < 1e-5);
        assert_eq!(b.len(), 1024);
    }

    #[test]
    fn snapshot_restore_rebuilds_tree_sums() {
        let b = mk(64, 16);
        for i in 0..40 {
            b.insert(&tr(i as f32));
        }
        let idx: Vec<usize> = (0..40).collect();
        let tds: Vec<f32> = (0..40).map(|i| 0.1 + i as f32).collect();
        b.update_priorities(&idx, &tds);
        let s = b.snapshot_shard();
        assert_eq!(s.len(), 40);
        assert!((b.max_priority() - s.max_priority).abs() < 1e-6);

        let fresh = mk(64, 16);
        fresh.restore_shard(&s).unwrap();
        // Leaves, cursor, max priority and every INTERIOR sum must come
        // back: the interior nodes are rebuilt, so root == Σ leaves.
        assert_eq!(fresh.len(), 40);
        assert!(fresh.tree().invariant_error() < 1e-6);
        let total: f64 = s.total_priority();
        assert!((fresh.total_priority() as f64 - total).abs() / total < 1e-4);
        for i in 0..40 {
            assert!((fresh.get_priority(i) - b.get_priority(i)).abs() < 1e-6, "leaf {i}");
        }
        assert!((fresh.max_priority() - b.max_priority()).abs() < 1e-6);
        // A corrupted state must be rejected without mutation.
        let mut bad = s.clone();
        bad.priorities[3] = f32::INFINITY;
        let before = fresh.snapshot_shard();
        assert!(fresh.restore_shard(&bad).is_err());
        assert_eq!(fresh.snapshot_shard(), before);
    }

    fn mk_with(capacity: usize, fanout: usize, remove: RemoverSpec) -> PrioritizedReplay {
        PrioritizedReplay::with_remover(
            PrioritizedConfig {
                capacity,
                obs_dim: 3,
                act_dim: 2,
                fanout,
                alpha: 0.6,
                beta: 0.4,
                lazy_writing: true,
                shards: 1,
            },
            remove,
        )
    }

    #[test]
    fn lifo_remover_overwrites_newest_slot() {
        let b = mk_with(4, 16, RemoverSpec::Lifo);
        let mut evicted = Vec::new();
        for i in 0..7 {
            if let Some(r) = b.insert(&tr(i as f32)) {
                evicted.push(r);
            }
        }
        assert_eq!(b.len(), 4);
        assert_eq!(evicted, vec![EvictReason::Lifo; 3]);
        // The newest slot (capacity-1) absorbed items 4, 5, 6 in turn.
        let s = b.snapshot_shard();
        let rewards: Vec<f32> = s.rows.iter().map(|r| r.reward).collect();
        assert_eq!(rewards, vec![0.0, 1.0, 2.0, 6.0]);
    }

    #[test]
    fn lowest_priority_remover_picks_min_leaf() {
        let b = mk_with(4, 16, RemoverSpec::LowestPriority);
        for i in 0..4 {
            b.insert(&tr(i as f32));
        }
        // Distinct priorities: slot 1 is the cheapest, slot 2 next.
        b.update_priorities(&[0, 1, 2, 3], &[5.0, 0.5, 3.0, 4.0]);
        assert_eq!(b.insert(&tr(9.0)), Some(EvictReason::LowestPriority));
        let s = b.snapshot_shard();
        assert_eq!(s.rows[1].reward, 9.0);
        assert_eq!(s.rows[0].reward, 0.0);
        // The replacement arrives at max priority, so the NEXT victim is
        // the second-lowest original (slot 2).
        assert_eq!(b.insert(&tr(11.0)), Some(EvictReason::LowestPriority));
        assert_eq!(b.snapshot_shard().rows[2].reward, 11.0);
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn max_sampled_remover_evicts_ripe_slots() {
        let b = mk_with(4, 16, RemoverSpec::MaxTimesSampled(2));
        for i in 0..4 {
            b.insert(&tr(i as f32));
        }
        // No slot ripe yet: eviction falls back to the FIFO ring slot.
        assert_eq!(b.insert(&tr(4.0)), Some(EvictReason::Fifo));
        // Slot 2 crosses its sample budget -> next victim.
        b.note_sampled(&[2, 2]);
        assert_eq!(b.max_sample_count(), 2);
        assert_eq!(b.insert(&tr(5.0)), Some(EvictReason::MaxSampled));
        let s = b.snapshot_shard();
        assert_eq!(s.rows[2].reward, 5.0);
        assert_eq!(s.rows[0].reward, 4.0);
        // Overwriting a slot resets its count.
        assert_eq!(s.sample_counts, vec![0, 0, 0, 0]);
    }

    #[test]
    fn sample_counts_roundtrip_through_snapshot() {
        let b = mk_with(8, 16, RemoverSpec::MaxTimesSampled(5));
        for i in 0..6 {
            b.insert(&tr(i as f32));
        }
        b.note_sampled(&[1, 3, 3]);
        let s = b.snapshot_shard();
        assert_eq!(s.sample_counts, vec![0, 1, 0, 2, 0, 0]);
        let fresh = mk_with(8, 16, RemoverSpec::MaxTimesSampled(5));
        fresh.restore_shard(&s).unwrap();
        assert_eq!(fresh.max_sample_count(), 2);
        assert_eq!(fresh.snapshot_shard(), s);
    }

    #[test]
    fn lock_stats_accumulate() {
        let b = mk(32, 16);
        b.stats.enable_timing();
        for i in 0..8 {
            b.insert(&tr(i as f32));
        }
        let mut rng = Rng::new(5);
        let mut out = SampleBatch::default();
        b.sample(4, &mut rng, &mut out);
        b.get_priority(0);
        b.update_priorities(&[0], &[1.0]);
        let s = b.stats.snapshot();
        assert_eq!(s.inserts, 8);
        assert_eq!(s.samples, 1);
        assert_eq!(s.retrievals, 1);
        assert_eq!(s.updates, 1);
        // insert = 2 locked updates each; sample = 1 global; update = 1.
        assert_eq!(s.global_acquisitions, 8 * 2 + 1 + 1);
        assert!(s.storage_copy_ns > 0);
    }

    #[test]
    fn held_time_excludes_lock_wait() {
        // Regression: the held timers used to start BEFORE lock
        // acquisition, so under contention `global_held_ns` reported
        // wait+hold — with T contending threads, roughly T× the wall
        // clock. Post-fix, holds are strictly nested in one mutex, so
        // their sum cannot exceed the wall clock (modulo timer overhead),
        // and the wait shows up in the separate `global_wait_ns`.
        const THREADS: usize = 4;
        const ROUNDS: usize = 60;
        let b = Arc::new(mk(65536, 64));
        b.stats.enable_timing();
        for i in 0..65536 {
            b.insert(&tr((i % 97) as f32));
        }
        let barrier = Arc::new(std::sync::Barrier::new(THREADS));
        let wall = Instant::now();
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let b = Arc::clone(&b);
                let barrier = Arc::clone(&barrier);
                s.spawn(move || {
                    let mut rng = Rng::new(100 + t as u64);
                    barrier.wait();
                    for _ in 0..ROUNDS {
                        let pairs: Vec<(usize, f32)> = (0..512)
                            .map(|_| (rng.below_usize(65536), 0.1 + rng.f32()))
                            .collect();
                        b.update_transformed_batch(&pairs);
                    }
                });
            }
        });
        let wall_ns = wall.elapsed().as_nanos() as u64;
        let s = b.stats.snapshot();
        assert!(
            s.global_held_ns <= wall_ns + wall_ns / 2,
            "held {} ns exceeds 1.5x wall {} ns: held timers include wait",
            s.global_held_ns,
            wall_ns
        );
        // The wait really happened — it is just accounted separately now.
        assert!(s.global_wait_ns > 0);
    }
}
