//! Replay buffers — the paper's core contribution plus every comparator.
//!
//! * [`PrioritizedReplay`] — K-ary sum tree, cache-aligned layout, lazy
//!   writing, two-lock synchronization (§IV).
//! * [`GlobalLockReplay`] — binary sum tree + one global lock (Fig 9
//!   baseline, RLlib-substitute framework buffer).
//! * [`UniformReplay`] — plain ring buffer, uniform sampling.
//! * [`NaiveScanReplay`] / [`PyBindBinaryReplay`] — emulations of the
//!   third-party buffers the paper plugs into (Fig 11).
//!
//! All implementations share the [`ReplayBuffer`] trait so the trainer,
//! the benches and the property tests are generic over them.

pub mod baseline;
pub mod emulated;
pub mod prioritized;
pub mod storage;
pub mod sumtree;
pub mod uniform;

pub use baseline::{BinarySumTree, GlobalLockReplay};
pub use emulated::{NaiveScanReplay, PyBindBinaryReplay, PySumTreeReplay};
pub use prioritized::{PrioritizedConfig, PrioritizedReplay};
pub use storage::{SampleBatch, Transition, TransitionStore};
pub use sumtree::KArySumTree;
pub use uniform::UniformReplay;

use crate::util::rng::Rng;

/// Common interface of every replay buffer in the crate.
///
/// All methods take `&self`: implementations are internally synchronized
/// so actors and learners can share one buffer behind an `Arc`.
pub trait ReplayBuffer: Send + Sync {
    /// Implementation name (used in bench output).
    fn name(&self) -> &'static str;

    /// Maximum number of transitions held.
    fn capacity(&self) -> usize;

    /// Current number of (fully inserted) transitions.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert one transition, evicting FIFO when full (paper §IV-A1).
    fn insert(&self, t: &Transition);

    /// Draw `batch` transitions into `out` (cleared first). Returns false
    /// if the buffer is empty. Prioritized impls fill `priorities` and
    /// normalized `is_weights`.
    fn sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> bool;

    /// Feed back new |TD| errors for sampled indices (paper §IV-A4).
    fn update_priorities(&self, indices: &[usize], td_abs: &[f32]);
}

#[cfg(test)]
mod trait_tests {
    //! Behavioural tests run against EVERY implementation.
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn impls(capacity: usize) -> Vec<Arc<dyn ReplayBuffer>> {
        vec![
            Arc::new(PrioritizedReplay::new(PrioritizedConfig {
                capacity,
                obs_dim: 2,
                act_dim: 1,
                fanout: 16,
                alpha: 0.6,
                beta: 0.4,
                lazy_writing: true,
            })),
            Arc::new(GlobalLockReplay::new(capacity, 2, 1, 0.6, 0.4)),
            Arc::new(UniformReplay::new(capacity, 2, 1)),
            Arc::new(NaiveScanReplay::new(capacity, 2, 1, 0.6, 0.4)),
            Arc::new(PyBindBinaryReplay::new(capacity, 2, 1, 0.6, 0.4)),
            Arc::new(PySumTreeReplay::new(capacity, 2, 1, 0.6, 0.4)),
        ]
    }

    fn tr(v: f32) -> Transition {
        Transition {
            obs: vec![v, -v],
            action: vec![v],
            next_obs: vec![v + 1.0, -v],
            reward: v,
            done: v as i64 % 5 == 0,
        }
    }

    #[test]
    fn all_impls_basic_contract() {
        for b in impls(32) {
            assert!(b.is_empty(), "{}", b.name());
            let mut rng = Rng::new(1);
            let mut out = SampleBatch::default();
            assert!(!b.sample(4, &mut rng, &mut out), "{}", b.name());
            for i in 0..48 {
                b.insert(&tr(i as f32));
            }
            assert_eq!(b.len(), 32, "{}", b.name());
            assert!(b.sample(16, &mut rng, &mut out), "{}", b.name());
            assert_eq!(out.len(), 16, "{}", b.name());
            assert_eq!(out.obs.len(), 32, "{}", b.name());
            assert_eq!(out.is_weights.len(), 16, "{}", b.name());
            // Sampled rows are self-consistent (obs[0] == reward by
            // construction) — catches torn batch assembly.
            for j in 0..16 {
                assert_eq!(out.obs[j * 2], out.reward[j], "{}", b.name());
            }
            // Priority feedback must not panic and must keep sampling OK.
            let idx = out.indices.clone();
            b.update_priorities(&idx, &vec![0.7; idx.len()]);
            assert!(b.sample(8, &mut rng, &mut out), "{}", b.name());
        }
    }

    #[test]
    fn all_impls_survive_concurrent_use() {
        for b in impls(256) {
            for i in 0..64 {
                b.insert(&tr(i as f32));
            }
            std::thread::scope(|s| {
                let b1 = Arc::clone(&b);
                s.spawn(move || {
                    for i in 0..1000 {
                        b1.insert(&tr(i as f32));
                    }
                });
                let b2 = Arc::clone(&b);
                s.spawn(move || {
                    let mut rng = Rng::new(9);
                    let mut out = SampleBatch::default();
                    for _ in 0..200 {
                        if b2.sample(8, &mut rng, &mut out) {
                            let idx = out.indices.clone();
                            b2.update_priorities(&idx, &vec![0.3; idx.len()]);
                        }
                    }
                });
            });
            assert_eq!(b.len(), 256, "{}", b.name());
        }
    }
}
