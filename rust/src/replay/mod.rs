//! Replay buffers — the paper's core contribution plus every comparator.
//!
//! * [`PrioritizedReplay`] — K-ary sum tree, cache-aligned layout, lazy
//!   writing, two-lock synchronization (§IV).
//! * [`ShardedPrioritizedReplay`] — S independent shard primitives with
//!   actor-affinity insert routing, two-level sampling and batched
//!   priority feedback (the ROADMAP's sharding scaling lever).
//! * [`GlobalLockReplay`] — binary sum tree + one global lock (Fig 9
//!   baseline, RLlib-substitute framework buffer).
//! * [`UniformReplay`] — plain ring buffer, uniform sampling.
//! * [`NaiveScanReplay`] / [`PyBindBinaryReplay`] — emulations of the
//!   third-party buffers the paper plugs into (Fig 11).
//!
//! All implementations share the [`ReplayBuffer`] trait so the trainer,
//! the benches and the property tests are generic over them.

pub mod baseline;
pub mod emulated;
pub mod prioritized;
pub mod remover;
pub mod sharded;
pub mod snapshot;
pub mod storage;
pub mod sumtree;
pub mod uniform;

pub use baseline::{BinarySumTree, GlobalLockReplay};
pub use emulated::{NaiveScanReplay, PyBindBinaryReplay, PySumTreeReplay};
pub use prioritized::{LockStatsSnapshot, PrioritizedConfig, PrioritizedReplay};
pub use remover::{EvictReason, Remover, RemoverSpec};
pub use sharded::ShardedPrioritizedReplay;
pub use snapshot::{BufferState, ShardState};
pub use storage::{SampleBatch, Transition, TransitionStore};
pub use sumtree::KArySumTree;
pub use uniform::UniformReplay;

use crate::util::rng::Rng;
use anyhow::Result;

/// Importance weights for a sampled batch: is(i) = (N · Pr(i))^-β,
/// normalized by the batch max so the largest weight is 1 (Schaul et
/// al.; the paper's Alg 1 line 15 is the same quantity un-normalized).
/// Shared by the single-tree and sharded prioritized buffers so the
/// formula cannot silently diverge between them. Reads
/// `out.priorities`, fills `out.is_weights`.
pub(crate) fn fill_is_weights(out: &mut SampleBatch, n: f32, total: f32, beta: f32) {
    let total = total.max(f32::MIN_POSITIVE);
    let mut wmax = 0.0f32;
    for &p in &out.priorities {
        let pr = (p / total).max(f32::MIN_POSITIVE);
        let w = (n * pr).powf(-beta);
        out.is_weights.push(w);
        wmax = wmax.max(w);
    }
    if wmax > 0.0 {
        for w in &mut out.is_weights {
            *w /= wmax;
        }
    }
}

/// Common interface of every replay buffer in the crate.
///
/// All methods take `&self`: implementations are internally synchronized
/// so actors and learners can share one buffer behind an `Arc`.
pub trait ReplayBuffer: Send + Sync {
    /// Implementation name (used in bench output).
    fn name(&self) -> &'static str;

    /// Maximum number of transitions held.
    fn capacity(&self) -> usize;

    /// Current number of (fully inserted) transitions.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Insert attributed to a producer (actor) id — the REQUIRED insert
    /// entry point. Sharded buffers route on the id so concurrent
    /// actors hit disjoint shard locks; everything else ignores it.
    ///
    /// When the buffer is full the configured [`Remover`] picks the
    /// victim (FIFO by default, paper §IV-A1) and the reason is
    /// returned so tables can count evictions; `None` means no item was
    /// displaced.
    fn insert_from(&self, actor_id: usize, t: &Transition) -> Option<EvictReason>;

    /// Unattributed insert: delegates to [`Self::insert_from`] with
    /// actor 0 (round-robin impls may override).
    fn insert(&self, t: &Transition) -> Option<EvictReason> {
        self.insert_from(0, t)
    }

    /// Insert carrying an explicit initial priority — the state-merge
    /// path (a draining mesh server handing its items to a peer), where
    /// the item's learned priority must survive the move instead of
    /// resetting to the insert-time maximum. Implementations without a
    /// priority plane ignore the value and take the plain insert.
    fn insert_with_priority(
        &self,
        actor_id: usize,
        t: &Transition,
        priority: f32,
    ) -> Option<EvictReason> {
        let _ = priority;
        self.insert_from(actor_id, t)
    }

    /// The eviction policy this buffer runs when full.
    fn remover(&self) -> RemoverSpec {
        RemoverSpec::Fifo
    }

    /// Record that `indices` were handed to a learner — feeds the
    /// per-item sample counts behind `MaxTimesSampled` and the stats
    /// histogram max. Called by `Table::try_sample`; a no-op for
    /// buffers without sample-count tracking.
    fn note_sampled(&self, indices: &[usize]) {
        let _ = indices;
    }

    /// Largest per-item sample count currently held (0 when the buffer
    /// does not track counts) — the capacity-pressure signal surfaced
    /// in table stats.
    fn max_sample_count(&self) -> u32 {
        0
    }

    /// Draw `batch` transitions into `out` (cleared first). Returns false
    /// if the buffer is empty. Prioritized impls fill `priorities` and
    /// normalized `is_weights`.
    fn sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> bool;

    /// Feed back new |TD| errors for sampled indices (paper §IV-A4).
    fn update_priorities(&self, indices: &[usize], td_abs: &[f32]);

    /// Total sampleable priority mass — the quantity two-level sampling
    /// routes on (shard roots in-process, the `Mass` RPC across the
    /// replay mesh). Prioritized impls report their sum-tree root;
    /// the default equates mass with item count, which is exactly a
    /// uniform buffer's unnormalized probability mass.
    fn total_priority(&self) -> f32 {
        self.len() as f32
    }

    /// Capture a consistent, serializable [`BufferState`] (ring
    /// contents, leaf priorities, cursors, max priority). `None` when
    /// the implementation does not support checkpointing (the emulated
    /// comparison buffers); the training buffers (`pal-kary`,
    /// `pal-sharded`, `uniform-ring`) all support it.
    fn snapshot_state(&self) -> Option<BufferState> {
        None
    }

    /// Validate that `state` could be restored into this buffer without
    /// mutating anything. Callers restoring several buffers validate
    /// ALL of them first so a failure can never leave a service
    /// half-loaded.
    fn validate_state(&self, state: &BufferState) -> Result<()> {
        let _ = state;
        anyhow::bail!("buffer `{}` does not support checkpoint restore", self.name())
    }

    /// Restore a previously captured state, rebuilding every derived
    /// structure (interior sum-tree nodes are recomputed from the
    /// leaves, never trusted from the file). Fails cleanly — with the
    /// buffer untouched — on any mismatch or inconsistency.
    fn restore_state(&self, state: &BufferState) -> Result<()> {
        self.validate_state(state)
    }
}

#[cfg(test)]
mod trait_tests {
    //! Behavioural tests run against EVERY implementation.
    use super::*;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    /// Every remover policy the contract suite must hold under.
    const ALL_REMOVERS: [RemoverSpec; 4] = [
        RemoverSpec::Fifo,
        RemoverSpec::Lifo,
        RemoverSpec::LowestPriority,
        RemoverSpec::MaxTimesSampled(3),
    ];

    fn impls(capacity: usize) -> Vec<Arc<dyn ReplayBuffer>> {
        impls_with(capacity, RemoverSpec::Fifo)
    }

    fn impls_with(capacity: usize, remove: RemoverSpec) -> Vec<Arc<dyn ReplayBuffer>> {
        vec![
            Arc::new(PrioritizedReplay::with_remover(
                PrioritizedConfig {
                    capacity,
                    obs_dim: 2,
                    act_dim: 1,
                    fanout: 16,
                    alpha: 0.6,
                    beta: 0.4,
                    lazy_writing: true,
                    shards: 1,
                },
                remove,
            )),
            Arc::new(ShardedPrioritizedReplay::with_remover(
                PrioritizedConfig {
                    capacity,
                    obs_dim: 2,
                    act_dim: 1,
                    fanout: 16,
                    alpha: 0.6,
                    beta: 0.4,
                    lazy_writing: true,
                    shards: 4,
                },
                remove,
            )),
            Arc::new(GlobalLockReplay::with_remover(capacity, 2, 1, 0.6, 0.4, remove)),
            Arc::new(UniformReplay::with_remover(capacity, 2, 1, remove)),
            Arc::new(NaiveScanReplay::with_remover(capacity, 2, 1, 0.6, 0.4, remove)),
            Arc::new(PyBindBinaryReplay::with_remover(capacity, 2, 1, 0.6, 0.4, remove)),
            Arc::new(PySumTreeReplay::with_remover(capacity, 2, 1, 0.6, 0.4, remove)),
        ]
    }

    fn tr(v: f32) -> Transition {
        Transition {
            obs: vec![v, -v],
            action: vec![v],
            next_obs: vec![v + 1.0, -v],
            reward: v,
            done: v as i64 % 5 == 0,
        }
    }

    #[test]
    fn all_impls_basic_contract() {
        for spec in ALL_REMOVERS {
            basic_contract(spec);
        }
    }

    fn basic_contract(spec: RemoverSpec) {
        for b in impls_with(32, spec) {
            let who = format!("{} under {:?}", b.name(), spec);
            assert_eq!(b.remover(), spec, "{who}");
            assert!(b.is_empty(), "{who}");
            let mut rng = Rng::new(1);
            let mut out = SampleBatch::default();
            assert!(!b.sample(4, &mut rng, &mut out), "{who}");
            for i in 0..48 {
                b.insert(&tr(i as f32));
            }
            assert_eq!(b.len(), 32, "{who}");
            assert!(b.sample(16, &mut rng, &mut out), "{who}");
            assert_eq!(out.len(), 16, "{who}");
            assert_eq!(out.obs.len(), 32, "{who}");
            assert_eq!(out.is_weights.len(), 16, "{who}");
            // Sampled rows are self-consistent (obs[0] == reward by
            // construction) — catches torn batch assembly.
            for j in 0..16 {
                assert_eq!(out.obs[j * 2], out.reward[j], "{who}");
            }
            // Per-item sample counts tick for every impl and policy.
            b.note_sampled(&out.indices);
            assert!(b.max_sample_count() >= 1, "{who}");
            // Priority feedback must not panic and must keep sampling OK.
            let idx = out.indices.clone();
            b.update_priorities(&idx, &vec![0.7; idx.len()]);
            assert!(b.sample(8, &mut rng, &mut out), "{who}");
        }
    }

    #[test]
    fn insert_with_priority_carries_the_priority_where_supported() {
        for b in impls(32) {
            for i in 0..4 {
                b.insert(&tr(i as f32));
            }
            // A migrated item arrives with its learned (tiny) priority.
            b.insert_with_priority(1, &tr(99.0), 0.125);
            assert_eq!(b.len(), 5, "{}", b.name());
            let Some(state) = b.snapshot_state() else {
                continue; // emulated impls: plain-insert fallback is enough
            };
            // Find the migrated row (reward 99) and check its stored
            // priority: the prioritized impls must keep 0.125 instead of
            // resetting to the insert-time max; the uniform ring has no
            // priority plane, any positive weight is fine.
            let mut found = None;
            for shard in &state.shards {
                for (slot, row) in shard.rows.iter().enumerate() {
                    if (row.reward - 99.0).abs() < 1e-6 {
                        found = Some(shard.priorities[slot]);
                    }
                }
            }
            let found = found.unwrap_or_else(|| panic!("{}: migrated row not found", b.name()));
            match b.name() {
                "pal-kary" | "pal-sharded" => {
                    assert!((found - 0.125).abs() < 1e-6, "{}: got {found}", b.name())
                }
                _ => assert!(found > 0.0, "{}: got {found}", b.name()),
            }
        }
    }

    #[test]
    fn all_impls_survive_concurrent_use() {
        for spec in ALL_REMOVERS {
            concurrent_use(spec);
        }
    }

    fn concurrent_use(spec: RemoverSpec) {
        for b in impls_with(256, spec) {
            for i in 0..64 {
                b.insert(&tr(i as f32));
            }
            // Concurrent producers use `insert_from` with DISTINCT actor
            // ids: sharded buffers route them to disjoint shard locks
            // (ids 0..4 cover every shard of the 4-shard impl), everyone
            // else falls through to `insert` — either way the shard
            // routing runs under real contention here.
            std::thread::scope(|s| {
                for actor in 0..4usize {
                    let b1 = Arc::clone(&b);
                    s.spawn(move || {
                        for i in 0..500 {
                            b1.insert_from(actor, &tr(i as f32));
                        }
                    });
                }
                let b2 = Arc::clone(&b);
                s.spawn(move || {
                    let mut rng = Rng::new(9);
                    let mut out = SampleBatch::default();
                    for _ in 0..200 {
                        if b2.sample(8, &mut rng, &mut out) {
                            // Sample-count feedback races the inserts
                            // too, like `Table::try_sample` would.
                            b2.note_sampled(&out.indices);
                            let idx = out.indices.clone();
                            b2.update_priorities(&idx, &vec![0.3; idx.len()]);
                        }
                    }
                });
            });
            // 64 round-robin prefills + 500 affinity inserts per actor
            // overfill every shard, so every impl must sit exactly at
            // capacity.
            assert_eq!(b.len(), 256, "{} under {:?}", b.name(), spec);
        }
    }

    #[test]
    fn checkpointable_impls_roundtrip_exactly() {
        for spec in ALL_REMOVERS {
            checkpoint_roundtrip(spec);
        }
    }

    fn checkpoint_roundtrip(spec: RemoverSpec) {
        // Every impl that supports snapshotting must reproduce its
        // EXACT state when the snapshot is restored — even into a
        // buffer that has drifted since (restore must clear the drift).
        let mut supported = 0;
        for b in impls_with(32, spec) {
            for i in 0..20 {
                b.insert(&tr(i as f32));
            }
            b.update_priorities(&[2, 5, 9], &[3.0, 0.2, 7.5]);
            // Sample counts are part of the snapshot too.
            b.note_sampled(&[1, 3, 3]);
            let Some(s1) = b.snapshot_state() else {
                // Unsupported impls must fail restore cleanly too.
                let dummy = BufferState {
                    impl_name: b.name().to_string(),
                    capacity: b.capacity(),
                    obs_dim: 2,
                    act_dim: 1,
                    shards: vec![],
                };
                assert!(b.restore_state(&dummy).is_err(), "{}", b.name());
                continue;
            };
            supported += 1;
            assert_eq!(s1.len(), 20, "{}", b.name());
            assert_eq!(s1.impl_name, b.name());
            // Drift the buffer past the snapshot...
            for i in 0..30 {
                b.insert(&tr((100 + i) as f32));
            }
            b.update_priorities(&[0, 1], &[9.0, 9.0]);
            b.note_sampled(&[0, 2, 4]);
            // ...then restore and re-capture: states must be identical.
            b.restore_state(&s1).unwrap_or_else(|e| panic!("{}: {e}", b.name()));
            assert_eq!(b.len(), 20, "{}", b.name());
            let s2 = b.snapshot_state().unwrap();
            assert_eq!(s1, s2, "{}", b.name());
            // The restored buffer keeps working: sampling + feedback.
            let mut rng = Rng::new(11);
            let mut out = SampleBatch::default();
            assert!(b.sample(8, &mut rng, &mut out), "{}", b.name());
            let idx = out.indices.clone();
            b.update_priorities(&idx, &vec![0.4; idx.len()]);
        }
        assert_eq!(supported, 4, "pal-kary, pal-sharded, baseline and uniform");
    }
}
