//! Pluggable eviction ("remover") strategies for full replay buffers.
//!
//! Reverb ships selector-driven removers (FIFO, LIFO, lowest-priority,
//! max-times-sampled); this module is our equivalent. A [`RemoverSpec`]
//! names the policy, and a [`Remover`] carries the per-slot bookkeeping
//! every buffer implementation shares: per-item sample counts (fed by
//! `Table::try_sample` via `ReplayBuffer::note_sampled`) and, for
//! `MaxTimesSampled`, the queue of slots that have crossed their sample
//! budget and are "ripe" for eviction.
//!
//! Victim *selection* stays in each buffer implementation because it
//! needs access to the priority structure (e.g. the K-ary sum tree's
//! min tracking); the shared state here is only the policy + counts.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Result};

/// Which eviction policy a table runs when an insert finds it full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RemoverSpec {
    /// Evict the oldest item (the ring's implicit policy; the default).
    Fifo,
    /// Evict the newest item.
    Lifo,
    /// Evict the item with the lowest priority (FIFO tie-break where
    /// priorities are uniform).
    LowestPriority,
    /// Evict an item once it has been sampled at least `n` times,
    /// falling back to FIFO while no item is ripe.
    MaxTimesSampled(u32),
}

impl RemoverSpec {
    /// Parse a `remove=` option value: `fifo` | `lifo` | `lowest` |
    /// `max_sampled:N`.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fifo" => Ok(RemoverSpec::Fifo),
            "lifo" => Ok(RemoverSpec::Lifo),
            "lowest" | "lowest_priority" => Ok(RemoverSpec::LowestPriority),
            _ => {
                if let Some(n) = s.strip_prefix("max_sampled:") {
                    let n: u32 = n.parse().map_err(|_| {
                        anyhow::anyhow!("invalid max_sampled count `{n}` (expected a positive integer)")
                    })?;
                    if n == 0 {
                        bail!("max_sampled count must be >= 1");
                    }
                    Ok(RemoverSpec::MaxTimesSampled(n))
                } else {
                    bail!("unknown remover `{s}` (expected fifo | lifo | lowest | max_sampled:N)")
                }
            }
        }
    }

    /// The canonical spec string, i.e. the inverse of [`parse`](Self::parse).
    pub fn spec_str(&self) -> String {
        match self {
            RemoverSpec::Fifo => "fifo".to_string(),
            RemoverSpec::Lifo => "lifo".to_string(),
            RemoverSpec::LowestPriority => "lowest".to_string(),
            RemoverSpec::MaxTimesSampled(n) => format!("max_sampled:{n}"),
        }
    }

    /// Checkpoint encoding: a policy tag plus one u32 parameter.
    pub fn tag(&self) -> (u8, u32) {
        match self {
            RemoverSpec::Fifo => (0, 0),
            RemoverSpec::Lifo => (1, 0),
            RemoverSpec::LowestPriority => (2, 0),
            RemoverSpec::MaxTimesSampled(n) => (3, *n),
        }
    }

    /// Inverse of [`tag`](Self::tag), for checkpoint decode.
    pub fn from_tag(tag: u8, param: u32) -> Result<Self> {
        match tag {
            0 => Ok(RemoverSpec::Fifo),
            1 => Ok(RemoverSpec::Lifo),
            2 => Ok(RemoverSpec::LowestPriority),
            3 => {
                if param == 0 {
                    bail!("max_sampled remover tag carries count 0");
                }
                Ok(RemoverSpec::MaxTimesSampled(param))
            }
            _ => bail!("unknown remover tag {tag}"),
        }
    }
}

impl Default for RemoverSpec {
    fn default() -> Self {
        RemoverSpec::Fifo
    }
}

/// Why a particular victim was chosen, reported by
/// `ReplayBuffer::insert_from` so the table layer can count evictions
/// by reason. `None` from an insert means the buffer was not yet full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictReason {
    Fifo,
    Lifo,
    LowestPriority,
    MaxSampled,
}

/// Shared per-buffer remover state: the policy plus per-slot sample
/// counts. Counts are plain relaxed atomics so the sample hot path
/// never takes a lock; only the `MaxTimesSampled` ripe queue is
/// mutex-protected (touched once per budget crossing and per eviction).
pub struct Remover {
    spec: RemoverSpec,
    counts: Box<[AtomicU32]>,
    ripe: Mutex<VecDeque<usize>>,
}

impl Remover {
    pub fn new(spec: RemoverSpec, capacity: usize) -> Self {
        let counts = (0..capacity).map(|_| AtomicU32::new(0)).collect();
        Remover { spec, counts, ripe: Mutex::new(VecDeque::new()) }
    }

    pub fn spec(&self) -> RemoverSpec {
        self.spec
    }

    /// Record one sampled batch. Under `MaxTimesSampled(n)`, a slot
    /// whose count crosses `n` is enqueued as ripe exactly once per
    /// crossing; stale entries (the slot was since overwritten and its
    /// count reset) are filtered at [`pick_ripe`](Self::pick_ripe).
    pub fn note_sampled(&self, indices: &[usize]) {
        match self.spec {
            RemoverSpec::MaxTimesSampled(n) => {
                for &i in indices {
                    let prev = self.counts[i].fetch_add(1, Ordering::Relaxed);
                    if prev + 1 == n {
                        self.ripe.lock().unwrap().push_back(i);
                    }
                }
            }
            _ => {
                for &i in indices {
                    self.counts[i].fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// A slot was (re)written: its sample count starts over.
    pub fn on_insert(&self, slot: usize) {
        self.counts[slot].store(0, Ordering::Relaxed);
    }

    pub fn count(&self, slot: usize) -> u32 {
        self.counts[slot].load(Ordering::Relaxed)
    }

    /// Max sample count over the first `len` (occupied) slots.
    pub fn max_count(&self, len: usize) -> u32 {
        self.counts[..len.min(self.counts.len())]
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0)
    }

    /// Per-slot counts for the first `len` slots, in slot order (the
    /// checkpoint representation).
    pub fn counts_snapshot(&self, len: usize) -> Vec<u32> {
        self.counts[..len.min(self.counts.len())]
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Restore counts from a checkpoint (slots beyond `counts.len()`
    /// reset to 0) and rebuild the ripe queue in slot order.
    pub fn restore_counts(&self, counts: &[u32]) {
        for (i, c) in self.counts.iter().enumerate() {
            c.store(counts.get(i).copied().unwrap_or(0), Ordering::Relaxed);
        }
        let mut q = self.ripe.lock().unwrap();
        q.clear();
        if let RemoverSpec::MaxTimesSampled(n) = self.spec {
            for (i, &c) in counts.iter().enumerate() {
                if c >= n && i < self.counts.len() {
                    q.push_back(i);
                }
            }
        }
    }

    /// Pop the next ripe slot (sampled >= n times), skipping entries
    /// whose slot was overwritten since it was enqueued. `None` when no
    /// slot is ripe (callers fall back to FIFO) or the policy is not
    /// `MaxTimesSampled`.
    pub fn pick_ripe(&self) -> Option<usize> {
        let RemoverSpec::MaxTimesSampled(n) = self.spec else {
            return None;
        };
        let mut q = self.ripe.lock().unwrap();
        while let Some(i) = q.pop_front() {
            if self.counts[i].load(Ordering::Relaxed) >= n {
                return Some(i);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_roundtrips_and_rejects() {
        for s in ["fifo", "lifo", "lowest", "max_sampled:4"] {
            let spec = RemoverSpec::parse(s).unwrap();
            assert_eq!(spec.spec_str(), s);
            let (tag, param) = spec.tag();
            assert_eq!(RemoverSpec::from_tag(tag, param).unwrap(), spec);
        }
        assert_eq!(RemoverSpec::parse("lowest_priority").unwrap(), RemoverSpec::LowestPriority);
        assert!(RemoverSpec::parse("max_sampled:0").is_err());
        assert!(RemoverSpec::parse("max_sampled:x").is_err());
        let err = RemoverSpec::parse("rand").unwrap_err().to_string();
        assert!(err.contains("unknown remover"), "got: {err}");
        assert!(RemoverSpec::from_tag(9, 0).is_err());
        assert!(RemoverSpec::from_tag(3, 0).is_err());
    }

    #[test]
    fn ripe_queue_crossing_and_stale_filtering() {
        let r = Remover::new(RemoverSpec::MaxTimesSampled(2), 4);
        r.note_sampled(&[1, 1]); // slot 1 crosses n=2
        r.note_sampled(&[3]);
        assert_eq!(r.count(1), 2);
        assert_eq!(r.max_count(4), 2);
        // Slot 1 is ripe; overwrite it first so the entry goes stale.
        r.on_insert(1);
        assert_eq!(r.pick_ripe(), None);
        // Cross again: enqueued once, popped once.
        r.note_sampled(&[3, 3]); // slot 3 reaches 3 >= 2 (crossed at 2)
        assert_eq!(r.pick_ripe(), Some(3));
        assert_eq!(r.pick_ripe(), None);
    }

    #[test]
    fn restore_rebuilds_counts_and_ripe_queue() {
        let r = Remover::new(RemoverSpec::MaxTimesSampled(3), 4);
        r.note_sampled(&[0]);
        r.restore_counts(&[0, 3, 1]);
        assert_eq!(r.count(0), 0);
        assert_eq!(r.count(1), 3);
        assert_eq!(r.count(2), 1);
        assert_eq!(r.count(3), 0); // beyond the snapshot: reset
        assert_eq!(r.counts_snapshot(3), vec![0, 3, 1]);
        assert_eq!(r.pick_ripe(), Some(1));
        assert_eq!(r.pick_ripe(), None);
    }

    #[test]
    fn non_max_sampled_policies_still_count() {
        let r = Remover::new(RemoverSpec::Fifo, 2);
        r.note_sampled(&[0, 0, 1]);
        assert_eq!(r.count(0), 2);
        assert_eq!(r.count(1), 1);
        assert_eq!(r.pick_ripe(), None);
    }
}
