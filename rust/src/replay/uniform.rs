//! Uniform (non-prioritized) ring replay buffer.
//!
//! Used by the non-PER configurations (classic DQN/DDPG/SAC without
//! prioritization) and as a cost floor in the Fig 11 comparisons. Lock
//! strategy mirrors the paper's lazy writing: slot allocation is a single
//! atomic, the copy is lock-free, and a per-slot "ready" epoch keeps
//! half-written rows out of samples.

use super::remover::{EvictReason, Remover, RemoverSpec};
use super::snapshot::{BufferState, ShardState};
use super::storage::{SampleBatch, Transition, TransitionStore};
use super::ReplayBuffer;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};

pub struct UniformReplay {
    store: TransitionStore,
    /// Monotone insertion counter.
    cursor: AtomicUsize,
    /// Count of fully-written rows (monotone, saturates at capacity).
    ready: AtomicUsize,
    capacity: usize,
    /// Eviction policy + per-slot sample counts. All priorities are
    /// uniform here, so `LowestPriority` degenerates to the FIFO ring
    /// slot (the oldest item IS a lowest-priority item) while keeping
    /// its configured eviction reason.
    remover: Remover,
}

impl UniformReplay {
    pub fn new(capacity: usize, obs_dim: usize, act_dim: usize) -> Self {
        Self::with_remover(capacity, obs_dim, act_dim, RemoverSpec::Fifo)
    }

    /// Build with an explicit eviction policy.
    pub fn with_remover(
        capacity: usize,
        obs_dim: usize,
        act_dim: usize,
        remove: RemoverSpec,
    ) -> Self {
        Self {
            store: TransitionStore::new(capacity, obs_dim, act_dim),
            cursor: AtomicUsize::new(0),
            ready: AtomicUsize::new(0),
            capacity,
            remover: Remover::new(remove, capacity),
        }
    }
}

impl ReplayBuffer for UniformReplay {
    fn name(&self) -> &'static str {
        "uniform-ring"
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn len(&self) -> usize {
        self.ready.load(Ordering::Acquire).min(self.capacity)
    }

    fn insert_from(&self, _actor_id: usize, t: &Transition) -> Option<EvictReason> {
        let cur = self.cursor.fetch_add(1, Ordering::Relaxed);
        let (slot, reason) = if cur < self.capacity {
            (cur, None)
        } else {
            match self.remover.spec() {
                RemoverSpec::Fifo => (cur % self.capacity, Some(EvictReason::Fifo)),
                RemoverSpec::Lifo => (self.capacity - 1, Some(EvictReason::Lifo)),
                // Uniform priorities: the ring slot is the oldest of the
                // all-tied lowest-priority items.
                RemoverSpec::LowestPriority => {
                    (cur % self.capacity, Some(EvictReason::LowestPriority))
                }
                RemoverSpec::MaxTimesSampled(_) => match self.remover.pick_ripe() {
                    Some(slot) => (slot, Some(EvictReason::MaxSampled)),
                    None => (cur % self.capacity, Some(EvictReason::Fifo)),
                },
            }
        };
        self.store.write(slot, t);
        self.remover.on_insert(slot);
        self.ready.fetch_add(1, Ordering::Release);
        reason
    }

    fn sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> bool {
        out.clear();
        let n = self.len();
        if n == 0 || batch == 0 {
            return false;
        }
        for _ in 0..batch {
            let idx = rng.below_usize(n);
            out.indices.push(idx);
            out.priorities.push(1.0);
            out.is_weights.push(1.0);
            self.store.read_into(idx, out);
        }
        true
    }

    fn update_priorities(&self, _indices: &[usize], _td_abs: &[f32]) {
        // Uniform buffer ignores priorities.
    }

    /// One "shard": the ring contents in slot order plus the cursor.
    /// Priorities are recorded as 1.0 so the checkpoint's priority-mass
    /// accounting stays meaningful across buffer kinds.
    ///
    /// Lock-free, like everything else on this buffer: a row whose
    /// lazy copy is in flight at capture time may be captured torn —
    /// the same benign inconsistency live sampling accepts on this
    /// ring (see [`super::storage`]). The coordinator's end-of-run
    /// snapshot is quiescent and therefore exact; only mid-run
    /// `--checkpoint-every` captures carry the race, bounded by the
    /// number of in-flight inserts at that instant.
    fn snapshot_state(&self) -> Option<BufferState> {
        let cursor = self.cursor.load(Ordering::Acquire);
        let len = cursor.min(self.capacity);
        let rows = (0..len).map(|i| self.store.read(i)).collect();
        Some(BufferState {
            impl_name: self.name().to_string(),
            capacity: self.capacity,
            obs_dim: self.store.obs_dim(),
            act_dim: self.store.act_dim(),
            shards: vec![ShardState {
                cursor: cursor as u64,
                max_priority: 1.0,
                priorities: vec![1.0; len],
                sample_counts: self.remover.counts_snapshot(len),
                rows,
            }],
        })
    }

    fn remover(&self) -> RemoverSpec {
        self.remover.spec()
    }

    fn note_sampled(&self, indices: &[usize]) {
        self.remover.note_sampled(indices);
    }

    fn max_sample_count(&self) -> u32 {
        self.remover.max_count(self.len())
    }

    fn validate_state(&self, state: &BufferState) -> Result<()> {
        state.check_header(
            self.name(),
            self.capacity,
            self.store.obs_dim(),
            self.store.act_dim(),
            1,
        )?;
        state.shards[0].validate(
            self.name(),
            self.capacity,
            self.store.obs_dim(),
            self.store.act_dim(),
        )
    }

    fn restore_state(&self, state: &BufferState) -> Result<()> {
        self.validate_state(state)?;
        let shard = &state.shards[0];
        for (i, row) in shard.rows.iter().enumerate() {
            self.store.write(i, row);
        }
        self.cursor.store(shard.cursor as usize, Ordering::Release);
        self.remover.restore_counts(&shard.sample_counts);
        // All restored rows are fully written; `ready` mirrors the
        // cursor so `len()` reports them (it saturates at capacity).
        self.ready.store(shard.cursor as usize, Ordering::Release);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_and_wraps() {
        let b = UniformReplay::new(4, 1, 1);
        for i in 0..10 {
            b.insert(&Transition {
                obs: vec![i as f32],
                action: vec![0.0],
                next_obs: vec![0.0],
                reward: i as f32,
                done: false,
            });
        }
        assert_eq!(b.len(), 4);
        let mut rng = Rng::new(0);
        let mut out = SampleBatch::default();
        assert!(b.sample(16, &mut rng, &mut out));
        assert!(out.is_weights.iter().all(|&w| w == 1.0));
        assert!(out.reward.iter().all(|&r| r >= 6.0));
    }

    #[test]
    fn empty_sample_false() {
        let b = UniformReplay::new(4, 1, 1);
        let mut rng = Rng::new(0);
        let mut out = SampleBatch::default();
        assert!(!b.sample(2, &mut rng, &mut out));
    }

    #[test]
    fn snapshot_restores_wrapped_ring_exactly() {
        let b = UniformReplay::new(4, 1, 1);
        for i in 0..6 {
            b.insert(&Transition {
                obs: vec![i as f32],
                action: vec![0.0],
                next_obs: vec![0.0],
                reward: i as f32,
                done: false,
            });
        }
        let s = b.snapshot_state().unwrap();
        assert_eq!(s.shards[0].cursor, 6);
        assert_eq!(s.len(), 4);
        // Slot order after wrap: 4, 5, 2, 3.
        assert_eq!(s.shards[0].rows[0].reward, 4.0);
        assert_eq!(s.shards[0].rows[2].reward, 2.0);
        let fresh = UniformReplay::new(4, 1, 1);
        fresh.restore_state(&s).unwrap();
        assert_eq!(fresh.len(), 4);
        assert_eq!(fresh.snapshot_state().unwrap(), s);
        // FIFO continues at the right slot: next insert lands in slot 2.
        fresh.insert(&Transition {
            obs: vec![9.0],
            action: vec![0.0],
            next_obs: vec![0.0],
            reward: 9.0,
            done: false,
        });
        assert_eq!(fresh.store.read(2).reward, 9.0);
        // Mismatched geometry is rejected.
        let wrong = UniformReplay::new(8, 1, 1);
        assert!(wrong.restore_state(&s).is_err());
    }

    #[test]
    fn lifo_and_max_sampled_removers_on_the_ring() {
        let tr = |v: f32| Transition {
            obs: vec![v],
            action: vec![0.0],
            next_obs: vec![0.0],
            reward: v,
            done: false,
        };
        let b = UniformReplay::with_remover(4, 1, 1, RemoverSpec::Lifo);
        assert_eq!(b.remover(), RemoverSpec::Lifo);
        for i in 0..6 {
            b.insert(&tr(i as f32));
        }
        assert_eq!(b.len(), 4);
        // Items 4 and 5 both displaced the newest slot (3).
        let rewards: Vec<f32> = (0..4).map(|i| b.store.read(i).reward).collect();
        assert_eq!(rewards, vec![0.0, 1.0, 2.0, 5.0]);

        let m = UniformReplay::with_remover(4, 1, 1, RemoverSpec::MaxTimesSampled(2));
        for i in 0..4 {
            m.insert(&tr(i as f32));
        }
        m.note_sampled(&[1, 1]);
        assert_eq!(m.max_sample_count(), 2);
        assert_eq!(m.insert(&tr(7.0)), Some(EvictReason::MaxSampled));
        assert_eq!(m.store.read(1).reward, 7.0);
        // Ripe queue drained: the next eviction falls back to the ring
        // (cursor 5 -> slot 1).
        assert_eq!(m.insert(&tr(8.0)), Some(EvictReason::Fifo));
        assert_eq!(m.store.read(1).reward, 8.0);
    }
}
