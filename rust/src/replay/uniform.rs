//! Uniform (non-prioritized) ring replay buffer.
//!
//! Used by the non-PER configurations (classic DQN/DDPG/SAC without
//! prioritization) and as a cost floor in the Fig 11 comparisons. Lock
//! strategy mirrors the paper's lazy writing: slot allocation is a single
//! atomic, the copy is lock-free, and a per-slot "ready" epoch keeps
//! half-written rows out of samples.

use super::storage::{SampleBatch, Transition, TransitionStore};
use super::ReplayBuffer;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};

pub struct UniformReplay {
    store: TransitionStore,
    /// Monotone insertion counter.
    cursor: AtomicUsize,
    /// Count of fully-written rows (monotone, saturates at capacity).
    ready: AtomicUsize,
    capacity: usize,
}

impl UniformReplay {
    pub fn new(capacity: usize, obs_dim: usize, act_dim: usize) -> Self {
        Self {
            store: TransitionStore::new(capacity, obs_dim, act_dim),
            cursor: AtomicUsize::new(0),
            ready: AtomicUsize::new(0),
            capacity,
        }
    }
}

impl ReplayBuffer for UniformReplay {
    fn name(&self) -> &'static str {
        "uniform-ring"
    }

    fn capacity(&self) -> usize {
        self.capacity
    }

    fn len(&self) -> usize {
        self.ready.load(Ordering::Acquire).min(self.capacity)
    }

    fn insert(&self, t: &Transition) {
        let slot = self.cursor.fetch_add(1, Ordering::Relaxed) % self.capacity;
        self.store.write(slot, t);
        self.ready.fetch_add(1, Ordering::Release);
    }

    fn sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> bool {
        out.clear();
        let n = self.len();
        if n == 0 || batch == 0 {
            return false;
        }
        for _ in 0..batch {
            let idx = rng.below_usize(n);
            out.indices.push(idx);
            out.priorities.push(1.0);
            out.is_weights.push(1.0);
            self.store.read_into(idx, out);
        }
        true
    }

    fn update_priorities(&self, _indices: &[usize], _td_abs: &[f32]) {
        // Uniform buffer ignores priorities.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_and_wraps() {
        let b = UniformReplay::new(4, 1, 1);
        for i in 0..10 {
            b.insert(&Transition {
                obs: vec![i as f32],
                action: vec![0.0],
                next_obs: vec![0.0],
                reward: i as f32,
                done: false,
            });
        }
        assert_eq!(b.len(), 4);
        let mut rng = Rng::new(0);
        let mut out = SampleBatch::default();
        assert!(b.sample(16, &mut rng, &mut out));
        assert!(out.is_weights.iter().all(|&w| w == 1.0));
        assert!(out.reward.iter().all(|&r| r >= 6.0));
    }

    #[test]
    fn empty_sample_false() {
        let b = UniformReplay::new(4, 1, 1);
        let mut rng = Rng::new(0);
        let mut out = SampleBatch::default();
        assert!(!b.sample(2, &mut rng, &mut out));
    }
}
