//! Transition storage for replay buffers.
//!
//! Structure-of-arrays, fixed row width, f32 everywhere (discrete actions
//! are stored as their index in f32 — the learn graphs cast back). Cells
//! are `AtomicU32` f32 bits with `Relaxed` ordering: the paper's *lazy
//! writing* protocol (§IV-D2) copies transition rows WITHOUT holding the
//! tree locks, relying on the zero-priority guard to keep half-written
//! rows out of sampling. A concurrent eviction can still race a reader on
//! the same slot (the paper accepts this as a benign inconsistency,
//! §IV-D3); atomics make that defined behaviour at zero cost on x86-64.

use crate::util::aligned::AlignedBox;
use std::sync::atomic::{AtomicU32, Ordering};

/// One transition as produced by an actor.
#[derive(Clone, Debug, PartialEq)]
pub struct Transition {
    pub obs: Vec<f32>,
    pub action: Vec<f32>,
    pub next_obs: Vec<f32>,
    pub reward: f32,
    pub done: bool,
}

impl Transition {
    /// Flat row width for the given dims: obs + action + next_obs + reward + done.
    pub fn row_width(obs_dim: usize, act_dim: usize) -> usize {
        2 * obs_dim + act_dim + 2
    }
}

/// A batch of transitions in flat SoA form, ready for literal conversion.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SampleBatch {
    pub indices: Vec<usize>,
    pub priorities: Vec<f32>,
    /// Importance weights (empty for uniform buffers).
    pub is_weights: Vec<f32>,
    pub obs: Vec<f32>,
    pub action: Vec<f32>,
    pub next_obs: Vec<f32>,
    pub reward: Vec<f32>,
    pub done: Vec<f32>,
}

impl SampleBatch {
    pub fn with_capacity(batch: usize, obs_dim: usize, act_dim: usize) -> Self {
        Self {
            indices: Vec::with_capacity(batch),
            priorities: Vec::with_capacity(batch),
            is_weights: Vec::with_capacity(batch),
            obs: Vec::with_capacity(batch * obs_dim),
            action: Vec::with_capacity(batch * act_dim),
            next_obs: Vec::with_capacity(batch * obs_dim),
            reward: Vec::with_capacity(batch),
            done: Vec::with_capacity(batch),
        }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    pub fn clear(&mut self) {
        self.indices.clear();
        self.priorities.clear();
        self.is_weights.clear();
        self.obs.clear();
        self.action.clear();
        self.next_obs.clear();
        self.reward.clear();
        self.done.clear();
    }
}

/// SoA storage of `capacity` transitions.
pub struct TransitionStore {
    obs_dim: usize,
    act_dim: usize,
    capacity: usize,
    obs: AlignedBox<AtomicU32>,
    action: AlignedBox<AtomicU32>,
    next_obs: AlignedBox<AtomicU32>,
    reward: AlignedBox<AtomicU32>,
    done: AlignedBox<AtomicU32>,
}

#[inline(always)]
fn put(dst: &[AtomicU32], src: &[f32]) {
    for (d, s) in dst.iter().zip(src) {
        d.store(s.to_bits(), Ordering::Relaxed);
    }
}

#[inline(always)]
fn get_into(src: &[AtomicU32], dst: &mut Vec<f32>) {
    for s in src {
        dst.push(f32::from_bits(s.load(Ordering::Relaxed)));
    }
}

impl TransitionStore {
    pub fn new(capacity: usize, obs_dim: usize, act_dim: usize) -> Self {
        assert!(capacity > 0 && obs_dim > 0 && act_dim > 0);
        Self {
            obs_dim,
            act_dim,
            capacity,
            obs: AlignedBox::zeroed(capacity * obs_dim),
            action: AlignedBox::zeroed(capacity * act_dim),
            next_obs: AlignedBox::zeroed(capacity * obs_dim),
            reward: AlignedBox::zeroed(capacity),
            done: AlignedBox::zeroed(capacity),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn obs_dim(&self) -> usize {
        self.obs_dim
    }

    pub fn act_dim(&self) -> usize {
        self.act_dim
    }

    /// Write a full transition row. This is the explicit memory copy the
    /// paper moves OUTSIDE the lock via lazy writing.
    pub fn write(&self, idx: usize, t: &Transition) {
        debug_assert!(idx < self.capacity);
        debug_assert_eq!(t.obs.len(), self.obs_dim);
        debug_assert_eq!(t.action.len(), self.act_dim);
        debug_assert_eq!(t.next_obs.len(), self.obs_dim);
        let (od, ad) = (self.obs_dim, self.act_dim);
        put(&self.obs[idx * od..(idx + 1) * od], &t.obs);
        put(&self.action[idx * ad..(idx + 1) * ad], &t.action);
        put(&self.next_obs[idx * od..(idx + 1) * od], &t.next_obs);
        self.reward[idx].store(t.reward.to_bits(), Ordering::Relaxed);
        self.done[idx].store((t.done as u32 as f32).to_bits(), Ordering::Relaxed);
    }

    /// Append row `idx` to a batch (flat SoA).
    pub fn read_into(&self, idx: usize, out: &mut SampleBatch) {
        debug_assert!(idx < self.capacity);
        let (od, ad) = (self.obs_dim, self.act_dim);
        get_into(&self.obs[idx * od..(idx + 1) * od], &mut out.obs);
        get_into(&self.action[idx * ad..(idx + 1) * ad], &mut out.action);
        get_into(&self.next_obs[idx * od..(idx + 1) * od], &mut out.next_obs);
        out.reward
            .push(f32::from_bits(self.reward[idx].load(Ordering::Relaxed)));
        out.done
            .push(f32::from_bits(self.done[idx].load(Ordering::Relaxed)));
    }

    /// Read a single transition back (tests / tooling).
    pub fn read(&self, idx: usize) -> Transition {
        let mut b = SampleBatch::default();
        self.read_into(idx, &mut b);
        Transition {
            obs: b.obs,
            action: b.action,
            next_obs: b.next_obs,
            reward: b.reward[0],
            done: b.done[0] != 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: f32) -> Transition {
        Transition {
            obs: vec![v, v + 1.0],
            action: vec![v * 10.0],
            next_obs: vec![v + 2.0, v + 3.0],
            reward: -v,
            done: v as usize % 2 == 0,
        }
    }

    #[test]
    fn write_read_roundtrip() {
        let s = TransitionStore::new(8, 2, 1);
        for i in 0..8 {
            s.write(i, &t(i as f32));
        }
        for i in 0..8 {
            assert_eq!(s.read(i), t(i as f32));
        }
    }

    #[test]
    fn overwrite_slot() {
        let s = TransitionStore::new(4, 2, 1);
        s.write(2, &t(1.0));
        s.write(2, &t(9.0));
        assert_eq!(s.read(2), t(9.0));
    }

    #[test]
    fn batch_assembly_flat_layout() {
        let s = TransitionStore::new(4, 2, 1);
        for i in 0..4 {
            s.write(i, &t(i as f32));
        }
        let mut b = SampleBatch::with_capacity(2, 2, 1);
        s.read_into(3, &mut b);
        s.read_into(1, &mut b);
        assert_eq!(b.obs, vec![3.0, 4.0, 1.0, 2.0]);
        assert_eq!(b.action, vec![30.0, 10.0]);
        assert_eq!(b.reward, vec![-3.0, -1.0]);
        assert_eq!(b.done, vec![0.0, 0.0]);
    }
}
