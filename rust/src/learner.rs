//! Parallel learner (paper §V-B): draws a rate-limited batch from the
//! replay service, computes sub-gradients through the compiled learn
//! graph(s), pushes them to the parameter server and feeds |TD| back as
//! new priorities (Algorithm 1 lines 12–18).
//!
//! The warmup and ratio gates that used to live here are now the
//! sampled table's rate limiter: [`SamplerHandle::try_sample`] denies a
//! batch while the table is below `min_size_to_sample` or consumption
//! would run past the configured sample-to-insert ratio, and the
//! learner sleep-polls on the denial.

use crate::actor::Control;
use crate::agent::Agent;
use crate::metrics::Metrics;
use crate::params::ParameterServer;
use crate::replay::SampleBatch;
use crate::service::{ExperienceSampler, SampleOutcome};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::time::Duration;

/// Learner main loop. Pacing (warmup + sample-to-insert ratio) comes
/// entirely from the sampled table's limiter, whether the table is
/// in-process ([`crate::service::SamplerHandle`]) or behind a socket
/// ([`crate::remote::RemoteSampler`]) — a stalled remote sample is a
/// retriable `WouldStall` frame, polled exactly like a local denial.
pub fn run_learner(
    learner_id: usize,
    agent: &mut Agent,
    sampler: &mut dyn ExperienceSampler,
    server: &ParameterServer,
    metrics: &Metrics,
    ctl: &Control,
    rng: &mut Rng,
) -> Result<()> {
    let batch_size = agent.model.info.batch_size;
    let obs_dim = agent.model.info.obs_dim;
    let act_dim = agent.model.info.flat_act_dim;
    let mut batch = SampleBatch::with_capacity(batch_size, obs_dim, act_dim);
    let mut params: Vec<f32> = Vec::new();
    let mut targets: Vec<f32> = Vec::new();
    let mut version = 0u64;
    let _ = learner_id;

    loop {
        if ctl.should_stop() {
            break;
        }
        match sampler.try_sample(batch_size, rng, &mut batch)? {
            SampleOutcome::Sampled => {}
            SampleOutcome::Throttled | SampleOutcome::NotEnoughData => {
                // Collection can no longer catch up once the env-step
                // budget is spent: drain out instead of spinning.
                if ctl.budget_exhausted() {
                    break;
                }
                std::thread::sleep(Duration::from_micros(150));
                continue;
            }
        }
        ctl.learn_steps.fetch_add(1, Ordering::Relaxed);

        version = server.sync_pair(&mut params, &mut targets, version);
        let out = agent.learn(&params, &targets, &batch, rng)?;
        for u in &out.updates {
            server.push_gradient(u.lo, u.hi, &u.grads);
        }
        metrics.grad_updates.fetch_add(out.updates.len(), Ordering::Relaxed);
        if !out.td_abs.is_empty() {
            sampler.update_priorities(&batch.indices, &out.td_abs)?;
        }
        metrics.record_learn(out.loss);
    }
    // A pipelined remote sampler may still have a prefetched batch in
    // flight; consume it so the connection closes on a frame boundary
    // instead of abandoning a response mid-stream.
    sampler.finish()?;
    Ok(())
}
