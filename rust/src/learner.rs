//! Parallel learner (paper §V-B): samples a batch from the shared
//! prioritized buffer, computes sub-gradients through the compiled learn
//! graph(s), pushes them to the parameter server and feeds |TD| back as
//! new priorities (Algorithm 1 lines 12–18).

use crate::actor::Control;
use crate::agent::Agent;
use crate::metrics::Metrics;
use crate::params::ParameterServer;
use crate::replay::{ReplayBuffer, SampleBatch};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::time::Duration;

/// Learner main loop. Paces itself so that
/// `learn_steps * update_interval <= env_steps` (the desired collection/
/// consumption ratio of §V-D), with warmup gating on buffer fill.
pub fn run_learner(
    learner_id: usize,
    agent: &mut Agent,
    buffer: &dyn ReplayBuffer,
    server: &ParameterServer,
    metrics: &Metrics,
    ctl: &Control,
    rng: &mut Rng,
) -> Result<()> {
    let batch_size = agent.model.info.batch_size;
    let obs_dim = agent.model.info.obs_dim;
    let act_dim = agent.model.info.flat_act_dim;
    let mut batch = SampleBatch::with_capacity(batch_size, obs_dim, act_dim);
    let mut params: Vec<f32> = Vec::new();
    let mut targets: Vec<f32> = Vec::new();
    let mut version = 0u64;
    let _ = learner_id;

    loop {
        if ctl.should_stop() {
            break;
        }
        // Warmup: wait for enough data.
        if buffer.len() < ctl.warmup_steps.max(batch_size) {
            std::thread::sleep(Duration::from_micros(200));
            continue;
        }
        // Ratio pacing (Alg 1 update_interval, Eq. 5 objective).
        let env_steps = ctl.env_steps.load(Ordering::Relaxed);
        let learn_steps = ctl.learn_steps.load(Ordering::Relaxed);
        if (learn_steps as f64 + 1.0) * ctl.update_interval > env_steps as f64 {
            // Collection is behind; actors still running => wait, else stop.
            if env_steps >= ctl.max_env_steps {
                break;
            }
            std::thread::sleep(Duration::from_micros(100));
            continue;
        }
        ctl.learn_steps.fetch_add(1, Ordering::Relaxed);

        version = server.sync_pair(&mut params, &mut targets, version);
        if !buffer.sample(batch_size, rng, &mut batch) {
            std::thread::sleep(Duration::from_micros(200));
            continue;
        }
        let out = agent.learn(&params, &targets, &batch, rng)?;
        for u in &out.updates {
            server.push_gradient(u.lo, u.hi, &u.grads);
        }
        metrics.grad_updates.fetch_add(out.updates.len(), Ordering::Relaxed);
        if !out.td_abs.is_empty() {
            buffer.update_priorities(&batch.indices, &out.td_abs);
        }
        metrics.record_learn(out.loss);
    }
    Ok(())
}
