//! Training metrics: lock-free counters shared by actors/learners plus a
//! CSV curve logger for the examples and EXPERIMENTS.md plots.

use crate::util::stats::Ema;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Shared, internally-synchronized metrics hub.
pub struct Metrics {
    start: Instant,
    pub env_steps: AtomicUsize,
    pub learn_steps: AtomicUsize,
    pub episodes: AtomicUsize,
    pub grad_updates: AtomicUsize,
    pub param_syncs: AtomicUsize,
    /// f64 bits of the most recent loss (learner side).
    last_loss_bits: AtomicU64,
    inner: Mutex<Inner>,
}

struct Inner {
    /// Recent episode returns (bounded window).
    returns: VecDeque<f32>,
    return_ema: Ema,
    loss_ema: Ema,
    /// (wall_secs, env_steps, learn_steps, episode_return) samples.
    curve: Vec<CurvePoint>,
}

/// One logged point on the training curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub wall_secs: f64,
    pub env_steps: usize,
    pub learn_steps: usize,
    pub episode_return: f32,
    pub loss_ema: f64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            start: Instant::now(),
            env_steps: AtomicUsize::new(0),
            learn_steps: AtomicUsize::new(0),
            episodes: AtomicUsize::new(0),
            grad_updates: AtomicUsize::new(0),
            param_syncs: AtomicUsize::new(0),
            last_loss_bits: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                returns: VecDeque::with_capacity(128),
                return_ema: Ema::new(0.05),
                loss_ema: Ema::new(0.01),
                curve: Vec::new(),
            }),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Actor: one environment step taken.
    #[inline]
    pub fn inc_env_step(&self) {
        self.env_steps.fetch_add(1, Ordering::Relaxed);
    }

    /// Actor: an episode finished with this return.
    pub fn record_episode(&self, ret: f32) {
        self.episodes.fetch_add(1, Ordering::Relaxed);
        let mut g = self.inner.lock().unwrap();
        if g.returns.len() == 128 {
            g.returns.pop_front();
        }
        g.returns.push_back(ret);
        g.return_ema.push(ret as f64);
        let point = CurvePoint {
            wall_secs: self.start.elapsed().as_secs_f64(),
            env_steps: self.env_steps.load(Ordering::Relaxed),
            learn_steps: self.learn_steps.load(Ordering::Relaxed),
            episode_return: ret,
            loss_ema: g.loss_ema.get().unwrap_or(f64::NAN),
        };
        g.curve.push(point);
    }

    /// Learner: one learn step with this loss.
    pub fn record_learn(&self, loss: f32) {
        self.learn_steps.fetch_add(1, Ordering::Relaxed);
        self.last_loss_bits
            .store((loss as f64).to_bits(), Ordering::Relaxed);
        let mut g = self.inner.lock().unwrap();
        g.loss_ema.push(loss as f64);
    }

    pub fn last_loss(&self) -> f64 {
        f64::from_bits(self.last_loss_bits.load(Ordering::Relaxed))
    }

    /// Mean of the recent episode-return window.
    pub fn mean_return(&self) -> Option<f64> {
        let g = self.inner.lock().unwrap();
        if g.returns.is_empty() {
            return None;
        }
        Some(g.returns.iter().map(|&r| r as f64).sum::<f64>() / g.returns.len() as f64)
    }

    pub fn return_ema(&self) -> Option<f64> {
        self.inner.lock().unwrap().return_ema.get()
    }

    pub fn loss_ema(&self) -> Option<f64> {
        self.inner.lock().unwrap().loss_ema.get()
    }

    /// Snapshot of the full training curve.
    pub fn curve(&self) -> Vec<CurvePoint> {
        self.inner.lock().unwrap().curve.clone()
    }

    /// Steps/sec since start.
    pub fn env_throughput(&self) -> f64 {
        self.env_steps.load(Ordering::Relaxed) as f64 / self.elapsed_secs().max(1e-9)
    }

    pub fn learn_throughput(&self) -> f64 {
        self.learn_steps.load(Ordering::Relaxed) as f64 / self.elapsed_secs().max(1e-9)
    }

    /// Write the curve as CSV (`wall_secs,env_steps,learn_steps,return,loss_ema`).
    pub fn write_curve_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "wall_secs,env_steps,learn_steps,episode_return,loss_ema")?;
        for p in self.curve() {
            writeln!(
                f,
                "{:.3},{},{},{},{}",
                p.wall_secs, p.env_steps, p.learn_steps, p.episode_return, p.loss_ema
            )?;
        }
        Ok(())
    }

    /// One-line progress summary.
    pub fn summary(&self) -> String {
        format!(
            "steps={} learn={} episodes={} ret~{:.1} loss~{:.4} {:.0} env/s {:.0} learn/s",
            self.env_steps.load(Ordering::Relaxed),
            self.learn_steps.load(Ordering::Relaxed),
            self.episodes.load(Ordering::Relaxed),
            self.return_ema().unwrap_or(f64::NAN),
            self.loss_ema().unwrap_or(f64::NAN),
            self.env_throughput(),
            self.learn_throughput(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_window() {
        let m = Metrics::new();
        for i in 0..200 {
            m.inc_env_step();
            if i % 10 == 0 {
                m.record_episode(i as f32);
            }
        }
        m.record_learn(0.5);
        assert_eq!(m.env_steps.load(Ordering::Relaxed), 200);
        assert_eq!(m.episodes.load(Ordering::Relaxed), 20);
        assert_eq!(m.learn_steps.load(Ordering::Relaxed), 1);
        assert!(m.mean_return().unwrap() > 0.0);
        assert_eq!(m.curve().len(), 20);
        assert!((m.last_loss() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn csv_roundtrip() {
        let m = Metrics::new();
        m.record_episode(1.5);
        m.record_episode(2.5);
        let path = std::env::temp_dir().join("pal_metrics_test.csv");
        m.write_curve_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.lines().nth(1).unwrap().contains("1.5"));
        std::fs::remove_file(path).ok();
    }
}
