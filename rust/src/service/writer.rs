//! Actor-side trajectory writers (Reverb's `TrajectoryWriter` /
//! Spreeze-style high-throughput collection): accumulate the steps of
//! the current episode and emit finished *items* into one or more
//! tables.
//!
//! Item shapes ([`ItemKind`]):
//!
//! * **1-step** — every step verbatim; byte-for-byte the legacy
//!   `buffer.insert_from` path (the parity configuration of
//!   `benches/fig_service.rs`).
//! * **N-step** — sliding window with discounted reward folding:
//!   the item starting at step *j* carries
//!   `reward = Σ_{k<m} γᵏ · r_{j+k}`, `obs/action` from step *j*,
//!   `next_obs` from step *j+m−1*, where `m = n` for interior items. At
//!   an episode boundary the partial tails (`m < n`) are flushed, so
//!   every step starts exactly one item and no window ever folds
//!   rewards across episodes — the writer clears its step buffer at
//!   every boundary, making cross-episode leakage structurally
//!   impossible.
//! * **Sequence** — fixed-length, non-overlapping windows of L steps,
//!   flattened along the feature axis (the table's dims are the base
//!   dims × L). Partial windows at episode end are dropped and counted
//!   (`dropped_partial`), never zero-padded.
//!
//! Truncation is not a true terminal: items whose window ends on a
//! truncated step keep `done = false` so learners bootstrap through it
//! (same rule the actor loop applied before the service existed).

use super::table::Table;
use crate::replay::Transition;
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::sync::Arc;

/// What kind of items a table stores / a writer emits into it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ItemKind {
    /// Plain transitions, one per env step.
    OneStep,
    /// N-step transitions with discounted reward folding.
    NStep { n: usize, gamma: f32 },
    /// Fixed-length flattened step sequences (table dims = base × len).
    Sequence { len: usize },
}

impl ItemKind {
    /// Parse a table-spec kind: `1step`, `nstep:N` (γ supplied by the
    /// run's `--gamma-nstep`), or `seq:L`.
    pub fn parse(s: &str, gamma: f32) -> Result<Self> {
        if s == "1step" || s == "onestep" {
            return Ok(ItemKind::OneStep);
        }
        if let Some(n) = s.strip_prefix("nstep:") {
            let n: usize = n
                .parse()
                .map_err(|_| anyhow!("bad nstep length in table kind `{s}`"))?;
            if n == 0 {
                bail!("nstep length must be >= 1 in `{s}`");
            }
            return Ok(ItemKind::NStep { n, gamma });
        }
        if let Some(l) = s.strip_prefix("seq:") {
            let len: usize = l
                .parse()
                .map_err(|_| anyhow!("bad sequence length in table kind `{s}`"))?;
            if len == 0 {
                bail!("sequence length must be >= 1 in `{s}`");
            }
            return Ok(ItemKind::Sequence { len });
        }
        bail!("unknown table kind `{s}` (expected 1step | nstep:N | seq:L)")
    }

    /// Canonical spec tag (`1step`, `nstep:N`, `seq:L`) — what
    /// [`Self::parse`] accepts, minus γ (which is run configuration,
    /// not table identity). Used by checkpoint restore to verify a
    /// state file is being loaded into a table of the same shape.
    pub fn tag(&self) -> String {
        match *self {
            ItemKind::OneStep => "1step".to_string(),
            ItemKind::NStep { n, .. } => format!("nstep:{n}"),
            ItemKind::Sequence { len } => format!("seq:{len}"),
        }
    }

    /// How many steps one item spans (the writer's retention window).
    pub fn span(&self) -> usize {
        match *self {
            ItemKind::OneStep => 1,
            ItemKind::NStep { n, .. } => n,
            ItemKind::Sequence { len } => len,
        }
    }

    /// Multiplier on the base obs/action dims of the table storing this
    /// kind (sequences flatten L steps into one row).
    pub fn dim_multiplier(&self) -> usize {
        match *self {
            ItemKind::Sequence { len } => len,
            _ => 1,
        }
    }
}

/// One raw env step as the actor observed it. Unlike
/// [`Transition`], truncation is kept separate from termination — the
/// writer owns the bootstrap-through-truncation rule.
#[derive(Clone, Debug, PartialEq)]
pub struct WriterStep {
    pub obs: Vec<f32>,
    pub action: Vec<f32>,
    pub next_obs: Vec<f32>,
    pub reward: f32,
    pub done: bool,
    pub truncated: bool,
}

#[inline]
fn done_flag(s: &WriterStep) -> bool {
    s.done && !s.truncated
}

/// Per-actor writer handle over the tables of a service. Single-owner
/// (`&mut self`): each actor thread holds its own writer; all sharing
/// happens inside the tables.
pub struct TrajectoryWriter {
    actor_id: usize,
    tables: Vec<Arc<Table>>,
    /// Steps of the CURRENT episode, most recent last, capped at the
    /// longest span any sink needs. Cleared at every episode boundary.
    window: VecDeque<WriterStep>,
    max_span: usize,
    /// Steps appended in the current episode (can exceed `window.len()`).
    ep_len: usize,
    items_emitted: u64,
    dropped_partial: u64,
}

impl TrajectoryWriter {
    pub fn new(actor_id: usize, tables: Vec<Arc<Table>>) -> Self {
        let max_span = tables.iter().map(|t| t.kind().span()).max().unwrap_or(1);
        Self {
            actor_id,
            tables,
            window: VecDeque::with_capacity(max_span),
            max_span,
            ep_len: 0,
            items_emitted: 0,
            dropped_partial: 0,
        }
    }

    pub fn actor_id(&self) -> usize {
        self.actor_id
    }

    /// Items emitted across all tables so far.
    pub fn items_emitted(&self) -> u64 {
        self.items_emitted
    }

    /// Partial sequence windows dropped at episode boundaries.
    pub fn dropped_partial(&self) -> u64 {
        self.dropped_partial
    }

    /// True while any target table's rate limiter denies inserts; the
    /// actor loop sleep-polls on this exactly like the old
    /// `Control::actors_ahead` gate.
    pub fn throttled(&self) -> bool {
        self.tables.iter().any(|t| !t.can_insert())
    }

    /// Append one step; emit every item it completes. Episode
    /// boundaries (`done || truncated`) flush N-step tails, drop
    /// partial sequences, and clear the step window. Returns the number
    /// of items emitted by this call.
    pub fn append(&mut self, step: WriterStep) -> usize {
        let boundary = step.done || step.truncated;
        self.window.push_back(step);
        if self.window.len() > self.max_span {
            self.window.pop_front();
        }
        self.ep_len += 1;
        let mut emitted = 0;
        for i in 0..self.tables.len() {
            emitted += self.emit_for(i, boundary);
        }
        if boundary {
            self.window.clear();
            self.ep_len = 0;
        }
        self.items_emitted += emitted as u64;
        emitted
    }

    /// Emit whatever the sink at `tables[i]` is owed after the newest
    /// step (already in the window).
    fn emit_for(&mut self, i: usize, boundary: bool) -> usize {
        let kind = self.tables[i].kind();
        let len = self.window.len();
        match kind {
            ItemKind::OneStep => {
                let s = &self.window[len - 1];
                let t = Transition {
                    obs: s.obs.clone(),
                    action: s.action.clone(),
                    next_obs: s.next_obs.clone(),
                    reward: s.reward,
                    done: done_flag(s),
                };
                self.tables[i].insert_from(self.actor_id, &t);
                1
            }
            ItemKind::NStep { n, gamma } => {
                if !boundary {
                    // Interior step: at most the one full window that
                    // just completed (starting n-1 steps back).
                    if len >= n {
                        let t = self.fold_nstep(len - n, gamma);
                        self.tables[i].insert_from(self.actor_id, &t);
                        1
                    } else {
                        0
                    }
                } else {
                    // Boundary: the full window ending here (if any)
                    // plus every shorter tail, so each step of the
                    // episode starts exactly one item.
                    let start_lo = len.saturating_sub(n);
                    let mut count = 0;
                    for st in start_lo..len {
                        let t = self.fold_nstep(st, gamma);
                        self.tables[i].insert_from(self.actor_id, &t);
                        count += 1;
                    }
                    count
                }
            }
            ItemKind::Sequence { len: seq } => {
                if self.ep_len % seq == 0 {
                    debug_assert!(len >= seq);
                    let t = self.flatten_sequence(len - seq, seq);
                    self.tables[i].insert_from(self.actor_id, &t);
                    1
                } else {
                    if boundary {
                        self.dropped_partial += 1;
                    }
                    0
                }
            }
        }
    }

    /// Fold window steps `[start ..]` into one N-step transition:
    /// discounted reward sum, first obs/action, last next_obs, terminal
    /// flag of the last step (bootstrapping through truncation).
    fn fold_nstep(&self, start: usize, gamma: f32) -> Transition {
        let end = self.window.len() - 1;
        let first = &self.window[start];
        let last = &self.window[end];
        let mut reward = 0.0f32;
        let mut g = 1.0f32;
        for k in start..=end {
            reward += g * self.window[k].reward;
            g *= gamma;
        }
        Transition {
            obs: first.obs.clone(),
            action: first.action.clone(),
            next_obs: last.next_obs.clone(),
            reward,
            done: done_flag(last),
        }
    }

    /// Flatten `count` steps starting at `start` into one wide row:
    /// concatenated obs / actions / next_obs, summed raw reward,
    /// terminal flag of the last step.
    fn flatten_sequence(&self, start: usize, count: usize) -> Transition {
        let steps = start..start + count;
        let mut obs = Vec::with_capacity(count * self.window[start].obs.len());
        let mut action = Vec::with_capacity(count * self.window[start].action.len());
        let mut next_obs = Vec::with_capacity(count * self.window[start].obs.len());
        let mut reward = 0.0f32;
        for k in steps {
            let s = &self.window[k];
            obs.extend_from_slice(&s.obs);
            action.extend_from_slice(&s.action);
            next_obs.extend_from_slice(&s.next_obs);
            reward += s.reward;
        }
        let last = &self.window[start + count - 1];
        Transition { obs, action, next_obs, reward, done: done_flag(last) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::UniformReplay;
    use crate::service::limiter::RateLimiter;
    use std::sync::Arc;

    fn mk_table(kind: ItemKind, obs_dim: usize, act_dim: usize) -> Arc<Table> {
        let m = kind.dim_multiplier();
        Arc::new(Table::new(
            "t",
            kind,
            Arc::new(UniformReplay::new(256, obs_dim * m, act_dim * m)),
            RateLimiter::Unlimited { min_size_to_sample: 1 },
        ))
    }

    fn step(i: usize, reward: f32, done: bool, truncated: bool) -> WriterStep {
        WriterStep {
            obs: vec![i as f32, 0.0],
            action: vec![i as f32 * 10.0],
            next_obs: vec![i as f32 + 1.0, 0.0],
            reward,
            done,
            truncated,
        }
    }

    #[test]
    fn item_kind_tag_roundtrips_through_parse() {
        for kind in [
            ItemKind::OneStep,
            ItemKind::NStep { n: 3, gamma: 0.9 },
            ItemKind::Sequence { len: 8 },
        ] {
            let reparsed = ItemKind::parse(&kind.tag(), 0.9).unwrap();
            assert_eq!(reparsed, kind);
        }
    }

    #[test]
    fn item_kind_parses() {
        assert_eq!(ItemKind::parse("1step", 0.99).unwrap(), ItemKind::OneStep);
        assert_eq!(
            ItemKind::parse("nstep:3", 0.9).unwrap(),
            ItemKind::NStep { n: 3, gamma: 0.9 }
        );
        assert_eq!(ItemKind::parse("seq:8", 0.99).unwrap(), ItemKind::Sequence { len: 8 });
        assert!(ItemKind::parse("nstep:0", 0.99).is_err());
        assert!(ItemKind::parse("seq:x", 0.99).is_err());
        assert!(ItemKind::parse("episodic", 0.99).is_err());
    }

    #[test]
    fn one_step_is_verbatim_passthrough() {
        let t = mk_table(ItemKind::OneStep, 2, 1);
        let mut w = TrajectoryWriter::new(3, vec![Arc::clone(&t)]);
        assert_eq!(w.append(step(0, 1.0, false, false)), 1);
        assert_eq!(w.append(step(1, 2.0, true, false)), 1);
        assert_eq!(t.len(), 2);
        assert_eq!(t.stats_snapshot().inserts, 2);
    }

    #[test]
    fn nstep_folds_discounted_reward_and_flushes_tails() {
        let gamma = 0.5f32;
        let t = mk_table(ItemKind::NStep { n: 3, gamma }, 2, 1);
        let mut w = TrajectoryWriter::new(0, vec![Arc::clone(&t)]);
        // 4-step episode with rewards 1, 2, 4, 8.
        assert_eq!(w.append(step(0, 1.0, false, false)), 0);
        assert_eq!(w.append(step(1, 2.0, false, false)), 0);
        // Step 2 completes the first full window [0..2].
        assert_eq!(w.append(step(2, 4.0, false, false)), 1);
        // Terminal step 3: full window [1..3] plus tails [2..3], [3..3].
        assert_eq!(w.append(step(3, 8.0, true, false)), 3);
        assert_eq!(t.len(), 4);
        // Inspect folded rewards via the storage-backed buffer.
        let mut rng = crate::util::rng::Rng::new(1);
        let mut out = crate::replay::SampleBatch::default();
        assert!(t.buffer().sample(64, &mut rng, &mut out));
        // Expected rewards: item@0: 1 + .5·2 + .25·4 = 3; item@1: 2 +
        // .5·4 + .25·8 = 6; item@2: 4 + .5·8 = 8; item@3: 8.
        let mut seen: Vec<(f32, f32, f32)> = (0..out.len())
            .map(|j| (out.obs[j * 2], out.reward[j], out.done[j]))
            .collect();
        seen.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        seen.dedup();
        assert_eq!(seen.len(), 4);
        assert_eq!(seen[0], (0.0, 3.0, 0.0));
        assert_eq!(seen[1], (1.0, 6.0, 1.0));
        assert_eq!(seen[2], (2.0, 8.0, 1.0));
        assert_eq!(seen[3], (3.0, 8.0, 1.0));
    }

    #[test]
    fn nstep_never_leaks_across_episodes() {
        let t = mk_table(ItemKind::NStep { n: 4, gamma: 1.0 }, 2, 1);
        let mut w = TrajectoryWriter::new(0, vec![Arc::clone(&t)]);
        // Two 2-step episodes; n = 4 windows would span both if the
        // writer leaked.
        w.append(step(0, 1.0, false, false));
        w.append(step(1, 1.0, true, false));
        w.append(step(10, 100.0, false, false));
        w.append(step(11, 100.0, true, false));
        let mut rng = crate::util::rng::Rng::new(2);
        let mut out = crate::replay::SampleBatch::default();
        assert!(t.buffer().sample(64, &mut rng, &mut out));
        for j in 0..out.len() {
            let start = out.obs[j * 2];
            let reward = out.reward[j];
            // Episode-1 items fold at most 1+1; episode-2 at most 200.
            if start < 10.0 {
                assert!(reward <= 2.0, "episode-1 item folded {reward}");
            } else {
                assert!((100.0..=200.0).contains(&reward), "episode-2 item folded {reward}");
            }
        }
    }

    #[test]
    fn truncation_bootstraps_through() {
        let t = mk_table(ItemKind::NStep { n: 2, gamma: 1.0 }, 2, 1);
        let mut w = TrajectoryWriter::new(0, vec![Arc::clone(&t)]);
        w.append(step(0, 1.0, false, false));
        // Truncated (time-limit) end: items must carry done = 0.
        w.append(step(1, 1.0, true, true));
        let mut rng = crate::util::rng::Rng::new(3);
        let mut out = crate::replay::SampleBatch::default();
        assert!(t.buffer().sample(16, &mut rng, &mut out));
        for j in 0..out.len() {
            assert_eq!(out.done[j], 0.0);
        }
    }

    #[test]
    fn sequence_emits_full_windows_only() {
        let t = mk_table(ItemKind::Sequence { len: 2 }, 2, 1);
        let mut w = TrajectoryWriter::new(0, vec![Arc::clone(&t)]);
        // 5-step episode → two full windows, one dropped partial.
        for i in 0..5 {
            let done = i == 4;
            w.append(step(i, 1.0, done, false));
        }
        assert_eq!(t.len(), 2);
        assert_eq!(w.dropped_partial(), 1);
        // Flattened rows are 2× wide.
        let mut rng = crate::util::rng::Rng::new(4);
        let mut out = crate::replay::SampleBatch::default();
        assert!(t.buffer().sample(2, &mut rng, &mut out));
        assert_eq!(out.obs.len(), 2 * 4);
        for j in 0..out.len() {
            assert_eq!(out.reward[j], 2.0); // sum of 2 unit rewards
        }
    }

    #[test]
    fn multi_table_fanout_from_one_writer() {
        let one = mk_table(ItemKind::OneStep, 2, 1);
        let three = mk_table(ItemKind::NStep { n: 3, gamma: 0.9 }, 2, 1);
        let seq = mk_table(ItemKind::Sequence { len: 4 }, 2, 1);
        let mut w = TrajectoryWriter::new(
            0,
            vec![Arc::clone(&one), Arc::clone(&three), Arc::clone(&seq)],
        );
        for i in 0..8 {
            let done = i == 7;
            w.append(step(i, 1.0, done, false));
        }
        assert_eq!(one.len(), 8); // one item per step
        assert_eq!(three.len(), 8); // sliding + boundary tails = one per start
        assert_eq!(seq.len(), 2); // two non-overlapping windows of 4
        assert_eq!(w.items_emitted(), 8 + 8 + 2);
    }
}
