//! Rate limiters: the replay service's ownership of the
//! sample-to-insert ratio (Reverb's `RateLimiter` concept).
//!
//! A limiter watches two monotone per-table counters — items inserted
//! and sample batches granted — and answers two questions without any
//! lock of its own (both counters are relaxed atomics owned by the
//! table):
//!
//! * may a writer insert another item right now?
//! * may a learner be granted another sample batch right now?
//!
//! [`SampleToInsertRatio`] keeps the *ratio drift*
//! `d = inserts · σ − samples` (σ = samples per insert) inside a
//! `[min_diff, max_diff]` window once the table holds
//! `min_size_to_sample` items: inserts stall when `d` would run past
//! `max_diff` (collection too far ahead), samples stall when granting
//! one would push `d` below `min_diff` (consumption too far ahead).
//! [`RateLimiter::Unlimited`] never stalls either side (the paper's
//! fully-asynchronous free-run mode); `min_size_to_sample` still gates
//! sampling so learners never train on an all-but-empty table.
//!
//! The coordinator's legacy pacing — `Control::actors_ahead` plus the
//! learner-side `(learn + 1) · update_interval <= env_steps` gate — is
//! exactly [`RateLimiter::from_update_interval`]: σ = 1/update_interval,
//! `min_diff = 0` (the learner gate), `max_diff = actor_lead · σ` (the
//! actor gate), warmup as `min_size_to_sample`. The old CLI flags map
//! onto the limiter without behaviour change.

use anyhow::{bail, Result};

/// Ratio window of a [`RateLimiter::SampleToInsertRatio`] table.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SampleToInsertRatio {
    /// σ: average sample batches granted per inserted item.
    pub samples_per_insert: f64,
    /// Sampling is denied until the table holds this many items.
    pub min_size_to_sample: usize,
    /// Lower bound on the ratio drift `d = inserts·σ − samples`;
    /// granting a sample that would push `d` below it stalls the caller.
    pub min_diff: f64,
    /// Upper bound on the drift; inserting past it stalls the writer.
    pub max_diff: f64,
}

impl SampleToInsertRatio {
    /// Reverb-style constructor: the allowed drift window is centred on
    /// `σ · min_size_to_sample` with half-width `error_buffer`.
    /// `error_buffer` must be at least `max(1, σ)` or the window could
    /// be too narrow to ever admit both an insert and a sample
    /// (deadlock); σ must be positive.
    pub fn new(
        samples_per_insert: f64,
        min_size_to_sample: usize,
        error_buffer: f64,
    ) -> Result<Self> {
        if !(samples_per_insert > 0.0) {
            bail!("samples_per_insert must be > 0, got {samples_per_insert}");
        }
        let min_buffer = samples_per_insert.max(1.0);
        if error_buffer < min_buffer {
            bail!(
                "error_buffer {error_buffer} too small: must be >= max(1, samples_per_insert) = {min_buffer}"
            );
        }
        let offset = samples_per_insert * min_size_to_sample as f64;
        Ok(Self {
            samples_per_insert,
            min_size_to_sample,
            min_diff: offset - error_buffer,
            max_diff: offset + error_buffer,
        })
    }
}

/// Per-table admission policy. `Copy` so tables and the DSE share one
/// value without synchronization; all state lives in the table counters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RateLimiter {
    /// Free-run: never stall inserts or samples. `min_size_to_sample`
    /// still gates sampling.
    Unlimited { min_size_to_sample: usize },
    /// Hold samples ≈ σ · inserts inside an error window.
    SampleToInsertRatio(SampleToInsertRatio),
}

impl RateLimiter {
    /// The legacy `Control` pacing as a limiter (see module docs):
    /// σ = 1/update_interval, learner gate `min_diff = 0`, actor gate
    /// `max_diff = actor_lead · σ` (`actor_lead = 0` = free-run actors,
    /// `max_diff = ∞`). The window is widened to at least `1 + σ` so a
    /// degenerate `actor_lead < update_interval` cannot deadlock the
    /// pipeline (legacy pacing had the same failure mode; the limiter
    /// refuses to reproduce it).
    pub fn from_update_interval(update_interval: f64, warmup: usize, actor_lead: usize) -> Self {
        let sigma = 1.0 / update_interval.max(1e-9);
        let max_diff = if actor_lead == 0 {
            f64::INFINITY
        } else {
            (actor_lead as f64 * sigma).max(1.0 + sigma)
        };
        RateLimiter::SampleToInsertRatio(SampleToInsertRatio {
            samples_per_insert: sigma,
            min_size_to_sample: warmup,
            min_diff: 0.0,
            max_diff,
        })
    }

    /// Items the table must hold before sampling is allowed.
    pub fn min_size_to_sample(&self) -> usize {
        match self {
            RateLimiter::Unlimited { min_size_to_sample } => *min_size_to_sample,
            RateLimiter::SampleToInsertRatio(r) => r.min_size_to_sample,
        }
    }

    /// May a writer insert one more item, given the current counters?
    /// Inserts are never denied below `min_size_to_sample` (warmup can
    /// never be starved by the limiter itself).
    #[inline]
    pub fn insert_ok(&self, inserts: usize, samples: usize) -> bool {
        match self {
            RateLimiter::Unlimited { .. } => true,
            RateLimiter::SampleToInsertRatio(r) => {
                if inserts < r.min_size_to_sample {
                    return true;
                }
                inserts as f64 * r.samples_per_insert - samples as f64 <= r.max_diff
            }
        }
    }

    /// May a sample batch be granted, where `samples_after` counts the
    /// batch being requested (callers reserve with `fetch_add` first and
    /// roll back on denial, so concurrent learners cannot overrun)?
    #[inline]
    pub fn sample_ok(&self, inserts: usize, samples_after: usize) -> bool {
        match self {
            RateLimiter::Unlimited { .. } => true,
            RateLimiter::SampleToInsertRatio(r) => {
                inserts as f64 * r.samples_per_insert - samples_after as f64 >= r.min_diff
            }
        }
    }
}

/// How a training run configures its tables' limiters (parsed from
/// `--rate-limit`, stored on `TrainConfig`). Separate from
/// [`RateLimiter`] because the legacy mapping needs run parameters
/// (update_interval / warmup / actor_lead) that only the coordinator
/// holds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RateLimitSpec {
    /// Reimplement the old `Control` pacing on the limiter (default —
    /// keeps `--update-interval` and the actor-lead behaviour).
    Legacy,
    /// Explicit σ samples per insert (Reverb's `SampleToInsertRatio`).
    SamplesPerInsert(f64),
    /// Free-run.
    Unlimited,
}

impl RateLimitSpec {
    /// Parse a `--rate-limit` value: `legacy`, `unlimited`/`none`/`off`,
    /// or a positive float σ (samples per insert).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "legacy" => Ok(RateLimitSpec::Legacy),
            "unlimited" | "none" | "off" | "free" => Ok(RateLimitSpec::Unlimited),
            other => {
                let sigma: f64 = match other.parse() {
                    Ok(v) => v,
                    Err(_) => bail!(
                        "--rate-limit: expected `legacy`, `unlimited` or a positive \
                         samples-per-insert float, got `{other}`"
                    ),
                };
                if !(sigma > 0.0) {
                    bail!("--rate-limit: samples-per-insert must be > 0, got {sigma}");
                }
                Ok(RateLimitSpec::SamplesPerInsert(sigma))
            }
        }
    }

    /// Instantiate for one table of a run. The explicit-σ variant uses a
    /// Reverb-style error buffer of `max(σ · warmup, max(1, σ))` — wide
    /// enough that sampling opens as soon as warmup fills, never so
    /// narrow the window deadlocks.
    pub fn build(&self, update_interval: f64, warmup: usize, actor_lead: usize) -> RateLimiter {
        match *self {
            RateLimitSpec::Legacy => {
                RateLimiter::from_update_interval(update_interval, warmup, actor_lead)
            }
            RateLimitSpec::SamplesPerInsert(sigma) => {
                let error_buffer = (sigma * warmup as f64).max(sigma.max(1.0));
                RateLimiter::SampleToInsertRatio(
                    SampleToInsertRatio::new(sigma, warmup, error_buffer)
                        .expect("error buffer chosen >= max(1, sigma)"),
                )
            }
            RateLimitSpec::Unlimited => RateLimiter::Unlimited { min_size_to_sample: warmup },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_never_stalls() {
        let l = RateLimiter::Unlimited { min_size_to_sample: 10 };
        assert!(l.insert_ok(0, 0));
        assert!(l.insert_ok(1_000_000, 0));
        assert!(l.sample_ok(0, 1_000_000));
        assert_eq!(l.min_size_to_sample(), 10);
    }

    #[test]
    fn ratio_window_bounds_both_sides() {
        // σ = 2 samples per insert, min_size 4, error buffer 8:
        // offset = 8, window d ∈ [0, 16].
        let l = RateLimiter::SampleToInsertRatio(
            SampleToInsertRatio::new(2.0, 4, 8.0).unwrap(),
        );
        // Below min_size inserts always pass.
        assert!(l.insert_ok(3, 0));
        // d = 8·2 − 0 = 16 = max_diff: still allowed, one more is not.
        assert!(l.insert_ok(8, 0));
        assert!(!l.insert_ok(9, 0));
        // Samples catch up: d = 9·2 − 10 = 8 <= 16 → inserts flow again.
        assert!(l.insert_ok(9, 10));
        // Sample side: granting batch #19 leaves d = 18 − 19 < 0 = min_diff.
        assert!(l.sample_ok(9, 18));
        assert!(!l.sample_ok(9, 19));
    }

    #[test]
    fn legacy_mapping_matches_control_pacing() {
        // update_interval R = 2, warmup 100, lead 512 — the old Control
        // gates: learners wait while (learn+1)·R > env, actors while
        // env > learn·R + 512.
        let l = RateLimiter::from_update_interval(2.0, 100, 512);
        // Learner gate: env = 9, learn = 4 → (4+1)·2 > 9 → denied.
        assert!(!l.sample_ok(9, 5));
        // env = 10 → allowed.
        assert!(l.sample_ok(10, 5));
        // Actor gate: env = learn·R + 512 → allowed; one past → denied.
        assert!(l.insert_ok(1000 + 512, 500));
        assert!(!l.insert_ok(1000 + 513, 500));
        // Warmup bypass: below warmup inserts always pass.
        assert!(l.insert_ok(99, 0));
    }

    #[test]
    fn free_run_lead_zero_means_unbounded_inserts() {
        let l = RateLimiter::from_update_interval(1.0, 100, 0);
        assert!(l.insert_ok(usize::MAX / 2, 0));
        // Learners still paced.
        assert!(!l.sample_ok(10, 11));
    }

    #[test]
    fn degenerate_lead_widened_to_avoid_deadlock() {
        // lead < update_interval would deadlock under the literal legacy
        // mapping; the limiter widens the window to 1 + σ.
        let l = RateLimiter::from_update_interval(4.0, 0, 1);
        match l {
            RateLimiter::SampleToInsertRatio(r) => {
                assert!(r.max_diff >= 1.0 + r.samples_per_insert);
            }
            _ => panic!("legacy mapping must be a ratio limiter"),
        }
        // Window admits an insert burst and then a sample.
        assert!(l.insert_ok(0, 0));
        assert!(l.insert_ok(4, 0));
        assert!(l.sample_ok(4, 1));
    }

    #[test]
    fn constructor_rejects_bad_parameters() {
        assert!(SampleToInsertRatio::new(0.0, 10, 5.0).is_err());
        assert!(SampleToInsertRatio::new(-1.0, 10, 5.0).is_err());
        assert!(SampleToInsertRatio::new(4.0, 10, 2.0).is_err()); // buffer < σ
        assert!(SampleToInsertRatio::new(4.0, 10, 4.0).is_ok());
    }

    #[test]
    fn spec_parses_and_builds() {
        assert_eq!(RateLimitSpec::parse("legacy").unwrap(), RateLimitSpec::Legacy);
        assert_eq!(RateLimitSpec::parse("unlimited").unwrap(), RateLimitSpec::Unlimited);
        assert_eq!(
            RateLimitSpec::parse("8").unwrap(),
            RateLimitSpec::SamplesPerInsert(8.0)
        );
        assert!(RateLimitSpec::parse("-2").is_err());
        assert!(RateLimitSpec::parse("fast").is_err());

        let l = RateLimitSpec::SamplesPerInsert(8.0).build(1.0, 100, 512);
        match l {
            RateLimiter::SampleToInsertRatio(r) => {
                assert_eq!(r.samples_per_insert, 8.0);
                assert_eq!(r.min_size_to_sample, 100);
                assert!(r.max_diff > r.min_diff);
            }
            _ => panic!("explicit sigma must build a ratio limiter"),
        }
        assert_eq!(
            RateLimitSpec::Unlimited.build(1.0, 7, 0),
            RateLimiter::Unlimited { min_size_to_sample: 7 }
        );
    }
}
