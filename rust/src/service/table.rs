//! A named replay table: one [`ReplayBuffer`] implementation plus the
//! service-level policy around it — which item shape it stores, the
//! [`RateLimiter`] that owns its sample-to-insert ratio, and lock-free
//! stall/throughput stats for the monitor loop and the benches.
//!
//! A table is to the service what a Reverb `Table` is to a Reverb
//! server: storage + sampler come from the wrapped buffer
//! implementation (prioritized = proportional sampler, uniform = FIFO
//! ring), the remover is whatever [`crate::replay::RemoverSpec`] the
//! buffer was built with (FIFO by default), and the limiter is
//! attached here. Capacity-pressure stats — evictions by reason, the
//! max per-item sample count — are tracked at this layer so the
//! monitor and the `Stats` RPC see them uniformly across buffer kinds.

use super::checkpoint::TableState;
use super::limiter::RateLimiter;
use super::writer::ItemKind;
use crate::replay::{EvictReason, ReplayBuffer, SampleBatch, Transition};
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Outcome of a [`Table::try_sample`] poll. The service never blocks a
/// thread; callers sleep-poll exactly like the old coordinator pacing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SampleOutcome {
    /// A batch was drawn into the caller's [`SampleBatch`].
    Sampled,
    /// The rate limiter denied the batch (consumption too far ahead).
    Throttled,
    /// The table is below `min_size_to_sample` (or empty).
    NotEnoughData,
}

/// Monotone relaxed counters; written by writers/learners, read by the
/// monitor loop without taking any lock.
#[derive(Default)]
pub struct TableStats {
    /// Items inserted (the limiter's insert counter).
    pub inserts: AtomicUsize,
    /// Sample batches granted (the limiter's sample counter).
    pub sample_batches: AtomicUsize,
    /// Transitions handed out across all granted batches.
    pub sampled_items: AtomicUsize,
    /// Priorities fed back.
    pub priority_updates: AtomicUsize,
    /// Denied insert polls (writer-side stall pressure).
    pub insert_stalls: AtomicUsize,
    /// Denied sample polls (learner-side stall pressure).
    pub sample_stalls: AtomicUsize,
    /// Env steps remote writers dropped client-side (spill-queue
    /// overflow during an outage) — steps that never became inserts.
    /// Nonzero means the stored data has gaps; see the README's fault
    /// tolerance notes.
    pub steps_dropped: AtomicUsize,
    /// Evictions by the FIFO remover (or a FIFO fallback of another
    /// remover — e.g. `max_sampled` before any item ripens).
    pub evict_fifo: AtomicUsize,
    /// Evictions by the LIFO remover.
    pub evict_lifo: AtomicUsize,
    /// Evictions by the lowest-priority remover.
    pub evict_lowest: AtomicUsize,
    /// Evictions of items that reached their sample-count ceiling.
    pub evict_sampled: AtomicUsize,
}

impl TableStats {
    /// Overwrite every counter from a snapshot (checkpoint restore).
    /// `inserts` and `sample_batches` carry the rate limiter's ratio
    /// accounting across the restart.
    pub fn restore(&self, s: &TableStatsSnapshot) {
        self.inserts.store(s.inserts, Ordering::Relaxed);
        self.sample_batches.store(s.sample_batches, Ordering::Relaxed);
        self.sampled_items.store(s.sampled_items, Ordering::Relaxed);
        self.priority_updates.store(s.priority_updates, Ordering::Relaxed);
        self.insert_stalls.store(s.insert_stalls, Ordering::Relaxed);
        self.sample_stalls.store(s.sample_stalls, Ordering::Relaxed);
        self.steps_dropped.store(s.steps_dropped, Ordering::Relaxed);
        self.evict_fifo.store(s.evict_fifo, Ordering::Relaxed);
        self.evict_lifo.store(s.evict_lifo, Ordering::Relaxed);
        self.evict_lowest.store(s.evict_lowest, Ordering::Relaxed);
        self.evict_sampled.store(s.evict_sampled, Ordering::Relaxed);
    }
}

/// Point-in-time copy of [`TableStats`], plus `max_times_sampled`,
/// which is derived from the buffer's per-item counts at snapshot time
/// (it is not an atomic of its own and is NOT restored by
/// [`TableStats::restore`] — the buffer's restored sample counts
/// reproduce it).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TableStatsSnapshot {
    pub inserts: usize,
    pub sample_batches: usize,
    pub sampled_items: usize,
    pub priority_updates: usize,
    pub insert_stalls: usize,
    pub sample_stalls: usize,
    pub steps_dropped: usize,
    pub evict_fifo: usize,
    pub evict_lifo: usize,
    pub evict_lowest: usize,
    pub evict_sampled: usize,
    /// Highest times-sampled count over the currently occupied slots.
    pub max_times_sampled: usize,
}

/// One named table of a [`super::ReplayService`].
pub struct Table {
    name: String,
    kind: ItemKind,
    buffer: Arc<dyn ReplayBuffer>,
    limiter: RateLimiter,
    stats: TableStats,
}

impl Table {
    pub fn new(
        name: impl Into<String>,
        kind: ItemKind,
        buffer: Arc<dyn ReplayBuffer>,
        limiter: RateLimiter,
    ) -> Self {
        Self {
            name: name.into(),
            kind,
            buffer,
            limiter,
            stats: TableStats::default(),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The item shape writers must emit into this table.
    pub fn kind(&self) -> ItemKind {
        self.kind
    }

    pub fn limiter(&self) -> &RateLimiter {
        &self.limiter
    }

    /// The wrapped buffer (benches / tests; training goes through the
    /// writer and sampler paths).
    pub fn buffer(&self) -> &Arc<dyn ReplayBuffer> {
        &self.buffer
    }

    pub fn len(&self) -> usize {
        self.buffer.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buffer.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.buffer.capacity()
    }

    /// Total sampleable priority mass of the wrapped buffer (what the
    /// `Mass` RPC advertises for mesh-level two-level sampling).
    pub fn total_priority(&self) -> f32 {
        self.buffer.total_priority()
    }

    /// Writer-side admission poll. Denials count as insert stalls (each
    /// denied poll is one observed stall interval of the polling loop).
    pub fn can_insert(&self) -> bool {
        let inserts = self.stats.inserts.load(Ordering::Relaxed);
        let samples = self.stats.sample_batches.load(Ordering::Relaxed);
        let ok = self.limiter.insert_ok(inserts, samples);
        if !ok {
            self.stats.insert_stalls.fetch_add(1, Ordering::Relaxed);
        }
        ok
    }

    /// Insert one item attributed to a producer (actor affinity routes
    /// sharded buffers to disjoint locks). Writers are expected to poll
    /// [`Self::can_insert`] first; the insert itself never blocks.
    pub fn insert_from(&self, actor_id: usize, t: &Transition) {
        let evicted = self.buffer.insert_from(actor_id, t);
        self.note_insert(evicted);
    }

    /// Insert one migrated item carrying its learned priority (the
    /// drain-handoff merge path). Non-finite or negative priorities are
    /// clamped to 0 here, like [`Self::update_priorities`] does, so a
    /// corrupt donor value cannot poison the receiver's sum tree.
    pub fn insert_with_priority(&self, actor_id: usize, t: &Transition, priority: f32) {
        let p = if priority.is_finite() && priority >= 0.0 { priority } else { 0.0 };
        let evicted = self.buffer.insert_with_priority(actor_id, t, p);
        self.note_insert(evicted);
    }

    /// Shared insert accounting: bump the insert counter and the
    /// eviction counter matching the displaced item's reason, if any.
    fn note_insert(&self, evicted: Option<EvictReason>) {
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        match evicted {
            None => {}
            Some(EvictReason::Fifo) => {
                self.stats.evict_fifo.fetch_add(1, Ordering::Relaxed);
            }
            Some(EvictReason::Lifo) => {
                self.stats.evict_lifo.fetch_add(1, Ordering::Relaxed);
            }
            Some(EvictReason::LowestPriority) => {
                self.stats.evict_lowest.fetch_add(1, Ordering::Relaxed);
            }
            Some(EvictReason::MaxSampled) => {
                self.stats.evict_sampled.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Learner-side sample poll: reserve a batch against the limiter,
    /// roll back on denial. The reserve-then-check protocol makes the
    /// ratio bound exact under concurrent learners: at most
    /// `σ · inserts − min_diff` batches are ever granted.
    pub fn try_sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> SampleOutcome {
        let need = self.limiter.min_size_to_sample().max(batch).max(1);
        if self.buffer.len() < need {
            self.stats.sample_stalls.fetch_add(1, Ordering::Relaxed);
            return SampleOutcome::NotEnoughData;
        }
        let reserved = self.stats.sample_batches.fetch_add(1, Ordering::Relaxed) + 1;
        let inserts = self.stats.inserts.load(Ordering::Relaxed);
        if !self.limiter.sample_ok(inserts, reserved) {
            self.stats.sample_batches.fetch_sub(1, Ordering::Relaxed);
            self.stats.sample_stalls.fetch_add(1, Ordering::Relaxed);
            return SampleOutcome::Throttled;
        }
        if !self.buffer.sample(batch, rng, out) {
            self.stats.sample_batches.fetch_sub(1, Ordering::Relaxed);
            self.stats.sample_stalls.fetch_add(1, Ordering::Relaxed);
            return SampleOutcome::NotEnoughData;
        }
        self.stats.sampled_items.fetch_add(out.len(), Ordering::Relaxed);
        // Feed per-item sample counts to the buffer's remover (a no-op
        // unless it is `MaxTimesSampled`, which evicts on them).
        self.buffer.note_sampled(&out.indices);
        SampleOutcome::Sampled
    }

    /// Feed |TD| errors back for sampled indices.
    ///
    /// This is the table's public update surface, so invalid |TD| values
    /// are sanitized here: a NaN or +inf flowing into the sum tree would
    /// poison interior sums up to the root (breaking sampling for the
    /// whole table), so non-finite and negative values clamp to 0 — the
    /// minimum-priority encoding — instead.
    pub fn update_priorities(&self, indices: &[usize], td_abs: &[f32]) {
        if td_abs.iter().any(|v| !v.is_finite() || *v < 0.0) {
            let cleaned: Vec<f32> = td_abs
                .iter()
                .map(|&v| if v.is_finite() && v >= 0.0 { v } else { 0.0 })
                .collect();
            self.buffer.update_priorities(indices, &cleaned);
        } else {
            self.buffer.update_priorities(indices, td_abs);
        }
        self.stats.priority_updates.fetch_add(indices.len(), Ordering::Relaxed);
    }

    /// Account env steps a remote writer dropped client-side (spill
    /// overflow during an outage). These steps never reached the table;
    /// the counter makes the loss visible in `Stats` and checkpoints.
    pub fn add_steps_dropped(&self, n: usize) {
        self.stats.steps_dropped.fetch_add(n, Ordering::Relaxed);
    }

    /// Serialize this table: buffer contents + stats counters (which
    /// ARE the limiter's ratio-accounting state). Fails if the wrapped
    /// buffer implementation does not support checkpointing.
    pub fn checkpoint(&self) -> Result<TableState> {
        let buffer = self.buffer.snapshot_state().ok_or_else(|| {
            anyhow!(
                "table `{}`: buffer `{}` does not support checkpointing",
                self.name,
                self.buffer.name()
            )
        })?;
        Ok(TableState {
            name: self.name.clone(),
            kind_tag: self.kind.tag(),
            stats: self.stats_snapshot(),
            remover: self.buffer.remover(),
            buffer,
        })
    }

    /// Check that `state` can be restored into this table without
    /// mutating anything (name, item kind, buffer impl + geometry,
    /// per-shard consistency).
    pub fn validate_restore(&self, state: &TableState) -> Result<()> {
        if state.name != self.name {
            bail!("state for table `{}` offered to table `{}`", state.name, self.name);
        }
        if state.kind_tag != self.kind.tag() {
            bail!(
                "table `{}`: state stores `{}` items, this table stores `{}`",
                self.name,
                state.kind_tag,
                self.kind.tag()
            );
        }
        self.buffer.validate_state(&state.buffer)
    }

    /// Restore a validated state: buffer contents first, then the stats
    /// counters so the rate limiter resumes with the exact snapshot
    /// accounting (no post-restart stall or burst).
    pub fn restore(&self, state: &TableState) -> Result<()> {
        self.validate_restore(state)?;
        self.apply_restore(state)
    }

    /// Apply without re-running the cross-table validation (the service
    /// restore path validates every table before applying any).
    pub(crate) fn apply_restore(&self, state: &TableState) -> Result<()> {
        self.buffer.restore_state(&state.buffer)?;
        self.stats.restore(&state.stats);
        Ok(())
    }

    pub fn stats_snapshot(&self) -> TableStatsSnapshot {
        TableStatsSnapshot {
            inserts: self.stats.inserts.load(Ordering::Relaxed),
            sample_batches: self.stats.sample_batches.load(Ordering::Relaxed),
            sampled_items: self.stats.sampled_items.load(Ordering::Relaxed),
            priority_updates: self.stats.priority_updates.load(Ordering::Relaxed),
            insert_stalls: self.stats.insert_stalls.load(Ordering::Relaxed),
            sample_stalls: self.stats.sample_stalls.load(Ordering::Relaxed),
            steps_dropped: self.stats.steps_dropped.load(Ordering::Relaxed),
            evict_fifo: self.stats.evict_fifo.load(Ordering::Relaxed),
            evict_lifo: self.stats.evict_lifo.load(Ordering::Relaxed),
            evict_lowest: self.stats.evict_lowest.load(Ordering::Relaxed),
            evict_sampled: self.stats.evict_sampled.load(Ordering::Relaxed),
            max_times_sampled: self.buffer.max_sample_count() as usize,
        }
    }

    /// One-line stats for the monitor's progress output, e.g.
    /// `replay[n=4096 in=5000 out=120 stall i/s=3/40]`. Capacity
    /// pressure shows up only once it exists: an ` evict=f/l/p/s` cell
    /// (FIFO/LIFO/lowest-priority/max-sampled counts) once anything
    /// has been evicted, and an ` smax=` cell once some occupied item
    /// has been sampled — quiet tables print exactly as before.
    pub fn stats_line(&self) -> String {
        let s = self.stats_snapshot();
        let drop = if s.steps_dropped > 0 {
            format!(" drop={}", s.steps_dropped)
        } else {
            String::new()
        };
        let evicted = s.evict_fifo + s.evict_lifo + s.evict_lowest + s.evict_sampled;
        let evict = if evicted > 0 {
            format!(
                " evict={}/{}/{}/{}",
                s.evict_fifo, s.evict_lifo, s.evict_lowest, s.evict_sampled
            )
        } else {
            String::new()
        };
        let smax = if s.max_times_sampled > 0 {
            format!(" smax={}", s.max_times_sampled)
        } else {
            String::new()
        };
        format!(
            "{}[n={} in={} out={} stall i/s={}/{}{}{}{}]",
            self.name,
            self.buffer.len(),
            s.inserts,
            s.sample_batches,
            s.insert_stalls,
            s.sample_stalls,
            drop,
            evict,
            smax,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::UniformReplay;
    use crate::service::limiter::{RateLimitSpec, SampleToInsertRatio};

    fn tr(v: f32) -> Transition {
        Transition {
            obs: vec![v, -v],
            action: vec![v],
            next_obs: vec![v + 1.0, -v],
            reward: v,
            done: false,
        }
    }

    fn table(limiter: RateLimiter) -> Table {
        Table::new(
            "t",
            ItemKind::OneStep,
            Arc::new(UniformReplay::new(64, 2, 1)),
            limiter,
        )
    }

    #[test]
    fn unlimited_table_inserts_and_samples() {
        let t = table(RateLimiter::Unlimited { min_size_to_sample: 4 });
        let mut rng = Rng::new(1);
        let mut out = SampleBatch::default();
        assert_eq!(t.try_sample(2, &mut rng, &mut out), SampleOutcome::NotEnoughData);
        for i in 0..8 {
            assert!(t.can_insert());
            t.insert_from(0, &tr(i as f32));
        }
        assert_eq!(t.len(), 8);
        assert_eq!(t.try_sample(4, &mut rng, &mut out), SampleOutcome::Sampled);
        assert_eq!(out.len(), 4);
        let s = t.stats_snapshot();
        assert_eq!(s.inserts, 8);
        assert_eq!(s.sample_batches, 1);
        assert_eq!(s.sampled_items, 4);
        assert_eq!(s.insert_stalls, 0);
        assert_eq!(s.sample_stalls, 1);
    }

    #[test]
    fn ratio_table_throttles_and_rolls_back_reserve() {
        // σ = 1 sample per insert, min_size 2, window d ∈ [0, 4].
        let t = table(RateLimiter::SampleToInsertRatio(SampleToInsertRatio {
            samples_per_insert: 1.0,
            min_size_to_sample: 2,
            min_diff: 0.0,
            max_diff: 4.0,
        }));
        let mut rng = Rng::new(2);
        let mut out = SampleBatch::default();
        for i in 0..4 {
            t.insert_from(0, &tr(i as f32));
        }
        // 4 inserts allow exactly 4 batches, then throttle.
        for _ in 0..4 {
            assert_eq!(t.try_sample(2, &mut rng, &mut out), SampleOutcome::Sampled);
        }
        assert_eq!(t.try_sample(2, &mut rng, &mut out), SampleOutcome::Throttled);
        let s = t.stats_snapshot();
        // The denied reserve must have been rolled back.
        assert_eq!(s.sample_batches, 4);
        assert_eq!(s.sample_stalls, 1);
        // One more insert unblocks one more batch.
        t.insert_from(0, &tr(9.0));
        assert_eq!(t.try_sample(2, &mut rng, &mut out), SampleOutcome::Sampled);
    }

    #[test]
    fn invalid_priorities_sanitized_at_table_surface() {
        use crate::replay::{PrioritizedConfig, PrioritizedReplay};
        let t = Table::new(
            "p",
            ItemKind::OneStep,
            Arc::new(PrioritizedReplay::new(PrioritizedConfig {
                capacity: 16,
                obs_dim: 2,
                act_dim: 1,
                ..Default::default()
            })),
            RateLimiter::Unlimited { min_size_to_sample: 1 },
        );
        for i in 0..8 {
            t.insert_from(0, &tr(i as f32));
        }
        // Regression: +inf used to flow through (|TD| + ε)^α = inf into
        // the tree and poison the root, breaking sampling for the whole
        // table; NaN and negatives are equally invalid. All must clamp
        // to the minimum (ε-derived) priority at this public surface.
        t.update_priorities(&[0, 1, 2], &[f32::INFINITY, f32::NAN, -3.0]);
        assert!(t.total_priority().is_finite());
        let mut rng = Rng::new(7);
        let mut out = SampleBatch::default();
        assert_eq!(t.try_sample(4, &mut rng, &mut out), SampleOutcome::Sampled);
        assert!(out.priorities.iter().all(|p| p.is_finite() && *p > 0.0));
        // Valid updates in the same batch as invalid ones still apply.
        t.update_priorities(&[3, 4], &[2.0, f32::INFINITY]);
        assert!(t.total_priority().is_finite());
        assert_eq!(t.stats_snapshot().priority_updates, 5);
    }

    #[test]
    fn insert_stall_counted_when_writers_run_ahead() {
        // σ = 1, min_size 2, max_diff 4: inserts stall once d > 4.
        let t = table(RateLimiter::SampleToInsertRatio(SampleToInsertRatio {
            samples_per_insert: 1.0,
            min_size_to_sample: 2,
            min_diff: 0.0,
            max_diff: 4.0,
        }));
        let mut stalled = 0;
        for i in 0..16 {
            if t.can_insert() {
                t.insert_from(0, &tr(i as f32));
            } else {
                stalled += 1;
            }
        }
        assert!(stalled > 0);
        assert_eq!(t.stats_snapshot().insert_stalls, stalled);
        // Inserted no further than the window allows past min_size.
        assert!(t.stats_snapshot().inserts <= 5);
    }

    #[test]
    fn eviction_counters_and_pressure_cells() {
        use crate::replay::RemoverSpec;
        let t = Table::new(
            "hot",
            ItemKind::OneStep,
            Arc::new(UniformReplay::with_remover(4, 2, 1, RemoverSpec::Lifo)),
            RateLimiter::Unlimited { min_size_to_sample: 1 },
        );
        for i in 0..4 {
            t.insert_from(0, &tr(i as f32));
        }
        // Nothing evicted, nothing sampled: the line has no pressure cells.
        let line = t.stats_line();
        assert_eq!(line, "hot[n=4 in=4 out=0 stall i/s=0/0]");
        for i in 4..7 {
            t.insert_from(0, &tr(i as f32));
        }
        let s = t.stats_snapshot();
        assert_eq!(s.evict_lifo, 3);
        assert_eq!(s.evict_fifo + s.evict_lowest + s.evict_sampled, 0);
        assert!(t.stats_line().contains(" evict=0/3/0/0"), "{}", t.stats_line());
        // Sampling feeds the per-item counts, surfacing smax.
        let mut rng = Rng::new(7);
        let mut out = SampleBatch::default();
        assert_eq!(t.try_sample(2, &mut rng, &mut out), SampleOutcome::Sampled);
        let s = t.stats_snapshot();
        assert!(s.max_times_sampled >= 1);
        assert!(t.stats_line().contains(" smax="), "{}", t.stats_line());
    }

    #[test]
    fn legacy_spec_end_to_end_pacing() {
        let limiter = RateLimitSpec::Legacy.build(2.0, 4, 8);
        let t = table(limiter);
        let mut rng = Rng::new(3);
        let mut out = SampleBatch::default();
        for i in 0..8 {
            t.insert_from(0, &tr(i as f32));
        }
        // update_interval 2 → at most floor(8 / 2) = 4 batches.
        let mut granted = 0;
        for _ in 0..10 {
            if t.try_sample(2, &mut rng, &mut out) == SampleOutcome::Sampled {
                granted += 1;
            }
        }
        assert_eq!(granted, 4);
    }
}
