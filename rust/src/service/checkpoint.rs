//! Replay-service checkpointing: serialize every table of a
//! [`ReplayService`] — buffer contents, table stats and the rate
//! limiter's counters — to one versioned, checksummed file, and restore
//! it into a freshly built service so a resumed run continues with
//! identical sampling behavior (Reverb's table-checkpointing feature,
//! arXiv:2102.04736 §"Checkpointing").
//!
//! # What is (and is not) in the file
//!
//! * **Per table**: name, item-kind tag, the [`TableStatsSnapshot`]
//!   counters (including the eviction-by-reason counters and the
//!   derived `max_times_sampled`), the table's [`RemoverSpec`] tag,
//!   and the wrapped buffer's [`BufferState`] (per-shard ring rows +
//!   leaf priorities + per-item sample counts + cursors + max
//!   priority).
//! * The limiter's *state* is exactly the `inserts` / `sample_batches`
//!   counters — restoring them transfers the sample-to-insert ratio
//!   accounting, so a resumed run neither stalls (drift wrongly high)
//!   nor bursts (drift wrongly zeroed) after restart.
//! * Interior sum-tree nodes are **not** stored: restore rebuilds them
//!   from the leaves, so a corrupted interior sum cannot be loaded.
//! * The limiter *configuration* (σ, error bounds) is not stored — it
//!   belongs to the run configuration, which must match between save
//!   and restore (enforced structurally via table names/kinds/geometry).
//!
//! # File format
//!
//! `magic "PALSTAT2" + payload + crc32(payload)` via the shared
//! [`crate::util::blob`] helpers (same writer/validator as the weights
//! [`crate::params::Checkpoint`]); writes are atomic (temp file +
//! rename). The payload starts with a `u32` format version so a future
//! layout change is reported as a version mismatch, not as garbage.
//!
//! **Forward compatibility**: v1 files (`PALSTAT1` magic, payload
//! version 2 — written before removers existed) still load. Their
//! tables decode with a FIFO remover tag, zeroed eviction counters and
//! zeroed per-item sample counts, which is exactly the state such a
//! run was in. Saves always emit the current (`PALSTAT2`, payload v3)
//! layout.
//!
//! # Failure semantics
//!
//! [`ServiceState::restore_into`] validates EVERY table — names, kinds,
//! buffer implementation, geometry, per-shard consistency — before the
//! first byte of service state is mutated. A corrupt, truncated,
//! version-mismatched or mismatched-topology file therefore fails with
//! a descriptive error and leaves the target service untouched; a table
//! can never be half-loaded.

use super::table::{Table, TableStatsSnapshot};
use super::ReplayService;
use crate::replay::{BufferState, RemoverSpec, ShardState, Transition};
use crate::util::blob::{read_blob_any, write_blob, ByteReader, ByteWriter};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// File-kind magic for replay-service state blobs (current revision).
pub const STATE_MAGIC: &[u8; 8] = b"PALSTAT2";
/// Previous file-kind magic; v1 files carrying it still load (their
/// payload version is 2).
pub const LEGACY_STATE_MAGIC: &[u8; 8] = b"PALSTAT1";
/// Payload layout version (first field of the payload). v2 added the
/// `steps_dropped` counter to each table's stats block; v3 (with the
/// `PALSTAT2` magic) added eviction-by-reason counters +
/// `max_times_sampled` to the stats block, a per-table remover tag,
/// and per-shard per-item sample counts.
pub const STATE_VERSION: u32 = 3;
/// Last legacy payload version this build still decodes.
pub const LEGACY_STATE_VERSION: u32 = 2;
/// Conventional file name inside a run/checkpoint directory.
pub const STATE_FILE: &str = "replay_state.bin";

/// Serialized state of one [`Table`].
#[derive(Clone, Debug, PartialEq)]
pub struct TableState {
    pub name: String,
    /// [`super::ItemKind::tag`] of the table's item kind.
    pub kind_tag: String,
    /// Counter snapshot; `inserts` and `sample_batches` double as the
    /// rate limiter's state.
    pub stats: TableStatsSnapshot,
    /// Eviction policy the table ran with at capture time. Advisory:
    /// restore does NOT require the target table to match (so a v1
    /// file — which decodes as FIFO — restores into any remover
    /// config, and operators may deliberately change policy across a
    /// restart; the data itself is policy-independent).
    pub remover: RemoverSpec,
    pub buffer: BufferState,
}

/// Serialized state of a whole [`ReplayService`].
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceState {
    pub tables: Vec<TableState>,
}

impl ServiceState {
    /// Capture every table. Fails if any table's buffer implementation
    /// does not support checkpointing (the emulated plugin buffers).
    pub fn capture(service: &ReplayService) -> Result<Self> {
        let tables = service
            .tables()
            .iter()
            .map(|t| t.checkpoint())
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { tables })
    }

    /// Validate this state against a service without mutating anything:
    /// table count, per-table existence, names/kinds, buffer impl and
    /// geometry, per-shard consistency. Returns the matched tables in
    /// state order. The single validation pass both [`Self::restore_into`]
    /// and the coordinator's cross-subsystem restore build on — one copy
    /// of the "no half-load" logic.
    pub fn validate_against<'a>(&self, service: &'a ReplayService) -> Result<Vec<&'a Table>> {
        if self.tables.len() != service.tables().len() {
            bail!(
                "state file has {} tables, service has {}",
                self.tables.len(),
                service.tables().len()
            );
        }
        // Duplicate names would let two state entries resolve to ONE
        // service table, leaving another silently unrestored despite
        // the count check passing.
        for (i, a) in self.tables.iter().enumerate() {
            for b in &self.tables[i + 1..] {
                if a.name == b.name {
                    bail!("state file lists table `{}` twice", a.name);
                }
            }
        }
        let mut targets: Vec<&Table> = Vec::with_capacity(self.tables.len());
        for ts in &self.tables {
            let table = service.table(&ts.name).ok_or_else(|| {
                anyhow!("state file table `{}` does not exist in this service", ts.name)
            })?;
            table.validate_restore(ts)?;
            targets.push(table.as_ref());
        }
        Ok(targets)
    }

    /// Apply a state already validated by [`Self::validate_against`] to
    /// the tables that call returned, in state order. The cross-table
    /// topology pass is NOT repeated; each buffer's `restore_state`
    /// still re-checks its own shard consistency once at the point of
    /// mutation (last-gate insurance).
    pub(crate) fn apply_to(&self, targets: &[&Table]) -> Result<()> {
        for (table, ts) in targets.iter().zip(&self.tables) {
            table.apply_restore(ts)?;
        }
        Ok(())
    }

    /// Restore into a freshly built (or at least structurally
    /// identical) service. Two-phase: validate all tables, then apply.
    pub fn restore_into(&self, service: &ReplayService) -> Result<()> {
        let targets = self.validate_against(service)?;
        self.apply_to(&targets)
    }

    /// Total items across all tables.
    pub fn total_len(&self) -> usize {
        self.tables.iter().map(|t| t.buffer.len()).sum()
    }

    /// Find one table's state by name.
    pub fn table(&self, name: &str) -> Option<&TableState> {
        self.tables.iter().find(|t| t.name == name)
    }

    /// Encode to the versioned payload (no header/crc — see [`Self::save`]).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.u32(STATE_VERSION);
        w.u32(self.tables.len() as u32);
        for t in &self.tables {
            w.str_(&t.name);
            w.str_(&t.kind_tag);
            w.u64(t.stats.inserts as u64);
            w.u64(t.stats.sample_batches as u64);
            w.u64(t.stats.sampled_items as u64);
            w.u64(t.stats.priority_updates as u64);
            w.u64(t.stats.insert_stalls as u64);
            w.u64(t.stats.sample_stalls as u64);
            w.u64(t.stats.steps_dropped as u64);
            w.u64(t.stats.evict_fifo as u64);
            w.u64(t.stats.evict_lifo as u64);
            w.u64(t.stats.evict_lowest as u64);
            w.u64(t.stats.evict_sampled as u64);
            w.u64(t.stats.max_times_sampled as u64);
            let (tag, param) = t.remover.tag();
            w.u8(tag);
            w.u32(param);
            w.str_(&t.buffer.impl_name);
            w.u64(t.buffer.capacity as u64);
            w.u32(t.buffer.obs_dim as u32);
            w.u32(t.buffer.act_dim as u32);
            w.u32(t.buffer.shards.len() as u32);
            for s in &t.buffer.shards {
                w.u64(s.cursor);
                w.f32(s.max_priority);
                w.f32s(&s.priorities);
                w.u32s(&s.sample_counts);
                w.u64(s.rows.len() as u64);
                for row in &s.rows {
                    for &v in row.obs.iter().chain(&row.action).chain(&row.next_obs) {
                        w.f32(v);
                    }
                    w.f32(row.reward);
                    w.u8(row.done as u8);
                }
            }
        }
        w.finish()
    }

    /// Decode a payload produced by [`Self::encode`] (payload v3), or
    /// a legacy v2 payload from a `PALSTAT1` file — its tables get a
    /// FIFO remover, zeroed eviction counters and zeroed sample
    /// counts.
    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(payload);
        let version = r.u32("format version")?;
        if version != STATE_VERSION && version != LEGACY_STATE_VERSION {
            bail!(
                "replay state format version mismatch: file is v{version}, \
                 this build reads v{LEGACY_STATE_VERSION} (PALSTAT1) and \
                 v{STATE_VERSION} (PALSTAT2)"
            );
        }
        let legacy = version == LEGACY_STATE_VERSION;
        // Sanity bounds on every count used for allocation, so a
        // corrupted length field fails cleanly instead of attempting an
        // absurd allocation.
        const MAX_TABLES: usize = 4_096;
        const MAX_SHARDS: usize = 65_536;
        const MAX_DIM: usize = 1 << 20;
        let n_tables = r.u32("table count")? as usize;
        if n_tables > MAX_TABLES {
            bail!("implausible table count {n_tables} (corrupted state file?)");
        }
        let mut tables = Vec::with_capacity(n_tables);
        for _ in 0..n_tables {
            let name = r.str_("table name")?;
            let kind_tag = r.str_("table kind")?;
            let mut stats = TableStatsSnapshot {
                inserts: r.u64("inserts")? as usize,
                sample_batches: r.u64("sample_batches")? as usize,
                sampled_items: r.u64("sampled_items")? as usize,
                priority_updates: r.u64("priority_updates")? as usize,
                insert_stalls: r.u64("insert_stalls")? as usize,
                sample_stalls: r.u64("sample_stalls")? as usize,
                steps_dropped: r.u64("steps_dropped")? as usize,
                ..TableStatsSnapshot::default()
            };
            let remover = if legacy {
                RemoverSpec::Fifo
            } else {
                stats.evict_fifo = r.u64("evict_fifo")? as usize;
                stats.evict_lifo = r.u64("evict_lifo")? as usize;
                stats.evict_lowest = r.u64("evict_lowest")? as usize;
                stats.evict_sampled = r.u64("evict_sampled")? as usize;
                stats.max_times_sampled = r.u64("max_times_sampled")? as usize;
                let tag = r.u8("remover tag")?;
                let param = r.u32("remover param")?;
                RemoverSpec::from_tag(tag, param)?
            };
            let impl_name = r.str_("buffer impl")?;
            let capacity = r.u64("capacity")? as usize;
            let obs_dim = r.u32("obs_dim")? as usize;
            let act_dim = r.u32("act_dim")? as usize;
            let n_shards = r.u32("shard count")? as usize;
            if obs_dim > MAX_DIM || act_dim > MAX_DIM || n_shards > MAX_SHARDS {
                bail!(
                    "implausible geometry obs={obs_dim} act={act_dim} shards={n_shards} \
                     (corrupted state file?)"
                );
            }
            let mut shards = Vec::with_capacity(n_shards);
            for _ in 0..n_shards {
                let cursor = r.u64("shard cursor")?;
                let max_priority = r.f32("max priority")?;
                let priorities = r.f32s("priorities")?;
                let sample_counts = if legacy {
                    Vec::new() // resized to n_rows zeros below
                } else {
                    r.u32s("sample counts")?
                };
                let n_rows = r.u64("row count")? as usize;
                if n_rows != priorities.len() {
                    bail!(
                        "shard claims {n_rows} rows for {} priorities",
                        priorities.len()
                    );
                }
                let sample_counts = if legacy {
                    vec![0u32; n_rows]
                } else if sample_counts.len() == n_rows {
                    sample_counts
                } else {
                    bail!(
                        "shard claims {n_rows} rows for {} sample counts",
                        sample_counts.len()
                    );
                };
                let mut rows = Vec::with_capacity(n_rows);
                for _ in 0..n_rows {
                    let mut obs = Vec::with_capacity(obs_dim);
                    for _ in 0..obs_dim {
                        obs.push(r.f32("row obs")?);
                    }
                    let mut action = Vec::with_capacity(act_dim);
                    for _ in 0..act_dim {
                        action.push(r.f32("row action")?);
                    }
                    let mut next_obs = Vec::with_capacity(obs_dim);
                    for _ in 0..obs_dim {
                        next_obs.push(r.f32("row next_obs")?);
                    }
                    let reward = r.f32("row reward")?;
                    let done = r.u8("row done")? != 0;
                    rows.push(Transition { obs, action, next_obs, reward, done });
                }
                shards.push(ShardState { cursor, max_priority, priorities, sample_counts, rows });
            }
            tables.push(TableState {
                name,
                kind_tag,
                stats,
                remover,
                buffer: BufferState { impl_name, capacity, obs_dim, act_dim, shards },
            });
        }
        r.expect_end()?;
        Ok(Self { tables })
    }

    /// Write the state to one file, atomically (temp file + rename).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        write_blob(path.as_ref(), STATE_MAGIC, &self.encode())
            .with_context(|| format!("writing replay state {}", path.as_ref().display()))
    }

    /// Load and fully validate a state file (magic, crc, version,
    /// internal consistency of the encoding). Accepts both the current
    /// `PALSTAT2` magic and legacy `PALSTAT1` files.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let (payload, _which) = read_blob_any(path, &[STATE_MAGIC, LEGACY_STATE_MAGIC])
            .with_context(|| format!("not a PAL replay state file: {}", path.display()))?;
        Self::decode(&payload)
            .with_context(|| format!("decoding replay state {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::{PrioritizedConfig, ReplayBuffer, ShardedPrioritizedReplay, UniformReplay};
    use crate::service::{ItemKind, RateLimiter, SampleOutcome, Table};
    use crate::util::blob;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn tr(v: f32) -> Transition {
        Transition {
            obs: vec![v, -v],
            action: vec![v],
            next_obs: vec![v + 1.0, -v],
            reward: v,
            done: false,
        }
    }

    fn svc(capacity: usize) -> ReplayService {
        let prio = Arc::new(ShardedPrioritizedReplay::new(PrioritizedConfig {
            capacity,
            obs_dim: 2,
            act_dim: 1,
            fanout: 16,
            alpha: 0.6,
            beta: 0.4,
            lazy_writing: true,
            shards: 4,
        }));
        let aux = Arc::new(UniformReplay::new(capacity, 2, 1));
        ReplayService::new(vec![
            Table::new(
                "replay",
                ItemKind::OneStep,
                prio,
                RateLimiter::SampleToInsertRatio(
                    crate::service::SampleToInsertRatio::new(1.0, 8, 16.0).unwrap(),
                ),
            ),
            Table::new(
                "aux",
                ItemKind::NStep { n: 3, gamma: 0.9 },
                aux,
                RateLimiter::Unlimited { min_size_to_sample: 1 },
            ),
        ])
        .unwrap()
    }

    fn drive(service: &ReplayService, items: usize) {
        let mut rng = Rng::new(7);
        let mut out = crate::replay::SampleBatch::default();
        for i in 0..items {
            for t in service.tables() {
                t.can_insert();
                t.insert_from(i % 4, &tr(i as f32));
            }
            if i % 3 == 0 {
                let t = service.default_table();
                if t.try_sample(4, &mut rng, &mut out) == SampleOutcome::Sampled {
                    let idx = out.indices.clone();
                    t.update_priorities(&idx, &vec![rng.f32() * 2.0; idx.len()]);
                }
            }
        }
    }

    #[test]
    fn capture_encode_decode_save_load_roundtrip() {
        let service = svc(64);
        drive(&service, 50);
        let state = ServiceState::capture(&service).unwrap();
        assert_eq!(state.tables.len(), 2);
        assert_eq!(state.table("replay").unwrap().kind_tag, "1step");
        assert_eq!(state.table("aux").unwrap().kind_tag, "nstep:3");
        assert!(state.total_len() > 0);

        // Pure encode/decode.
        let decoded = ServiceState::decode(&state.encode()).unwrap();
        assert_eq!(decoded, state);

        // Disk roundtrip.
        let path = std::env::temp_dir().join("pal_svc_state_test.bin");
        state.save(&path).unwrap();
        assert!(!path.with_extension("tmp").exists(), "save must be atomic");
        let loaded = ServiceState::load(&path).unwrap();
        assert_eq!(loaded, state);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn restore_reproduces_tables_and_limiter_counters() {
        let service = svc(64);
        drive(&service, 50);
        let state = ServiceState::capture(&service).unwrap();

        let fresh = svc(64);
        state.restore_into(&fresh).unwrap();
        for t in fresh.tables() {
            let ts = state.table(t.name()).unwrap();
            assert_eq!(t.len(), ts.buffer.len(), "{}", t.name());
            assert_eq!(t.stats_snapshot(), ts.stats, "{}", t.name());
        }
        // Idempotence: capture(restore(capture(x))) == capture(x).
        assert_eq!(ServiceState::capture(&fresh).unwrap(), state);
    }

    #[test]
    fn restore_rejects_mismatched_topology_without_mutation() {
        let service = svc(64);
        drive(&service, 30);
        let state = ServiceState::capture(&service).unwrap();

        // Wrong capacity.
        let wrong_cap = svc(128);
        assert!(state.restore_into(&wrong_cap).is_err());
        assert_eq!(wrong_cap.total_len(), 0, "failed restore must not mutate");

        // Wrong table name.
        let mut renamed = state.clone();
        renamed.tables[1].name = "other".into();
        let fresh = svc(64);
        assert!(renamed.restore_into(&fresh).is_err());
        assert_eq!(fresh.total_len(), 0);

        // Wrong kind tag.
        let mut rekinded = state.clone();
        rekinded.tables[1].kind_tag = "seq:4".into();
        assert!(rekinded.restore_into(&fresh).is_err());
        assert_eq!(fresh.total_len(), 0);

        // Corrupt SECOND table: the valid first table must not be
        // half-loaded before the failure is noticed.
        let mut corrupt = state;
        corrupt.tables[1].buffer.shards[0].priorities.push(1.0);
        assert!(corrupt.restore_into(&fresh).is_err());
        assert_eq!(fresh.total_len(), 0, "no table may be half-loaded");
    }

    #[test]
    fn duplicate_state_table_names_rejected() {
        // Two state entries with one name would both resolve to the
        // same service table, leaving another table silently
        // unrestored while the count check passes.
        let service = svc(64);
        drive(&service, 20);
        let mut state = ServiceState::capture(&service).unwrap();
        state.tables[1] = state.tables[0].clone();
        let fresh = svc(64);
        let err = state.restore_into(&fresh).unwrap_err();
        assert!(format!("{err:#}").contains("twice"), "{err:#}");
        assert_eq!(fresh.total_len(), 0);
    }

    #[test]
    fn legacy_v2_payload_decodes_with_fifo_defaults_and_restores() {
        // Hand-encode a PALSTAT1-era v2 payload: one uniform table,
        // two rows, the seven-counter stats block, no remover tag, no
        // sample counts.
        let mut w = ByteWriter::new();
        w.u32(LEGACY_STATE_VERSION);
        w.u32(1); // table count
        w.str_("replay");
        w.str_("1step");
        for v in [2u64, 1, 2, 0, 0, 1, 0] {
            w.u64(v);
        }
        w.str_("uniform-ring");
        w.u64(4); // capacity
        w.u32(2); // obs_dim
        w.u32(1); // act_dim
        w.u32(1); // shard count
        w.u64(2); // cursor
        w.f32(1.0); // max_priority
        w.f32s(&[1.0, 1.0]);
        w.u64(2); // row count
        for i in 0..2 {
            let v = i as f32;
            for x in [v, -v, v, v + 1.0, -v] {
                w.f32(x); // obs(2) + action(1) + next_obs(2)
            }
            w.f32(v); // reward
            w.u8(0); // done
        }
        let state = ServiceState::decode(&w.finish()).unwrap();
        let t = state.table("replay").unwrap();
        assert_eq!(t.remover, RemoverSpec::Fifo);
        assert_eq!(t.stats.inserts, 2);
        assert_eq!(t.stats.sample_stalls, 1);
        let zeroed = t.stats.evict_fifo
            + t.stats.evict_lifo
            + t.stats.evict_lowest
            + t.stats.evict_sampled
            + t.stats.max_times_sampled;
        assert_eq!(zeroed, 0);
        assert_eq!(t.buffer.shards[0].sample_counts, vec![0, 0]);
        // The decoded legacy state restores into a live service — even
        // one running a different remover (the spec is advisory).
        let svc = ReplayService::new(vec![Table::new(
            "replay",
            ItemKind::OneStep,
            Arc::new(UniformReplay::with_remover(4, 2, 1, crate::replay::RemoverSpec::Lifo)),
            RateLimiter::Unlimited { min_size_to_sample: 1 },
        )])
        .unwrap();
        state.restore_into(&svc).unwrap();
        assert_eq!(svc.default_table().len(), 2);
        // Re-capturing writes the v3 layout and stays equal modulo the
        // remover spec the live table actually runs.
        let recaptured = ServiceState::capture(&svc).unwrap();
        assert_eq!(recaptured.tables[0].remover, RemoverSpec::Lifo);
        assert_eq!(recaptured.tables[0].buffer, state.tables[0].buffer);
    }

    #[test]
    fn version_mismatch_reported_distinctly() {
        let service = svc(64);
        drive(&service, 10);
        let state = ServiceState::capture(&service).unwrap();
        let mut payload = state.encode();
        payload[0] = 99; // bump the version field
        let path = std::env::temp_dir().join("pal_svc_state_vers.bin");
        blob::write_blob(&path, STATE_MAGIC, &payload).unwrap();
        let err = ServiceState::load(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("version"), "{msg}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn limiter_accounting_continues_exactly_after_restore() {
        // σ = 1, min_diff 0 effectively: with I inserts and B granted
        // batches restored, exactly floor(I·σ − min_diff) − B more
        // batches are grantable before Throttled.
        let mk = || {
            let buf: Arc<dyn ReplayBuffer> = Arc::new(UniformReplay::new(64, 2, 1));
            ReplayService::new(vec![Table::new(
                "replay",
                ItemKind::OneStep,
                buf,
                RateLimiter::SampleToInsertRatio(crate::service::SampleToInsertRatio {
                    samples_per_insert: 1.0,
                    min_size_to_sample: 2,
                    min_diff: 0.0,
                    max_diff: 1e9,
                }),
            )])
            .unwrap()
        };
        let service = mk();
        let t = service.default_table();
        let mut rng = Rng::new(3);
        let mut out = crate::replay::SampleBatch::default();
        for i in 0..10 {
            t.insert_from(0, &tr(i as f32));
        }
        for _ in 0..4 {
            assert_eq!(t.try_sample(2, &mut rng, &mut out), SampleOutcome::Sampled);
        }
        // Live budget left: 10·1 − 4 = 6 batches.
        let state = ServiceState::capture(&service).unwrap();

        let resumed = mk();
        state.restore_into(&resumed).unwrap();
        let t2 = resumed.default_table();
        for k in 0..6 {
            assert_eq!(
                t2.try_sample(2, &mut rng, &mut out),
                SampleOutcome::Sampled,
                "batch {k} after restore"
            );
        }
        assert_eq!(t2.try_sample(2, &mut rng, &mut out), SampleOutcome::Throttled);
    }
}
