//! Replay service: a multi-table experience server in front of the
//! replay buffers — the architectural layer Reverb (Cassirer et al.,
//! 2021) showed production RL systems converge on, built here as an
//! in-process subsystem so actors, learners and the coordinator stop
//! talking to one bare `Arc<dyn ReplayBuffer>`.
//!
//! # Concept map (this crate ⇄ Reverb)
//!
//! | here | Reverb | notes |
//! |------|--------|-------|
//! | [`ReplayService`] | `reverb.Server` | in-process; [`crate::remote`] puts a socket front-end on it |
//! | [`Table`] | `reverb.Table` | named; wraps any [`crate::replay::ReplayBuffer`] impl |
//! | wrapped buffer impl | sampler | prioritized = proportional sampler, uniform = FIFO ring |
//! | [`crate::replay::RemoverSpec`] | `reverb.selectors` (remover) | per-table `remove=` option: `fifo` (default) / `lifo` / `lowest` / `max_sampled:N` |
//! | [`RateLimiter::SampleToInsertRatio`] | `reverb.rate_limiters.SampleToInsertRatio` | σ, `min_size_to_sample`, error bounds |
//! | [`RateLimiter::Unlimited`] | `reverb.rate_limiters.MinSize` | free-run; min-size gate only |
//! | [`TrajectoryWriter`] | `reverb.TrajectoryWriter` | actor-side; 1-step / N-step / sequence items |
//! | [`SamplerHandle`] | `reverb.TFClient.sample` | learner-side; batch draw + priority feedback |
//! | [`ServiceState`] | `reverb.checkpointers` | versioned + checksummed table snapshots, atomic writes |
//! | table ACLs + insert budgets | `reverb.Client` per-table usage | tenant quotas, enforced at the [`crate::remote`] front-end (`Hello` binds the ACL) |
//!
//! # Shape of a training run
//!
//! The coordinator builds one service per run; every actor gets a
//! [`TrajectoryWriter`] (all tables), every learner a [`SamplerHandle`]
//! (the first table, which therefore must store `1step` or `nstep`
//! items — `seq` tables are for auxiliary consumers). Pacing that used
//! to be hardwired into `Control` (`actor_lead` / `update_interval`)
//! is now each table's rate limiter: the legacy flags map onto
//! [`RateLimiter::from_update_interval`], `--rate-limit` selects an
//! explicit σ or free-run. A ratio limiter belongs only on a table
//! something actually samples — writers block while ANY table denies
//! inserts, so the coordinator attaches the configured ratio to the
//! learner-sampled (first) table and lets auxiliary tables free-run
//! (per-table limiter specs are a ROADMAP item). Nothing in the
//! service blocks a thread —
//! writers and samplers sleep-poll admission exactly like the old
//! coordinator gates, so the 1-step/Unlimited configuration is the
//! legacy hot path with one counter bump per op
//! (`benches/fig_service.rs` holds it to parity).

pub mod checkpoint;
pub mod limiter;
pub mod table;
pub mod writer;

pub use checkpoint::{ServiceState, TableState, STATE_FILE};
pub use limiter::{RateLimitSpec, RateLimiter, SampleToInsertRatio};
pub use table::{SampleOutcome, Table, TableStats, TableStatsSnapshot};
pub use writer::{ItemKind, TrajectoryWriter, WriterStep};

use crate::replay::{RemoverSpec, SampleBatch};
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Parsed `--tables` entry: `name=kind[@option,option,...]`, e.g.
/// `replay=1step`, `multi=nstep:3@50000`, `traj=seq:8`,
/// `hot=1step@50000,alpha=0.9,beta=0.6,limit=1.5,remove=max_sampled:4`.
/// Options after `@` are a bare integer (capacity), per-table PER
/// exponent overrides `alpha=..` / `beta=..` (the run's
/// `--alpha`/`--beta` when absent), a per-table rate limiter
/// `limit=..` taking the `--rate-limit` grammar (`legacy`,
/// `unlimited`, or a samples-per-insert float), and a per-table
/// eviction policy `remove=..` taking the `--remove` grammar (`fifo`,
/// `lifo`, `lowest`, `max_sampled:N`) — so one stream can feed a
/// ratio-limited learner table next to a free-running auxiliary one,
/// each with its own policy.
#[derive(Clone, Debug, PartialEq)]
pub struct TableSpec {
    pub name: String,
    pub kind: ItemKind,
    /// Per-table capacity override (run default when `None`).
    pub capacity: Option<usize>,
    /// Per-table PER priority exponent α (run default when `None`).
    pub alpha: Option<f32>,
    /// Per-table PER importance exponent β (run default when `None`).
    pub beta: Option<f32>,
    /// Per-table rate limiter (`limit=..`). `None` keeps the
    /// coordinator's default: the run's `--rate-limit` on the
    /// learner-sampled (first) table, free-run on auxiliaries. A ratio
    /// limiter only belongs on a table something actually samples —
    /// writers block while ANY table denies inserts.
    pub limit: Option<RateLimitSpec>,
    /// Per-table eviction policy (`remove=..`); the run's `--remove`
    /// (FIFO unless overridden) when `None`.
    pub remove: Option<RemoverSpec>,
}

/// Uniform duplicate-key rejection for the `@`-option tokenizer: every
/// key (and the bare capacity) may appear at most once per entry.
fn set_option<T>(slot: &mut Option<T>, key: &str, value: T, spec: &str) -> Result<()> {
    if slot.replace(value).is_some() {
        bail!("duplicate {key} in table spec `{spec}`");
    }
    Ok(())
}

/// Parse an `alpha=` / `beta=` exponent value with a per-key error.
fn parse_exponent(key: &str, value: &str, spec: &str) -> Result<f32> {
    let v: f32 = value.parse().map_err(|_| {
        anyhow!("bad {key} value `{value}` in table spec `{spec}` (expected a float in [0, 1])")
    })?;
    if !v.is_finite() || !(0.0..=1.0).contains(&v) {
        bail!("{key} must be within [0, 1] in table spec `{spec}`, got `{value}`");
    }
    Ok(v)
}

impl TableSpec {
    /// Parse one spec entry; `gamma` seeds N-step folding (the run's
    /// `--gamma-nstep`).
    pub fn parse(s: &str, gamma: f32) -> Result<Self> {
        let (name, rest) = match s.split_once('=') {
            Some((n, r)) => (n.trim(), r.trim()),
            None => bail!("table spec `{s}` must be name=kind[@capacity,alpha=..,beta=..]"),
        };
        if name.is_empty() {
            bail!("table spec `{s}` has an empty name");
        }
        let (kind_str, opts) = match rest.split_once('@') {
            Some((k, o)) => (k, Some(o)),
            None => (rest, None),
        };
        let mut capacity = None;
        let mut alpha = None;
        let mut beta = None;
        let mut limit = None;
        let mut remove = None;
        // One tokenizer for every `@` option: split on commas, then
        // dispatch on the key before `=` (a key-less token is the bare
        // capacity). Each key parses with its own error text; duplicate
        // rejection is uniform via `set_option`.
        for opt in opts.into_iter().flat_map(|o| o.split(',')) {
            let opt = opt.trim();
            if opt.is_empty() {
                bail!("empty option in table spec `{s}`");
            }
            match opt.split_once('=') {
                Some((key, value)) => {
                    let (key, value) = (key.trim(), value.trim());
                    match key {
                        "alpha" => set_option(&mut alpha, key, parse_exponent(key, value, s)?, s)?,
                        "beta" => set_option(&mut beta, key, parse_exponent(key, value, s)?, s)?,
                        "limit" => {
                            let v = RateLimitSpec::parse(value).map_err(|e| {
                                anyhow!("bad limit value `{value}` in table spec `{s}`: {e}")
                            })?;
                            set_option(&mut limit, key, v, s)?;
                        }
                        "remove" => {
                            let v = RemoverSpec::parse(value).map_err(|e| {
                                anyhow!("bad remove value `{value}` in table spec `{s}`: {e}")
                            })?;
                            set_option(&mut remove, key, v, s)?;
                        }
                        other => bail!(
                            "unknown option `{other}` in table spec `{s}` \
                             (expected a capacity, alpha=.., beta=.., limit=.., remove=..)"
                        ),
                    }
                }
                None => {
                    let cap: usize = opt.parse().map_err(|_| {
                        anyhow!(
                            "bad capacity `{opt}` in table spec `{s}` \
                             (a key-less option must be an integer capacity)"
                        )
                    })?;
                    if cap == 0 {
                        bail!("capacity must be > 0 in table spec `{s}`");
                    }
                    set_option(&mut capacity, "capacity", cap, s)?;
                }
            }
        }
        Ok(TableSpec {
            name: name.to_string(),
            kind: ItemKind::parse(kind_str, gamma)?,
            capacity,
            alpha,
            beta,
            limit,
            remove,
        })
    }

    /// Parse a whole `--tables` value. Entries split on commas, but a
    /// comma also separates the options *inside* one entry
    /// (`hot=1step@alpha=0.9,beta=0.6,limit=2,remove=lifo`): a segment
    /// whose key before the first `=` is
    /// `alpha`/`beta`/`limit`/`remove` continues the previous entry
    /// instead of starting a new one. Consequence: `alpha`, `beta`,
    /// `limit` and `remove` are reserved by the grammar and cannot be
    /// used as table names.
    pub fn parse_list(s: &str, gamma: f32) -> Result<Vec<TableSpec>> {
        let mut entries: Vec<String> = Vec::new();
        for seg in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            // A segment continues the previous entry when it is an
            // option (exponent, limiter or remover), or a bare capacity
            // following an entry that already opened its option list (a
            // capacity can never START an entry — entries need
            // `name=kind`).
            let continues = matches!(
                seg.split_once('=').map(|(k, _)| k.trim()),
                Some("alpha") | Some("beta") | Some("limit") | Some("remove")
            ) || (seg.bytes().all(|b| b.is_ascii_digit())
                && entries.last().is_some_and(|p| p.contains('@')));
            match (continues, entries.last_mut()) {
                (true, Some(prev)) => {
                    prev.push(',');
                    prev.push_str(seg);
                }
                (true, None) => bail!(
                    "`{seg}` looks like a per-table option but no table entry \
                     precedes it (`alpha`, `beta`, `limit` and `remove` are \
                     reserved option keys, not usable as table names)"
                ),
                (false, _) => entries.push(seg.to_string()),
            }
        }
        let specs: Vec<TableSpec> =
            entries.iter().map(|e| Self::parse(e, gamma)).collect::<Result<_>>()?;
        // Duplicate names are rejected HERE, with both entries named,
        // instead of surfacing later from service construction (or,
        // worse, silently resolving last-wins in a config merge).
        for (i, spec) in specs.iter().enumerate() {
            if let Some(prev) = specs[..i].iter().position(|p| p.name == spec.name) {
                bail!(
                    "table `{}` is declared twice in `--tables` (entries {prev} and {i}); \
                     table names must be unique",
                    spec.name
                );
            }
        }
        Ok(specs)
    }
}

/// Actor-side experience sink: what an actor loop needs from a replay
/// front-end, whether the tables live in this process
/// ([`TrajectoryWriter`]) or behind a socket
/// ([`crate::remote::RemoteWriter`]). Methods are fallible because the
/// remote implementation does I/O; the in-process one never errors.
pub trait ExperienceWriter: Send {
    /// True while a target table's rate limiter denies inserts; the
    /// actor sleep-polls on this instead of blocking.
    fn throttled(&mut self) -> Result<bool>;

    /// Append one raw env step; returns the number of finished items it
    /// emitted (a remote writer batching steps client-side may report
    /// them on a later call, once the chunk ships and the limiter
    /// admits it).
    fn append(&mut self, step: WriterStep) -> Result<usize>;

    /// Push any client-side pending steps toward the tables now
    /// (ignoring batching thresholds); returns how many remain pending
    /// (> 0 only when a rate limiter stalled the tail — retriable).
    /// In-process writers hand every step to the tables inside
    /// `append`, so the default is a no-op.
    fn flush(&mut self) -> Result<usize> {
        Ok(0)
    }
}

impl ExperienceWriter for TrajectoryWriter {
    fn throttled(&mut self) -> Result<bool> {
        Ok(TrajectoryWriter::throttled(self))
    }

    fn append(&mut self, step: WriterStep) -> Result<usize> {
        Ok(TrajectoryWriter::append(self, step))
    }
}

/// Learner-side experience source: rate-limited batch draws plus
/// priority feedback, in-process ([`SamplerHandle`]) or over a socket
/// ([`crate::remote::RemoteSampler`]).
pub trait ExperienceSampler: Send {
    /// Poll for a batch. The remote implementation samples with a
    /// server-side RNG (seeded at connect) and ignores `rng`.
    fn try_sample(
        &mut self,
        batch: usize,
        rng: &mut Rng,
        out: &mut SampleBatch,
    ) -> Result<SampleOutcome>;

    /// Feed |TD| errors back for a sampled batch.
    fn update_priorities(&mut self, indices: &[usize], td_abs: &[f32]) -> Result<()>;

    /// Wind the sampler down: a pipelined remote sampler consumes its
    /// in-flight prefetch here so the connection closes on a frame
    /// boundary. In-process samplers have nothing in flight.
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

impl ExperienceSampler for SamplerHandle {
    fn try_sample(
        &mut self,
        batch: usize,
        rng: &mut Rng,
        out: &mut SampleBatch,
    ) -> Result<SampleOutcome> {
        Ok(SamplerHandle::try_sample(self, batch, rng, out))
    }

    fn update_priorities(&mut self, indices: &[usize], td_abs: &[f32]) -> Result<()> {
        SamplerHandle::update_priorities(self, indices, td_abs);
        Ok(())
    }
}

/// Learner-side handle onto one table: rate-limited batch draws plus
/// priority feedback. Cheap to clone (one `Arc`).
#[derive(Clone)]
pub struct SamplerHandle {
    table: Arc<Table>,
}

impl SamplerHandle {
    pub fn table(&self) -> &Arc<Table> {
        &self.table
    }

    /// Poll for a batch; see [`Table::try_sample`].
    pub fn try_sample(&self, batch: usize, rng: &mut Rng, out: &mut SampleBatch) -> SampleOutcome {
        self.table.try_sample(batch, rng, out)
    }

    /// Feed |TD| errors back for a sampled batch.
    pub fn update_priorities(&self, indices: &[usize], td_abs: &[f32]) {
        self.table.update_priorities(indices, td_abs);
    }
}

/// The experience server: named tables, writer and sampler handles.
pub struct ReplayService {
    tables: Vec<Arc<Table>>,
}

impl ReplayService {
    /// Build from constructed tables. At least one table; names unique.
    pub fn new(tables: Vec<Table>) -> Result<Self> {
        if tables.is_empty() {
            bail!("replay service needs at least one table");
        }
        for (i, a) in tables.iter().enumerate() {
            for b in &tables[i + 1..] {
                if a.name() == b.name() {
                    bail!("duplicate table name `{}`", a.name());
                }
            }
        }
        Ok(Self { tables: tables.into_iter().map(Arc::new).collect() })
    }

    pub fn tables(&self) -> &[Arc<Table>] {
        &self.tables
    }

    pub fn table(&self, name: &str) -> Option<&Arc<Table>> {
        self.tables.iter().find(|t| t.name() == name)
    }

    /// The table learners train from (first configured).
    pub fn default_table(&self) -> &Arc<Table> {
        &self.tables[0]
    }

    /// A writer handle for one actor, fanning out to every table.
    pub fn writer(&self, actor_id: usize) -> TrajectoryWriter {
        self.writer_for(actor_id, None)
    }

    /// A writer handle restricted to the named tables (`None` = all
    /// tables, same as [`Self::writer`]) — the building block for
    /// per-connection table ACLs at the remote front-end. Names are
    /// expected to be pre-validated against [`Self::table`] (the
    /// server rejects unknown names at `Hello`); a name with no match
    /// here is simply skipped, so the call is infallible.
    pub fn writer_for(&self, actor_id: usize, allowed: Option<&[String]>) -> TrajectoryWriter {
        let tables = match allowed {
            None => self.tables.to_vec(),
            Some(names) => self
                .tables
                .iter()
                .filter(|t| names.iter().any(|n| n == t.name()))
                .cloned()
                .collect(),
        };
        TrajectoryWriter::new(actor_id, tables)
    }

    /// A sampler handle on a named table.
    pub fn sampler(&self, name: &str) -> Option<SamplerHandle> {
        self.table(name).map(|t| SamplerHandle { table: Arc::clone(t) })
    }

    /// A sampler handle on the default (first) table.
    pub fn default_sampler(&self) -> SamplerHandle {
        SamplerHandle { table: Arc::clone(self.default_table()) }
    }

    /// Total items across all tables.
    pub fn total_len(&self) -> usize {
        self.tables.iter().map(|t| t.len()).sum()
    }

    /// Per-table stats for the monitor's progress line.
    pub fn stats_line(&self) -> String {
        self.tables
            .iter()
            .map(|t| t.stats_line())
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Serialize every table (buffers + stats + limiter counters) —
    /// see [`checkpoint::ServiceState::capture`].
    pub fn checkpoint(&self) -> Result<ServiceState> {
        ServiceState::capture(self)
    }

    /// Restore a previously captured state into this (freshly built)
    /// service — see [`checkpoint::ServiceState::restore_into`].
    pub fn restore(&self, state: &ServiceState) -> Result<()> {
        state.restore_into(self)
    }

    /// Absorb another service's captured tables into this LIVE service
    /// — the receiving half of a drain handoff. Unlike
    /// [`Self::restore`], nothing here is overwritten: every donor row
    /// is replayed as an ordinary insert carrying its learned priority
    /// ([`Table::insert_with_priority`]), so existing items keep their
    /// slots and overflow evicts under the receiver's normal policy.
    /// The donor's `steps_dropped` counters ride along so mesh-wide
    /// drop accounting stays exact across the migration. Returns the
    /// number of items absorbed.
    ///
    /// Two-phase like restore: EVERY donor table is validated against
    /// its receiver (name, kind, buffer impl, geometry — the mesh
    /// already requires uniform topology at connect time) before the
    /// first insert, so a mismatched donor cannot half-merge.
    pub fn merge_state(&self, state: &ServiceState) -> Result<u64> {
        let targets = state.validate_against(self)?;
        let mut absorbed = 0u64;
        for (table, ts) in targets.iter().zip(&state.tables) {
            for (s, shard) in ts.buffer.shards.iter().enumerate() {
                // Donor shard index doubles as the actor id so sharded
                // receivers keep the donor's affinity locality.
                for (row, &pri) in shard.rows.iter().zip(&shard.priorities) {
                    table.insert_with_priority(s, row, pri);
                    absorbed += 1;
                }
            }
            table.add_steps_dropped(ts.stats.steps_dropped);
        }
        Ok(absorbed)
    }

    /// Snapshot every table's counters (reported in `TrainReport`).
    pub fn stats_snapshots(&self) -> Vec<(String, TableStatsSnapshot)> {
        self.tables
            .iter()
            .map(|t| (t.name().to_string(), t.stats_snapshot()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::UniformReplay;

    fn svc() -> ReplayService {
        let mk = |name: &str, kind: ItemKind| {
            let m = kind.dim_multiplier();
            Table::new(
                name,
                kind,
                Arc::new(UniformReplay::new(128, 2 * m, m)),
                RateLimiter::Unlimited { min_size_to_sample: 1 },
            )
        };
        ReplayService::new(vec![
            mk("replay", ItemKind::OneStep),
            mk("nstep", ItemKind::NStep { n: 2, gamma: 0.9 }),
        ])
        .unwrap()
    }

    #[test]
    fn table_spec_parses() {
        let s = TableSpec::parse("replay=1step", 0.99).unwrap();
        assert_eq!(s.name, "replay");
        assert_eq!(s.kind, ItemKind::OneStep);
        assert_eq!(s.capacity, None);
        assert_eq!((s.alpha, s.beta), (None, None));
        let s = TableSpec::parse("multi=nstep:3@50000", 0.9).unwrap();
        assert_eq!(s.kind, ItemKind::NStep { n: 3, gamma: 0.9 });
        assert_eq!(s.capacity, Some(50_000));
        let s = TableSpec::parse("hot=1step@50000,alpha=0.9,beta=0.6", 0.99).unwrap();
        assert_eq!(s.capacity, Some(50_000));
        assert_eq!(s.alpha, Some(0.9));
        assert_eq!(s.beta, Some(0.6));
        assert_eq!(s.limit, None);
        let s = TableSpec::parse("hot=1step@limit=2.5", 0.99).unwrap();
        assert_eq!(s.limit, Some(RateLimitSpec::SamplesPerInsert(2.5)));
        let s = TableSpec::parse("aux=seq:4@512,limit=unlimited", 0.99).unwrap();
        assert_eq!(s.limit, Some(RateLimitSpec::Unlimited));
        assert_eq!(s.capacity, Some(512));
        assert!(TableSpec::parse("=1step", 0.99).is_err());
        assert!(TableSpec::parse("noequals", 0.99).is_err());
        assert!(TableSpec::parse("t=seq:4@0", 0.99).is_err());
        assert!(TableSpec::parse("t=1step@limit=fast", 0.99).is_err());
        assert!(TableSpec::parse("t=1step@limit=1,limit=2", 0.99).is_err());
    }

    #[test]
    fn table_spec_remove_option() {
        use crate::replay::RemoverSpec;
        let s = TableSpec::parse("hot=1step@100000,remove=max_sampled:4", 0.99).unwrap();
        assert_eq!(s.capacity, Some(100_000));
        assert_eq!(s.remove, Some(RemoverSpec::MaxTimesSampled(4)));
        let s = TableSpec::parse("hot=1step@remove=lifo,alpha=0.9", 0.99).unwrap();
        assert_eq!(s.remove, Some(RemoverSpec::Lifo));
        assert_eq!(s.alpha, Some(0.9));
        let s = TableSpec::parse("hot=1step", 0.99).unwrap();
        assert_eq!(s.remove, None);
        // Per-key errors: value, duplicates, unknown remover.
        let e = TableSpec::parse("t=1step@remove=oldest", 0.99).unwrap_err();
        assert!(format!("{e:#}").contains("bad remove value"), "{e:#}");
        assert!(TableSpec::parse("t=1step@remove=fifo,remove=lifo", 0.99).is_err());
        assert!(TableSpec::parse("t=1step@remove=max_sampled:0", 0.99).is_err());
        // `remove` continues an entry across the list split and is a
        // reserved key.
        let specs =
            TableSpec::parse_list("hot=1step@16,remove=lowest, cold=1step@remove=fifo", 0.9)
                .unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].remove, Some(RemoverSpec::LowestPriority));
        assert_eq!(specs[1].remove, Some(RemoverSpec::Fifo));
        assert!(TableSpec::parse_list("remove=fifo,replay=1step", 0.9).is_err());
    }

    #[test]
    fn table_spec_list_keeps_exponent_options_attached() {
        let specs = TableSpec::parse_list(
            "replay=1step@alpha=0.7,beta=0.5, aux=nstep:3@1024, flat=1step@alpha=0.0",
            0.9,
        )
        .unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].name, "replay");
        assert_eq!((specs[0].alpha, specs[0].beta), (Some(0.7), Some(0.5)));
        assert_eq!(specs[1].name, "aux");
        assert_eq!(specs[1].capacity, Some(1024));
        assert_eq!((specs[1].alpha, specs[1].beta), (None, None));
        assert_eq!(specs[2].alpha, Some(0.0));
        // A bare capacity after the option list stays attached too.
        let specs = TableSpec::parse_list("t=seq:4@alpha=0.9,beta=0.4,128", 0.9).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].capacity, Some(128));
        assert_eq!((specs[0].alpha, specs[0].beta), (Some(0.9), Some(0.4)));
        // A limit option stays attached to its entry across the list
        // split, like the exponents.
        let specs = TableSpec::parse_list(
            "replay=1step@limit=1.0,alpha=0.7, aux=nstep:3@limit=unlimited",
            0.9,
        )
        .unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].limit, Some(RateLimitSpec::SamplesPerInsert(1.0)));
        assert_eq!(specs[0].alpha, Some(0.7));
        assert_eq!(specs[1].limit, Some(RateLimitSpec::Unlimited));
        // An option with no entry to attach to is an error, as is a
        // bare capacity with no option list to join.
        assert!(TableSpec::parse_list("alpha=0.5", 0.9).is_err());
        assert!(TableSpec::parse_list("beta=0.5,replay=1step", 0.9).is_err());
        assert!(TableSpec::parse_list("limit=2,replay=1step", 0.9).is_err());
        assert!(TableSpec::parse_list("replay=1step,128", 0.9).is_err());
        // Duplicate names are a parse-time error naming both entries,
        // not a later service-construction failure or a silent
        // last-wins merge.
        let e = TableSpec::parse_list("replay=1step,aux=nstep:3,replay=1step@512", 0.9)
            .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("declared twice") && msg.contains("entries 0 and 2"), "{msg}");
    }

    #[test]
    fn duplicate_table_names_rejected() {
        let mk = |name: &str| {
            Table::new(
                name,
                ItemKind::OneStep,
                Arc::new(UniformReplay::new(16, 2, 1)) as Arc<dyn crate::replay::ReplayBuffer>,
                RateLimiter::Unlimited { min_size_to_sample: 1 },
            )
        };
        assert!(ReplayService::new(vec![mk("a"), mk("a")]).is_err());
        assert!(ReplayService::new(vec![]).is_err());
        assert!(ReplayService::new(vec![mk("a"), mk("b")]).is_ok());
    }

    #[test]
    fn writer_for_scopes_the_fan_out() {
        let svc = svc();
        let allowed = vec!["nstep".to_string()];
        let mut w = svc.writer_for(3, Some(&allowed));
        for i in 0..4 {
            w.append(WriterStep {
                obs: vec![i as f32, 0.0],
                action: vec![1.0],
                next_obs: vec![i as f32 + 1.0, 0.0],
                reward: 1.0,
                done: i == 3,
                truncated: false,
            });
        }
        // Only the allowed table received the items.
        assert_eq!(svc.table("replay").unwrap().len(), 0);
        assert_eq!(svc.table("nstep").unwrap().len(), 4);
    }

    #[test]
    fn merge_state_absorbs_donor_rows_and_dropped_steps() {
        let fill = |svc: &ReplayService, actor: usize, n: usize| {
            let mut w = svc.writer(actor);
            for i in 0..n {
                w.append(WriterStep {
                    obs: vec![i as f32, 0.0],
                    action: vec![1.0],
                    next_obs: vec![i as f32 + 1.0, 0.0],
                    reward: 1.0,
                    done: i + 1 == n,
                    truncated: false,
                });
            }
        };
        let donor = svc();
        let receiver = svc();
        fill(&donor, 0, 5);
        fill(&receiver, 1, 3);
        donor.table("replay").unwrap().add_steps_dropped(4);
        let state = donor.checkpoint().unwrap();

        // A mismatched donor is rejected before any mutation.
        let mut bad = state.clone();
        bad.tables[0].name = "other".into();
        assert!(receiver.merge_state(&bad).is_err());
        assert_eq!(receiver.total_len(), 6);

        // The real merge adds the donor's rows on top of the
        // receiver's own and carries the drop counter.
        let absorbed = receiver.merge_state(&state).unwrap();
        assert_eq!(absorbed, 10);
        assert_eq!(receiver.table("replay").unwrap().len(), 8);
        assert_eq!(receiver.table("nstep").unwrap().len(), 8);
        let dropped: usize = receiver
            .stats_snapshots()
            .iter()
            .map(|(_, s)| s.steps_dropped)
            .sum();
        assert_eq!(dropped, 4);
    }

    #[test]
    fn writer_fans_out_and_sampler_reads_back() {
        let svc = svc();
        let mut w = svc.writer(0);
        for i in 0..6 {
            w.append(WriterStep {
                obs: vec![i as f32, 0.0],
                action: vec![1.0],
                next_obs: vec![i as f32 + 1.0, 0.0],
                reward: 1.0,
                done: i == 5,
                truncated: false,
            });
        }
        assert_eq!(svc.table("replay").unwrap().len(), 6);
        assert_eq!(svc.table("nstep").unwrap().len(), 6);
        assert_eq!(svc.total_len(), 12);
        let sampler = svc.sampler("nstep").unwrap();
        let mut rng = crate::util::rng::Rng::new(1);
        let mut out = SampleBatch::default();
        assert_eq!(sampler.try_sample(4, &mut rng, &mut out), SampleOutcome::Sampled);
        assert_eq!(out.len(), 4);
        assert!(svc.sampler("nope").is_none());
        assert!(svc.stats_line().contains("replay[") && svc.stats_line().contains("nstep["));
    }
}
