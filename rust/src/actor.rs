//! Asynchronous actor (paper §V-A): interacts with its own environment
//! instance using snapshot weights and inserts transitions into the
//! shared replay buffer. No synchronization with other actors — acting
//! never mutates weights.

use crate::agent::Agent;
use crate::env::Env;
use crate::metrics::Metrics;
use crate::params::ParameterServer;
use crate::replay::{ReplayBuffer, Transition};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Shared control plane handed to every worker.
pub struct Control {
    pub stop: AtomicBool,
    /// Global environment-step budget (actors stop when exhausted).
    pub max_env_steps: usize,
    /// Env-steps per learn-step the coordinator wants (Alg 1
    /// update_interval). Learners never run ahead of it; actors also
    /// throttle when collection runs too far ahead (two-sided pacing, the
    /// ratio objective of Eq. 5).
    pub update_interval: f64,
    /// Learners hold off until the buffer has this many transitions.
    pub warmup_steps: usize,
    /// Actors may run at most this many env steps ahead of
    /// `learn_steps * update_interval` once warmup is done (0 = actors
    /// free-run, paper's fully-async mode).
    pub actor_lead: usize,
    /// Global counters for pacing (mirrors of Metrics, kept separate so
    /// pacing never takes the metrics mutex).
    pub env_steps: AtomicUsize,
    pub learn_steps: AtomicUsize,
}

impl Control {
    pub fn new(max_env_steps: usize, update_interval: f64, warmup_steps: usize) -> Self {
        Self {
            stop: AtomicBool::new(false),
            max_env_steps,
            update_interval,
            warmup_steps,
            actor_lead: 512,
            env_steps: AtomicUsize::new(0),
            learn_steps: AtomicUsize::new(0),
        }
    }

    #[inline]
    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// True while actors should hold off (collection too far ahead).
    #[inline]
    pub fn actors_ahead(&self) -> bool {
        if self.actor_lead == 0 {
            return false;
        }
        let env = self.env_steps.load(Ordering::Relaxed);
        if env < self.warmup_steps {
            return false;
        }
        let learn = self.learn_steps.load(Ordering::Relaxed);
        (env as f64) > learn as f64 * self.update_interval + self.actor_lead as f64
    }
}

/// Actor main loop. Runs until the step budget is exhausted or stop is
/// requested. `agent` and `env` are thread-local (PJRT objects inside).
#[allow(clippy::too_many_arguments)]
pub fn run_actor(
    actor_id: usize,
    agent: &mut Agent,
    env: &mut dyn Env,
    buffer: &dyn ReplayBuffer,
    server: &ParameterServer,
    metrics: &Metrics,
    ctl: &Control,
    rng: &mut Rng,
) -> Result<()> {
    let mut params: Vec<f32> = Vec::new();
    let mut version = 0u64;
    let mut obs = env.reset(rng);
    let mut ep_return = 0.0f32;

    loop {
        if ctl.should_stop() {
            break;
        }
        // Two-sided ratio pacing: wait while collection is too far ahead
        // of consumption (learners have their own one-sided gate).
        if ctl.actors_ahead() {
            std::thread::sleep(std::time::Duration::from_micros(100));
            continue;
        }
        let step_idx = ctl.env_steps.fetch_add(1, Ordering::Relaxed);
        if step_idx >= ctl.max_env_steps {
            // Un-reserve the overshoot and stop.
            ctl.env_steps.fetch_sub(1, Ordering::Relaxed);
            break;
        }
        // Weight snapshot only when the server moved (cheap version read).
        version = server.sync_online(&mut params, version);

        // §Perf: device-resident parameters, re-uploaded on version bumps.
        let action = agent.act_cached(&params, version, &obs, step_idx, true, rng)?;
        let step = env.step(&action, rng);
        ep_return += step.reward;

        // Truncation is not a true terminal: bootstrap through it.
        let done_flag = step.done && !step.truncated;
        // Actor-affinity insert: sharded buffers route this actor to a
        // fixed shard so concurrent actors take disjoint locks.
        buffer.insert_from(
            actor_id,
            &Transition {
                obs: obs.clone(),
                action,
                next_obs: step.obs.clone(),
                reward: step.reward,
                done: done_flag,
            },
        );
        metrics.inc_env_step();

        if step.done || step.truncated {
            metrics.record_episode(ep_return);
            ep_return = 0.0;
            obs = env.reset(rng);
        } else {
            obs = step.obs;
        }
    }
    Ok(())
}
