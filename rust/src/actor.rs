//! Asynchronous actor (paper §V-A): interacts with its own environment
//! instance using snapshot weights and writes the trajectory into the
//! replay service. No synchronization with other actors — acting never
//! mutates weights.
//!
//! Pacing: the old `actor_lead` / `update_interval` throttle that lived
//! here moved into the replay service's per-table rate limiters
//! ([`crate::service::RateLimiter`]); the actor only sleep-polls its
//! writer's admission, exactly like the old `actors_ahead` gate.

use crate::agent::Agent;
use crate::env::Env;
use crate::metrics::Metrics;
use crate::params::ParameterServer;
use crate::service::{ExperienceWriter, WriterStep};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Shared control plane handed to every worker: the stop flag, the
/// global env-step budget, and the run counters. Ratio pacing is NOT
/// here any more — it belongs to the service's rate limiters.
pub struct Control {
    pub stop: AtomicBool,
    /// Global environment-step budget (actors stop when exhausted).
    pub max_env_steps: usize,
    /// Global counters (mirrors of Metrics, kept separate so budget
    /// checks never take the metrics mutex).
    pub env_steps: AtomicUsize,
    pub learn_steps: AtomicUsize,
}

impl Control {
    pub fn new(max_env_steps: usize) -> Self {
        Self {
            stop: AtomicBool::new(false),
            max_env_steps,
            env_steps: AtomicUsize::new(0),
            learn_steps: AtomicUsize::new(0),
        }
    }

    #[inline]
    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    pub fn request_stop(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// True once the env-step budget is spent (learners use this to
    /// stop waiting on a limiter that can no longer open).
    #[inline]
    pub fn budget_exhausted(&self) -> bool {
        self.env_steps.load(Ordering::Relaxed) >= self.max_env_steps
    }
}

/// Actor main loop. Runs until the step budget is exhausted or stop is
/// requested. `agent` and `env` are thread-local (PJRT objects inside);
/// `writer` is this actor's private handle onto the shared service —
/// in-process ([`crate::service::TrajectoryWriter`]) or remote
/// ([`crate::remote::RemoteWriter`]); the loop cannot tell which.
pub fn run_actor(
    agent: &mut Agent,
    env: &mut dyn Env,
    writer: &mut dyn ExperienceWriter,
    server: &ParameterServer,
    metrics: &Metrics,
    ctl: &Control,
    rng: &mut Rng,
) -> Result<()> {
    let mut params: Vec<f32> = Vec::new();
    let mut version = 0u64;
    let mut obs = env.reset(rng);
    let mut ep_return = 0.0f32;

    loop {
        if ctl.should_stop() {
            break;
        }
        // Rate-limited collection: wait while any target table's limiter
        // says collection is too far ahead of consumption.
        if writer.throttled()? {
            std::thread::sleep(std::time::Duration::from_micros(100));
            continue;
        }
        let step_idx = ctl.env_steps.fetch_add(1, Ordering::Relaxed);
        if step_idx >= ctl.max_env_steps {
            // Un-reserve the overshoot and stop.
            ctl.env_steps.fetch_sub(1, Ordering::Relaxed);
            break;
        }
        // Weight snapshot only when the server moved (cheap version read).
        version = server.sync_online(&mut params, version);

        // §Perf: device-resident parameters, re-uploaded on version bumps.
        let action = agent.act_cached(&params, version, &obs, step_idx, true, rng)?;
        let step = env.step(&action, rng);
        ep_return += step.reward;

        // The writer owns item assembly: 1-step passthrough, N-step
        // folding, sequence flattening, and the
        // bootstrap-through-truncation rule; its actor id gives sharded
        // tables their affinity routing.
        writer.append(WriterStep {
            obs: obs.clone(),
            action,
            next_obs: step.obs.clone(),
            reward: step.reward,
            done: step.done,
            truncated: step.truncated,
        })?;
        metrics.inc_env_step();

        if step.done || step.truncated {
            metrics.record_episode(ep_return);
            ep_return = 0.0;
            obs = env.reset(rng);
        } else {
            obs = step.obs;
        }
    }
    // A batching remote writer may hold a sub-batch tail; push it out
    // so the budget's final steps reach the tables. A limiter stall
    // here is not an error — the run is ending and the writer's drop
    // retries once more.
    let _ = writer.flush()?;
    Ok(())
}
