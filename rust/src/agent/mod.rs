//! Per-algorithm agent behaviour: wraps a compiled [`Model`] and knows
//! how to act (exploration included) and how to run learn steps (which
//! graphs, in what order, with what auxiliary inputs).
//!
//! The framework supports DQN, DDQN, DDPG, TD3 and SAC (paper §V-C); all
//! five share the Algorithm-1 training loop and differ only here.

use crate::replay::SampleBatch;
use crate::runtime::Model;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Algorithm family, parsed from the manifest's `algo` field.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoKind {
    Dqn,
    Ddqn,
    Ddpg,
    Td3,
    Sac,
}

impl AlgoKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "dqn" => AlgoKind::Dqn,
            "ddqn" => AlgoKind::Ddqn,
            "ddpg" => AlgoKind::Ddpg,
            "td3" => AlgoKind::Td3,
            "sac" => AlgoKind::Sac,
            other => bail!("unknown algorithm `{other}`"),
        })
    }

    pub fn discrete(self) -> bool {
        matches!(self, AlgoKind::Dqn | AlgoKind::Ddqn)
    }

    /// Default target-network sync policy.
    pub fn default_target_sync(self) -> crate::params::TargetSync {
        match self {
            AlgoKind::Dqn | AlgoKind::Ddqn => crate::params::TargetSync::Hard { every: 500 },
            _ => crate::params::TargetSync::Polyak { tau: 0.005 },
        }
    }
}

/// Exploration hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct Exploration {
    /// ε-greedy schedule (discrete algos): linear from start to end.
    pub eps_start: f32,
    pub eps_end: f32,
    pub eps_decay_steps: usize,
    /// Gaussian action noise std, in units of act_high (DDPG/TD3).
    pub action_noise: f32,
}

impl Default for Exploration {
    fn default() -> Self {
        Self { eps_start: 1.0, eps_end: 0.05, eps_decay_steps: 10_000, action_noise: 0.1 }
    }
}

impl Exploration {
    pub fn epsilon(&self, step: usize) -> f32 {
        if step >= self.eps_decay_steps {
            return self.eps_end;
        }
        let t = step as f32 / self.eps_decay_steps as f32;
        self.eps_start + t * (self.eps_end - self.eps_start)
    }
}

/// One learner-side gradient bundle: element range + flattened grads.
#[derive(Clone, Debug)]
pub struct GradUpdate {
    pub lo: usize,
    pub hi: usize,
    pub grads: Vec<f32>,
}

/// Result of one learn step.
#[derive(Clone, Debug, Default)]
pub struct LearnOutput {
    pub updates: Vec<GradUpdate>,
    pub td_abs: Vec<f32>,
    pub loss: f32,
}

/// An agent bound to one compiled model (thread-local; the model holds
/// PJRT objects and must not cross threads).
pub struct Agent {
    pub model: Model,
    pub kind: AlgoKind,
    pub explore: Exploration,
    /// TD3 delayed policy updates: run learn_actor every `policy_delay`
    /// critic steps.
    pub policy_delay: usize,
    critic_steps: usize,
    // Reusable input scratch to avoid per-call allocation.
    noise_buf: Vec<f32>,
    // §Perf: device-resident parameter buffers for the act graph, keyed
    // by the parameter-server version — re-uploaded only on version
    // change instead of every env step.
    act_param_cache: Vec<xla::PjRtBuffer>,
    act_cache_version: u64,
}

impl Agent {
    pub fn new(model: Model, explore: Exploration) -> Result<Self> {
        let kind = AlgoKind::parse(&model.info.algo)?;
        let policy_delay = if kind == AlgoKind::Td3 { 2 } else { 1 };
        Ok(Self {
            model,
            kind,
            explore,
            policy_delay,
            critic_steps: 0,
            noise_buf: Vec::new(),
            act_param_cache: Vec::new(),
            act_cache_version: 0,
        })
    }

    /// Convert a manifest grad_slice (param-table indices) into flat
    /// element offsets.
    fn elem_range(&self, slice: (usize, usize)) -> (usize, usize) {
        let ps = &self.model.info.params;
        let lo = ps[slice.0].offset;
        let last = &ps[slice.1 - 1];
        (lo, last.offset + last.size)
    }

    /// Slice of the flat vector for the named parameter.
    fn param_by_name<'a>(&self, flat: &'a [f32], name: &str) -> Result<&'a [f32]> {
        let p = self
            .model
            .info
            .params
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown parameter `{name}`"))?;
        Ok(&flat[p.offset..p.offset + p.size])
    }

    /// Select an action for `obs` using the online weights in `params`.
    ///
    /// `env_step` drives the ε schedule; `explore=false` gives the greedy
    /// / deterministic / mean action for evaluation.
    pub fn act(
        &mut self,
        params: &[f32],
        obs: &[f32],
        env_step: usize,
        explore: bool,
        rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let info = &self.model.info;
        // ε-greedy short-circuits the network entirely.
        if self.kind.discrete() && explore {
            let eps = self.explore.epsilon(env_step);
            if rng.chance(eps as f64) {
                let n = info.n_actions.unwrap_or(2);
                return Ok(vec![rng.below_usize(n) as f32]);
            }
        }
        let graph = self.model.graph("act")?;
        // SAC's act graph takes a noise input (zeros = mean action).
        if self.kind == AlgoKind::Sac {
            let ad = info.act_dim.unwrap_or(1);
            self.noise_buf.clear();
            self.noise_buf.resize(ad, 0.0);
            if explore {
                let mut tmp = std::mem::take(&mut self.noise_buf);
                rng.fill_gaussian(&mut tmp);
                self.noise_buf = tmp;
            }
        }
        // Assemble inputs by the graph's declared (pruned-precise) names.
        let mut inputs: Vec<&[f32]> = Vec::with_capacity(graph.arity());
        for (name, _) in &graph.info.inputs {
            if let Some(pname) = name.strip_prefix("p:") {
                inputs.push(self.param_by_name(params, pname)?);
            } else if name == "obs" {
                inputs.push(obs);
            } else if name == "noise" {
                inputs.push(&self.noise_buf);
            } else {
                anyhow::bail!("act graph: unexpected input `{name}`");
            }
        }
        let mut out = graph.run(&inputs)?;
        let mut action = out.swap_remove(0);
        // Additive Gaussian exploration noise for deterministic policies.
        if explore && matches!(self.kind, AlgoKind::Ddpg | AlgoKind::Td3) {
            let high = info.act_high;
            for a in action.iter_mut() {
                *a = (*a + rng.gaussian_f32(0.0, self.explore.action_noise * high))
                    .clamp(-high, high);
            }
        }
        Ok(action)
    }

    /// §Perf fast path of [`Agent::act`]: parameters live on the device
    /// and are re-uploaded only when `version` changes. With the PJRT CPU
    /// client this removes the per-step parameter upload that dominates
    /// B=1 inference dispatch.
    #[allow(clippy::too_many_arguments)]
    pub fn act_cached(
        &mut self,
        params: &[f32],
        version: u64,
        obs: &[f32],
        env_step: usize,
        explore: bool,
        rng: &mut Rng,
    ) -> Result<Vec<f32>> {
        let info = &self.model.info;
        if self.kind.discrete() && explore {
            let eps = self.explore.epsilon(env_step);
            if rng.chance(eps as f64) {
                let n = info.n_actions.unwrap_or(2);
                return Ok(vec![rng.below_usize(n) as f32]);
            }
        }
        // Refresh the device-resident parameter buffers on version bumps.
        if self.act_cache_version != version || self.act_param_cache.is_empty() {
            let graph = self.model.graph("act")?;
            let mut bufs = Vec::new();
            for (i, (name, _)) in graph.info.inputs.iter().enumerate() {
                if let Some(pname) = name.strip_prefix("p:") {
                    let slice = self.param_by_name(params, pname)?;
                    bufs.push(graph.upload(i, slice)?);
                }
            }
            self.act_param_cache = bufs;
            self.act_cache_version = version;
        }
        if self.kind == AlgoKind::Sac {
            let ad = info.act_dim.unwrap_or(1);
            self.noise_buf.clear();
            self.noise_buf.resize(ad, 0.0);
            if explore {
                let mut tmp = std::mem::take(&mut self.noise_buf);
                rng.fill_gaussian(&mut tmp);
                self.noise_buf = tmp;
            }
        }
        let graph = self.model.graph("act")?;
        let mut inputs: Vec<crate::runtime::Input> = Vec::with_capacity(graph.arity());
        let mut pi = 0usize;
        for (name, _) in &graph.info.inputs {
            if name.starts_with("p:") {
                inputs.push(crate::runtime::Input::Device(&self.act_param_cache[pi]));
                pi += 1;
            } else if name == "obs" {
                inputs.push(crate::runtime::Input::Host(obs));
            } else if name == "noise" {
                inputs.push(crate::runtime::Input::Host(&self.noise_buf));
            } else {
                anyhow::bail!("act graph: unexpected input `{name}`");
            }
        }
        let mut out = graph.run_mixed(&inputs)?;
        let mut action = out.swap_remove(0);
        if explore && matches!(self.kind, AlgoKind::Ddpg | AlgoKind::Td3) {
            let high = info.act_high;
            for a in action.iter_mut() {
                *a = (*a + rng.gaussian_f32(0.0, self.explore.action_noise * high))
                    .clamp(-high, high);
            }
        }
        Ok(action)
    }

    /// Run one learn step on a sampled batch. Returns gradient bundles
    /// (element ranges into the flat vector), |TD| for priority feedback,
    /// and the scalar loss.
    pub fn learn(
        &mut self,
        params: &[f32],
        target_params: &[f32],
        batch: &SampleBatch,
        rng: &mut Rng,
    ) -> Result<LearnOutput> {
        match self.kind {
            AlgoKind::Dqn | AlgoKind::Ddqn | AlgoKind::Ddpg => {
                self.run_learn_graph("learn", params, Some(target_params), batch, false, rng)
            }
            AlgoKind::Td3 | AlgoKind::Sac => {
                let mut out = self.run_learn_graph(
                    "learn_critic",
                    params,
                    Some(target_params),
                    batch,
                    true,
                    rng,
                )?;
                self.critic_steps += 1;
                let actor_now = self.critic_steps % self.policy_delay == 0;
                if actor_now {
                    let actor = self.run_actor_graph(params, batch, rng)?;
                    out.updates.extend(actor.updates);
                    out.loss += actor.loss;
                }
                Ok(out)
            }
        }
    }

    /// Generic learn-graph runner: inputs assembled from the graph's
    /// declared names (`p:`/`t:` parameter references, batch roles, and
    /// `noise`).
    fn run_learn_graph(
        &mut self,
        gname: &str,
        params: &[f32],
        target_params: Option<&[f32]>,
        batch: &SampleBatch,
        wants_noise: bool,
        rng: &mut Rng,
    ) -> Result<LearnOutput> {
        let graph = self.model.graph(gname)?;
        let slice = graph
            .info
            .grad_slice
            .ok_or_else(|| anyhow::anyhow!("graph {gname} lacks grad_slice"))?;
        let (elem_lo, elem_hi) = self.elem_range(slice);

        if wants_noise {
            let n = batch.len() * self.model.info.act_dim.unwrap_or(1);
            self.noise_buf.clear();
            self.noise_buf.resize(n, 0.0);
            let mut tmp = std::mem::take(&mut self.noise_buf);
            rng.fill_gaussian(&mut tmp);
            self.noise_buf = tmp;
        }
        let mut inputs: Vec<&[f32]> = Vec::with_capacity(graph.arity());
        for (name, _) in &graph.info.inputs {
            if let Some(pname) = name.strip_prefix("p:") {
                inputs.push(self.param_by_name(params, pname)?);
            } else if let Some(pname) = name.strip_prefix("t:") {
                let t = target_params
                    .ok_or_else(|| anyhow::anyhow!("{gname} needs target params"))?;
                inputs.push(self.param_by_name(t, pname)?);
            } else {
                inputs.push(match name.as_str() {
                    "obs" => &batch.obs,
                    "action" => &batch.action,
                    "next_obs" => &batch.next_obs,
                    "reward" => &batch.reward,
                    "done" => &batch.done,
                    "is_weights" => &batch.is_weights,
                    "noise" => &self.noise_buf,
                    other => anyhow::bail!("{gname}: unexpected input `{other}`"),
                });
            }
        }
        let outs = graph.run(&inputs)?;
        Ok(assemble_learn_output(outs, elem_lo, elem_hi))
    }

    /// TD3/SAC delayed/auxiliary actor step.
    fn run_actor_graph(
        &mut self,
        params: &[f32],
        batch: &SampleBatch,
        rng: &mut Rng,
    ) -> Result<LearnOutput> {
        let wants_noise = self.kind == AlgoKind::Sac;
        let mut out =
            self.run_learn_graph("learn_actor", params, None, batch, wants_noise, rng)?;
        out.td_abs.clear(); // actor graphs emit placeholder TDs
        Ok(out)
    }
}

/// Flatten [g0, g1, ..., td_abs, loss] into a LearnOutput.
fn assemble_learn_output(mut outs: Vec<Vec<f32>>, elem_lo: usize, elem_hi: usize) -> LearnOutput {
    let loss = outs.pop().map(|l| l[0]).unwrap_or(f32::NAN);
    let td_abs = outs.pop().unwrap_or_default();
    let mut grads = Vec::with_capacity(elem_hi - elem_lo);
    for g in outs {
        grads.extend_from_slice(&g);
    }
    debug_assert_eq!(grads.len(), elem_hi - elem_lo);
    LearnOutput {
        updates: vec![GradUpdate { lo: elem_lo, hi: elem_hi, grads }],
        td_abs,
        loss,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epsilon_schedule_linear() {
        let e =
            Exploration { eps_start: 1.0, eps_end: 0.1, eps_decay_steps: 100, action_noise: 0.1 };
        assert!((e.epsilon(0) - 1.0).abs() < 1e-6);
        assert!((e.epsilon(50) - 0.55).abs() < 1e-6);
        assert!((e.epsilon(100) - 0.1).abs() < 1e-6);
        assert!((e.epsilon(1000) - 0.1).abs() < 1e-6);
    }

    #[test]
    fn algo_parse() {
        assert_eq!(AlgoKind::parse("dqn").unwrap(), AlgoKind::Dqn);
        assert_eq!(AlgoKind::parse("sac").unwrap(), AlgoKind::Sac);
        assert!(AlgoKind::parse("ppo").is_err());
        assert!(AlgoKind::Dqn.discrete());
        assert!(!AlgoKind::Td3.discrete());
    }
}
