//! Discrete-event simulation of the actor/learner/buffer system on an
//! M-core machine with an accelerator.
//!
//! **Why this exists** (DESIGN.md §Substitutions): the paper's Figs 8, 10
//! and 12 measure wall-clock scalability on an 8-core i7 + GTX 1650. This
//! container has one core, so real threads cannot show parallel speedup.
//! The DES models the same system — cores as a resource pool, the replay
//! buffer's locks as exclusive servers, the accelerator as a serialized
//! device — driven by per-operation costs *measured on this machine* (see
//! [`CostProfile::measure`]) so the projected curves keep the paper's
//! shape: linear scaling while CPU-bound, saturation when the accelerator
//! or a global lock becomes the bottleneck.
//!
//! The simulation is intentionally coarse (segment granularity, FIFO
//! resource queues); it is a *model* of contention, not a cycle-accurate
//! replay. Its fidelity claims are limited to ordering and ratio effects:
//! who wins, by what factor, where the knee sits.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Exclusive resources a segment may need besides its core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lock {
    /// `global_tree_lock` of the prioritized buffer.
    GlobalTree,
    /// `last_level_lock` of the prioritized buffer.
    LeafLevel,
    /// The single accelerator (GPU in the paper; PJRT-CPU here).
    Accel,
    /// Parameter-server mutex.
    Server,
    /// `global_tree_lock` of shard `k` of the sharded buffer. Shard 0
    /// aliases [`Lock::GlobalTree`], so S=1 sharded task shapes reduce
    /// exactly to the unsharded ones.
    TreeShard(u8),
}

/// Largest shard count the DES distinguishes (larger values alias the
/// top shard — by then the tree locks are far off the critical path).
pub const MAX_SIM_SHARDS: usize = 16;

const N_LOCKS: usize = 3 + MAX_SIM_SHARDS;

fn lock_idx(l: Lock) -> usize {
    match l {
        Lock::GlobalTree => 0,
        Lock::LeafLevel => 1,
        Lock::Accel => 2,
        Lock::Server => 3,
        Lock::TreeShard(k) => {
            let k = (k as usize).min(MAX_SIM_SHARDS - 1);
            if k == 0 {
                0
            } else {
                3 + k
            }
        }
    }
}

/// One step of a task's cycle: hold the core for `ns`, plus `lock` if set.
#[derive(Clone, Copy, Debug)]
pub struct Segment {
    pub ns: u64,
    pub lock: Option<Lock>,
    /// Segment runs on the accelerator *instead of* a core (learner
    /// gradient computation when the accelerator is the GPU).
    pub on_accel: bool,
}

impl Segment {
    pub fn cpu(ns: u64) -> Self {
        Self { ns, lock: None, on_accel: false }
    }

    pub fn locked(ns: u64, lock: Lock) -> Self {
        Self { ns, lock: Some(lock), on_accel: false }
    }

    pub fn accel(ns: u64) -> Self {
        Self { ns, lock: None, on_accel: true }
    }
}

/// A cyclic task (one actor or one learner).
#[derive(Clone, Debug)]
pub struct Task {
    pub segments: Vec<Segment>,
    /// Which counter this task's completed cycles add to.
    pub counts_as: Counter,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    Collect,
    Consume,
}

/// Simulation result.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimResult {
    pub collect_per_sec: f64,
    pub consume_per_sec: f64,
    /// Fraction of total core-time spent waiting on each lock.
    pub lock_wait_frac: [f64; N_LOCKS],
    pub sim_ns: u64,
    /// Fraction of actor throughput lost to a rate limiter (0 until
    /// [`SimResult::rate_limited`] applies one).
    pub actor_stall_frac: f64,
    /// Fraction of learner throughput lost to a rate limiter.
    pub learner_stall_frac: f64,
}

impl SimResult {
    /// Couple the two free-running throughputs through a
    /// `SampleToInsertRatio` limiter with σ samples per insert: the
    /// steady state obeys `consume = σ · collect`, so whichever side the
    /// raw simulation ran faster stalls down to the ratio and the lost
    /// fraction is recorded as its stall term. The DES itself stays
    /// limiter-free — a limiter is a counter gate, not a lock, so its
    /// effect on steady-state throughput is exactly this coupling.
    pub fn rate_limited(mut self, samples_per_insert: f64) -> SimResult {
        let sigma = samples_per_insert.max(1e-12);
        let (c, l) = (self.collect_per_sec, self.consume_per_sec);
        if c <= 0.0 || l <= 0.0 {
            return self;
        }
        if l > sigma * c {
            // Learners outrun the ratio: sample side stalls.
            self.consume_per_sec = sigma * c;
            self.learner_stall_frac = 1.0 - sigma * c / l;
        } else if c > l / sigma {
            // Collection outruns the ratio: insert side stalls.
            self.collect_per_sec = l / sigma;
            self.actor_stall_frac = 1.0 - (l / sigma) / c;
        }
        self
    }
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum TaskState {
    WaitingCore,
    WaitingLock(usize),
    Running,
}

/// Event-driven simulation of `tasks` on `cores` cores for `horizon_ns`
/// with a single-slot accelerator.
pub fn simulate(tasks: &[Task], cores: usize, horizon_ns: u64) -> SimResult {
    simulate_with(tasks, cores, 1, horizon_ns)
}

/// Simulation with an accelerator of `accel_slots` concurrent batches
/// (GPUs overlap several learners' batches before compute-saturating).
pub fn simulate_with(
    tasks: &[Task],
    cores: usize,
    accel_slots: usize,
    horizon_ns: u64,
) -> SimResult {
    assert!(cores >= 1);
    assert!(accel_slots >= 1);
    let n = tasks.len();
    let mut seg_idx = vec![0usize; n];
    let mut state = vec![TaskState::WaitingCore; n];
    let mut cycles = vec![0u64; n];
    let mut lock_wait_ns = vec![0u64; n];
    let mut wait_since = vec![0u64; n];

    // Resource state.
    let mut free_cores = cores;
    let mut lock_free = [true; N_LOCKS];
    let mut accel_free = accel_slots;
    // FIFO queues per resource.
    let mut core_q: std::collections::VecDeque<usize> = (0..n).collect();
    let mut lock_q: [std::collections::VecDeque<usize>; N_LOCKS] = Default::default();
    let mut accel_q: std::collections::VecDeque<usize> = Default::default();

    // (finish_time, task) completion events.
    let mut events: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut now = 0u64;

    // Try to start task t at `now`; returns true if it started.
    macro_rules! try_start {
        ($t:expr, $now:expr, $events:expr, $free_cores:expr, $lock_free:expr, $accel_free:expr) => {{
            let t = $t;
            let seg = &tasks[t].segments[seg_idx[t]];
            let need_core = !seg.on_accel;
            let core_ok = !need_core || $free_cores > 0;
            let accel_ok = !seg.on_accel || $accel_free > 0;
            let lock_ok = seg.lock.map_or(true, |l| $lock_free[lock_idx(l)]);
            if core_ok && accel_ok && lock_ok {
                if need_core {
                    $free_cores -= 1;
                }
                if seg.on_accel {
                    $accel_free -= 1;
                }
                if let Some(l) = seg.lock {
                    $lock_free[lock_idx(l)] = false;
                }
                if state[t] != TaskState::Running {
                    lock_wait_ns[t] += $now - wait_since[t];
                }
                state[t] = TaskState::Running;
                $events.push(Reverse(($now + seg.ns.max(1), t)));
                true
            } else {
                false
            }
        }};
    }

    // Kick off: everyone queued for a core.
    {
        let mut defer = std::collections::VecDeque::new();
        while let Some(t) = core_q.pop_front() {
            wait_since[t] = 0;
            if !try_start!(t, 0u64, events, free_cores, lock_free, accel_free) {
                let seg = &tasks[t].segments[seg_idx[t]];
                if seg.on_accel && accel_free == 0 {
                    state[t] = TaskState::WaitingLock(lock_idx(Lock::Accel));
                    accel_q.push_back(t);
                } else if let Some(l) = seg.lock.filter(|l| !lock_free[lock_idx(*l)]) {
                    state[t] = TaskState::WaitingLock(lock_idx(l));
                    lock_q[lock_idx(l)].push_back(t);
                } else {
                    defer.push_back(t);
                }
            }
        }
        core_q = defer;
    }

    while let Some(Reverse((t_end, t))) = events.pop() {
        if t_end > horizon_ns {
            now = horizon_ns;
            break;
        }
        now = t_end;
        // Release resources of the finished segment.
        let seg = tasks[t].segments[seg_idx[t]];
        if !seg.on_accel {
            free_cores += 1;
        } else {
            accel_free += 1;
        }
        if let Some(l) = seg.lock {
            lock_free[lock_idx(l)] = true;
        }
        // Advance the task.
        seg_idx[t] += 1;
        if seg_idx[t] == tasks[t].segments.len() {
            seg_idx[t] = 0;
            cycles[t] += 1;
        }
        state[t] = TaskState::WaitingCore;
        wait_since[t] = now;
        core_q.push_back(t);

        // Greedy re-dispatch: wake lock waiters first (they already hold
        // their place), then core waiters.
        for li in 0..N_LOCKS {
            if lock_free[li] || li == lock_idx(Lock::Accel) {
                if let Some(&w) = lock_q[li].front() {
                    if try_start!(w, now, events, free_cores, lock_free, accel_free) {
                        lock_q[li].pop_front();
                    }
                }
            }
        }
        if accel_free > 0 {
            if let Some(&w) = accel_q.front() {
                if try_start!(w, now, events, free_cores, lock_free, accel_free) {
                    accel_q.pop_front();
                }
            }
        }
        let mut requeue = std::collections::VecDeque::new();
        while let Some(w) = core_q.pop_front() {
            if !try_start!(w, now, events, free_cores, lock_free, accel_free) {
                let seg = &tasks[w].segments[seg_idx[w]];
                if seg.on_accel && accel_free == 0 {
                    state[w] = TaskState::WaitingLock(lock_idx(Lock::Accel));
                    accel_q.push_back(w);
                } else if let Some(l) = seg.lock.filter(|l| !lock_free[lock_idx(*l)]) {
                    state[w] = TaskState::WaitingLock(lock_idx(l));
                    lock_q[lock_idx(l)].push_back(w);
                } else {
                    requeue.push_back(w);
                }
            }
        }
        core_q = requeue;
    }

    let secs = (now.max(1)) as f64 / 1e9;
    let mut collect = 0u64;
    let mut consume = 0u64;
    for (i, task) in tasks.iter().enumerate() {
        match task.counts_as {
            Counter::Collect => collect += cycles[i],
            Counter::Consume => consume += cycles[i],
        }
    }
    let total_wait: u64 = lock_wait_ns.iter().sum();
    let mut frac = [0.0; N_LOCKS];
    // Approximate attribution: all wait counted under the first lock the
    // task blocks on; refined attribution isn't needed for the figures.
    frac[0] = total_wait as f64 / (now.max(1) as f64 * n as f64);
    SimResult {
        collect_per_sec: collect as f64 / secs,
        consume_per_sec: consume as f64 / secs,
        lock_wait_frac: frac,
        sim_ns: now,
        actor_stall_frac: 0.0,
        learner_stall_frac: 0.0,
    }
}

/// Build actor/learner task templates from per-op costs (ns).
#[derive(Clone, Copy, Debug)]
pub struct OpCosts {
    /// Actor: policy inference for one step.
    pub act_ns: u64,
    /// Actor: one env.step.
    pub env_ns: u64,
    /// Actor: insert — lock-held tree update portion.
    pub insert_lock_ns: u64,
    /// Actor: insert — data copy portion (outside locks with lazy
    /// writing; inside the global lock for the baseline).
    pub insert_copy_ns: u64,
    /// Learner: batch descent portion (global lock held).
    pub sample_lock_ns: u64,
    /// Learner: batch row copies (outside lock with lazy writing).
    pub batch_copy_ns: u64,
    /// Learner: gradient computation (accelerator).
    pub learn_ns: u64,
    /// Learner: priority update (lock-held).
    pub update_lock_ns: u64,
    /// Learner: parameter-server push.
    pub server_ns: u64,
}

impl OpCosts {
    fn learn_segment(&self, serialized_accel: bool) -> Segment {
        if serialized_accel {
            // Paper testbed: one GPU — learner compute is exclusive.
            Segment::accel(self.learn_ns)
        } else {
            // This host: PJRT-CPU learners, one client per thread —
            // learner compute parallelizes across cores.
            Segment::cpu(self.learn_ns)
        }
    }

    /// Tasks for the PAL design: short lock segments, copies outside.
    /// `serialized_accel` models the paper's single GPU; false models
    /// per-thread PJRT-CPU learners.
    pub fn pal_tasks_accel(
        &self,
        actors: usize,
        learners: usize,
        serialized_accel: bool,
    ) -> Vec<Task> {
        self.pal_tasks_sharded(actors, learners, 1, serialized_accel)
    }

    /// PAL task shapes over an S-shard buffer. Actor `a` inserts into
    /// shard `a % S` (actor affinity → disjoint insert locks); each
    /// learner's two-level sample and batched priority update touch every
    /// shard once, for 1/S of the unsharded critical-section length (the
    /// stratified descents and leaf writes split evenly, and the lock
    /// amortization keeps the per-shard overhead to one acquisition).
    /// `shards = 1` reduces exactly to the unsharded shapes.
    pub fn pal_tasks_sharded(
        &self,
        actors: usize,
        learners: usize,
        shards: usize,
        serialized_accel: bool,
    ) -> Vec<Task> {
        let s = shards.clamp(1, MAX_SIM_SHARDS);
        let mut tasks = Vec::new();
        for a in 0..actors {
            let lock = Lock::TreeShard((a % s) as u8);
            tasks.push(Task {
                segments: vec![
                    Segment::cpu(self.act_ns),
                    Segment::cpu(self.env_ns),
                    Segment::locked(self.insert_lock_ns, lock),
                    Segment::cpu(self.insert_copy_ns), // lazy write: no lock
                    Segment::locked(self.insert_lock_ns, lock),
                ],
                counts_as: Counter::Collect,
            });
        }
        for _ in 0..learners {
            let mut segments = Vec::with_capacity(2 * s + 3);
            for k in 0..s {
                segments.push(Segment::locked(
                    (self.sample_lock_ns / s as u64).max(1),
                    Lock::TreeShard(k as u8),
                ));
            }
            segments.push(Segment::cpu(self.batch_copy_ns)); // copies outside lock
            segments.push(self.learn_segment(serialized_accel));
            for k in 0..s {
                segments.push(Segment::locked(
                    (self.update_lock_ns / s as u64).max(1),
                    Lock::TreeShard(k as u8),
                ));
            }
            segments.push(Segment::locked(self.server_ns, Lock::Server));
            tasks.push(Task {
                segments,
                counts_as: Counter::Consume,
            });
        }
        tasks
    }

    /// PAL tasks with the paper's serialized accelerator.
    pub fn pal_tasks(&self, actors: usize, learners: usize) -> Vec<Task> {
        self.pal_tasks_accel(actors, learners, true)
    }

    /// Baseline tasks: ONE global lock held across everything the buffer
    /// does, including the copies.
    pub fn baseline_tasks_accel(
        &self,
        actors: usize,
        learners: usize,
        serialized_accel: bool,
    ) -> Vec<Task> {
        let mut tasks = Vec::new();
        for _ in 0..actors {
            tasks.push(Task {
                segments: vec![
                    Segment::cpu(self.act_ns),
                    Segment::cpu(self.env_ns),
                    Segment::locked(
                        2 * self.insert_lock_ns + self.insert_copy_ns,
                        Lock::GlobalTree,
                    ),
                ],
                counts_as: Counter::Collect,
            });
        }
        for _ in 0..learners {
            tasks.push(Task {
                segments: vec![
                    Segment::locked(
                        self.sample_lock_ns + self.batch_copy_ns,
                        Lock::GlobalTree,
                    ),
                    self.learn_segment(serialized_accel),
                    Segment::locked(self.update_lock_ns, Lock::GlobalTree),
                    Segment::locked(self.server_ns, Lock::Server),
                ],
                counts_as: Counter::Consume,
            });
        }
        tasks
    }

    /// Baseline tasks with the paper's serialized accelerator.
    pub fn baseline_tasks(&self, actors: usize, learners: usize) -> Vec<Task> {
        self.baseline_tasks_accel(actors, learners, true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs() -> OpCosts {
        OpCosts {
            act_ns: 40_000,
            env_ns: 10_000,
            insert_lock_ns: 1_000,
            insert_copy_ns: 2_000,
            sample_lock_ns: 20_000,
            batch_copy_ns: 10_000,
            learn_ns: 500_000,
            update_lock_ns: 15_000,
            server_ns: 20_000,
        }
    }

    #[test]
    fn single_actor_throughput_matches_cycle_time() {
        let c = costs();
        let tasks = c.pal_tasks(1, 0);
        let r = simulate(&tasks, 1, 1_000_000_000);
        let cycle_ns: u64 = tasks[0].segments.iter().map(|s| s.ns).sum();
        let expect = 1e9 / cycle_ns as f64;
        assert!(
            (r.collect_per_sec - expect).abs() / expect < 0.02,
            "{} vs {expect}",
            r.collect_per_sec
        );
    }

    #[test]
    fn actors_scale_linearly_until_cores_run_out() {
        let c = costs();
        let one = simulate(&c.pal_tasks(1, 0), 8, 500_000_000).collect_per_sec;
        let four = simulate(&c.pal_tasks(4, 0), 8, 500_000_000).collect_per_sec;
        let ratio = four / one;
        assert!(ratio > 3.5, "4-actor speedup only {ratio:.2}");
        // With 2 cores, 4 actors can't exceed ~2x.
        let starved = simulate(&c.pal_tasks(4, 0), 2, 500_000_000).collect_per_sec;
        assert!(starved / one < 2.3, "{}", starved / one);
    }

    #[test]
    fn pal_beats_baseline_under_contention() {
        // Buffer-dominated workload (the Fig 9 regime): cheap act/env so
        // the lock discipline is what differentiates the designs.
        let c = OpCosts {
            act_ns: 1_000,
            env_ns: 500,
            insert_lock_ns: 700,
            insert_copy_ns: 2_500,
            sample_lock_ns: 20_000,
            batch_copy_ns: 15_000,
            learn_ns: 30_000,
            update_lock_ns: 15_000,
            server_ns: 5_000,
        };
        let pal = simulate(&c.pal_tasks(6, 2), 8, 500_000_000);
        let base = simulate(&c.baseline_tasks(6, 2), 8, 500_000_000);
        assert!(
            pal.collect_per_sec > 1.2 * base.collect_per_sec,
            "pal {} vs base {}",
            pal.collect_per_sec,
            base.collect_per_sec
        );
        // And with compute-dominated costs the two designs converge.
        let c2 = costs();
        let pal2 = simulate(&c2.pal_tasks(4, 2), 8, 500_000_000);
        let base2 = simulate(&c2.baseline_tasks(4, 2), 8, 500_000_000);
        assert!(pal2.collect_per_sec >= 0.95 * base2.collect_per_sec);
    }

    #[test]
    fn accelerator_serializes_learners() {
        let c = costs();
        // learn_ns dominates; adding learners beyond 1 cannot scale
        // because the accelerator is exclusive.
        let one = simulate(&c.pal_tasks(0, 1), 8, 500_000_000).consume_per_sec;
        let four = simulate(&c.pal_tasks(0, 4), 8, 500_000_000).consume_per_sec;
        assert!(four / one < 1.4, "accelerator-bound: {}", four / one);
    }

    #[test]
    fn rate_limiter_coupling_stalls_the_faster_side() {
        let base = SimResult {
            collect_per_sec: 1000.0,
            consume_per_sec: 100.0,
            ..Default::default()
        };
        // σ = 1: collection 10x too fast → actors stall to 100/s.
        let r = base.rate_limited(1.0);
        assert!((r.collect_per_sec - 100.0).abs() < 1e-9);
        assert!((r.actor_stall_frac - 0.9).abs() < 1e-9);
        assert_eq!(r.learner_stall_frac, 0.0);
        // σ = 0.01: learners are the fast side → they stall to 10/s.
        let r = base.rate_limited(0.01);
        assert!((r.consume_per_sec - 10.0).abs() < 1e-9);
        assert!(r.learner_stall_frac > 0.89 && r.learner_stall_frac < 0.91);
        assert_eq!(r.actor_stall_frac, 0.0);
        // Exactly on-ratio: nothing stalls.
        let r = base.rate_limited(0.1);
        assert_eq!(r.collect_per_sec, 1000.0);
        assert_eq!(r.consume_per_sec, 100.0);
        assert_eq!(r.actor_stall_frac, 0.0);
        assert_eq!(r.learner_stall_frac, 0.0);
        // Degenerate inputs pass through untouched.
        let z = SimResult::default().rate_limited(1.0);
        assert_eq!(z.collect_per_sec, 0.0);
    }

    #[test]
    fn zero_horizon_safe() {
        let c = costs();
        let r = simulate(&c.pal_tasks(1, 1), 1, 0);
        assert_eq!(r.collect_per_sec, 0.0);
    }

    #[test]
    fn sharded_tasks_reduce_to_unsharded_at_s1() {
        let c = costs();
        let a = c.pal_tasks_accel(3, 2, false);
        let b = c.pal_tasks_sharded(3, 2, 1, false);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.segments.len(), y.segments.len());
            for (sx, sy) in x.segments.iter().zip(&y.segments) {
                assert_eq!(sx.ns, sy.ns);
                assert_eq!(
                    sx.lock.map(super::lock_idx),
                    sy.lock.map(super::lock_idx)
                );
            }
        }
    }

    #[test]
    fn sharding_relieves_tree_lock_contention() {
        // Buffer-dominated workload: long descents/updates make the
        // single tree lock the bottleneck at 8 workers.
        let c = OpCosts {
            act_ns: 1_000,
            env_ns: 500,
            insert_lock_ns: 2_000,
            insert_copy_ns: 1_000,
            sample_lock_ns: 40_000,
            batch_copy_ns: 5_000,
            learn_ns: 10_000,
            update_lock_ns: 30_000,
            server_ns: 1_000,
        };
        let s1 = simulate(&c.pal_tasks_sharded(4, 4, 1, false), 8, 500_000_000);
        let s4 = simulate(&c.pal_tasks_sharded(4, 4, 4, false), 8, 500_000_000);
        let t1 = s1.collect_per_sec + s1.consume_per_sec;
        let t4 = s4.collect_per_sec + s4.consume_per_sec;
        assert!(t4 > 2.0 * t1, "sharding speedup only {:.2}x", t4 / t1);
    }
}
