//! Coordinator: wires the replay service + parameter server + parallel
//! actors + parallel learners into one training run (paper §V, Fig 7).
//!
//! Every worker thread owns its own PJRT runtime (compiled from the same
//! AOT artifacts); weights move between threads only as flat f32 vectors
//! through the parameter server. Experience moves through the
//! [`ReplayService`]: actors hold [`crate::service::TrajectoryWriter`]s,
//! learners hold [`crate::service::SamplerHandle`]s, and the old
//! `actor_lead` / `update_interval` pacing is each table's rate limiter.

use crate::actor::{run_actor, Control};
use crate::agent::{Agent, AlgoKind, Exploration};
use crate::env::make_env;
use crate::learner::run_learner;
use crate::metrics::{CurvePoint, Metrics};
use crate::params::{AdamConfig, Checkpoint, ParameterServer, TargetSync};
use crate::remote::{
    BackoffPolicy, ConnectionPolicy, Endpoint, MeshSampler, MeshWriter, RemoteClient,
    RemoteSampler, RemoteWriter, TableInfo, DEFAULT_REMOTE_BATCH, DEFAULT_RPC_TIMEOUT,
    DEFAULT_SPILL_CAP,
};
use crate::replay::{
    GlobalLockReplay, NaiveScanReplay, PrioritizedConfig, PrioritizedReplay,
    PyBindBinaryReplay, RemoverSpec, ReplayBuffer, ShardedPrioritizedReplay, UniformReplay,
};
use crate::runtime::{Manifest, Runtime};
use crate::service::{
    ExperienceSampler, ExperienceWriter, ItemKind, RateLimitSpec, RateLimiter, ReplayService,
    ServiceState, Table, TableSpec, TableStatsSnapshot, STATE_FILE,
};
use anyhow::{anyhow, bail, Context, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Which replay-buffer implementation to train with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufferKind {
    /// The paper's K-ary sum tree + two locks + lazy writing.
    PalKary,
    /// Binary tree + one global lock (baseline framework).
    GlobalLock,
    /// Uniform ring buffer (no prioritization).
    Uniform,
    /// Fig-11 emulations.
    EmulatedPython,
    EmulatedBinding,
}

impl BufferKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "pal" | "kary" | "pal-kary" => BufferKind::PalKary,
            "global-lock" | "baseline" => BufferKind::GlobalLock,
            "uniform" => BufferKind::Uniform,
            "emulated-python" => BufferKind::EmulatedPython,
            "emulated-binding" => BufferKind::EmulatedBinding,
            other => bail!("unknown buffer kind `{other}`"),
        })
    }
}

/// Full configuration of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub algo: String,
    pub env: String,
    pub artifact_dir: std::path::PathBuf,
    pub actors: usize,
    pub learners: usize,
    pub total_env_steps: usize,
    pub warmup_steps: usize,
    /// Desired env-steps per learn-step (Alg 1 update_interval). Feeds
    /// the legacy rate-limiter mapping (σ = 1/update_interval).
    pub update_interval: f64,
    pub buffer: BufferKind,
    pub buffer_capacity: usize,
    /// Replay shards S (PalKary only): >1 splits each table into S
    /// independent sub-trees with actor-affinity insert routing,
    /// two-level sampling and per-shard batched priority updates.
    pub shards: usize,
    pub fanout: usize,
    pub alpha: f32,
    pub beta: f32,
    pub lr: f32,
    pub grad_clip: f32,
    /// Sub-gradients aggregated per optimizer step (paper: one per
    /// learner batch; >1 emulates synchronous parameter-server rounds).
    pub aggregation: usize,
    /// Max env steps collection may lead consumption×ratio (0 = actors
    /// free-run, the paper's fully-asynchronous mode). Feeds the legacy
    /// rate-limiter mapping (`max_diff = actor_lead · σ`).
    pub actor_lead: usize,
    /// N-step return length for the default table (1 = plain
    /// transitions).
    pub n_step: usize,
    /// Discount used for N-step reward folding.
    pub gamma_nstep: f32,
    /// Explicit table layout (`--tables`); empty = one table named
    /// `replay` whose item kind follows `n_step`.
    pub tables: Vec<TableSpec>,
    /// Run-default eviction policy (`--remove`): which item a full
    /// table evicts to admit an insert. Per-table `remove=` entries in
    /// `--tables` override this for their table only.
    pub remove: RemoverSpec,
    /// Remote replay front-end (`--remote`): endpoints of external
    /// `pal serve` processes (`uds://PATH`, `tcp://HOST:PORT`, or a
    /// bare socket path). Empty = local tables. One endpoint: actors
    /// hold [`RemoteWriter`]s, learners [`RemoteSampler`]s. Two or
    /// more: a replay mesh — actors hold [`MeshWriter`]s routed by
    /// affinity, learners [`MeshSampler`]s drawing across servers by
    /// priority mass. Either way this run builds NO local tables; the
    /// buffer/table/limiter flags belong to the serving processes.
    pub remote: Vec<Endpoint>,
    /// Client-side append batching on a remote run (`--remote-batch`):
    /// each actor's `RemoteWriter` accumulates this many steps per
    /// `Append` RPC. 1 = one RPC per step (the pre-batching wire
    /// behaviour); ignored on local runs.
    pub remote_batch: usize,
    /// Per-RPC socket timeout in seconds on a remote run
    /// (`--rpc-timeout`): an RPC silent longer than this counts as a
    /// transport failure and is handed to the reconnect supervisor.
    pub rpc_timeout_secs: f64,
    /// Overall reconnect deadline in seconds on a remote run
    /// (`--reconnect-deadline`): how long one outage may last before a
    /// supervised connection gives up and fails the worker.
    pub reconnect_deadline_secs: f64,
    /// Bound on each remote writer's outage spill queue
    /// (`--spill-cap`): steps queued past this while the server is
    /// unreachable drop oldest-first, counted in the server's
    /// `steps_dropped` stat once the link heals.
    pub spill_cap: usize,
    /// Mesh mass-cache TTL in milliseconds (`--mass-ttl`): how long a
    /// [`MeshSampler`] may reuse the per-server mass adverts before
    /// re-polling (also bounded to a fixed number of draws). 0 probes
    /// every draw — the exact-lockstep mode the determinism tests pin;
    /// the default trades a few ms of staleness for N fewer RPCs per
    /// batch. Ignored on local and single-server runs.
    pub mass_ttl_ms: f64,
    /// Rate-limiter selection for every table (`--rate-limit`).
    pub rate_limit: RateLimitSpec,
    /// Run-state directory (`--save-state`): weights + replay-service
    /// state are written here atomically at the end of the run and, if
    /// `checkpoint_every_secs > 0`, periodically during it.
    pub save_state: Option<std::path::PathBuf>,
    /// Resume directory (`--restore-state`): weights + replay state are
    /// loaded before any worker starts, so the run continues from the
    /// snapshot's buffers and limiter accounting.
    pub restore_state: Option<std::path::PathBuf>,
    /// Seconds between periodic run-state snapshots (0 = only at the
    /// end of the run). Requires `save_state`.
    pub checkpoint_every_secs: f64,
    pub target_sync: Option<TargetSync>,
    pub exploration: Exploration,
    pub seed: u64,
    /// Stop early once the recent mean return reaches this value.
    pub stop_at_reward: Option<f32>,
    /// Print a progress line every N seconds (0 = silent).
    pub log_every_secs: f64,
}

impl TrainConfig {
    pub fn new(algo: &str, env: &str) -> Self {
        Self {
            algo: algo.to_string(),
            env: env.to_string(),
            artifact_dir: "artifacts".into(),
            actors: 1,
            learners: 1,
            total_env_steps: 20_000,
            warmup_steps: 1_000,
            update_interval: 1.0,
            buffer: BufferKind::PalKary,
            buffer_capacity: 100_000,
            shards: 1,
            fanout: 64,
            alpha: 0.6,
            beta: 0.4,
            lr: 1e-3,
            grad_clip: 10.0,
            aggregation: 1,
            actor_lead: 512,
            n_step: 1,
            gamma_nstep: 0.99,
            tables: Vec::new(),
            remove: RemoverSpec::Fifo,
            remote: Vec::new(),
            remote_batch: DEFAULT_REMOTE_BATCH,
            rpc_timeout_secs: DEFAULT_RPC_TIMEOUT.as_secs_f64(),
            reconnect_deadline_secs: BackoffPolicy::default().deadline.as_secs_f64(),
            spill_cap: DEFAULT_SPILL_CAP,
            mass_ttl_ms: 5.0,
            rate_limit: RateLimitSpec::Legacy,
            save_state: None,
            restore_state: None,
            checkpoint_every_secs: 0.0,
            target_sync: None,
            exploration: Exploration::default(),
            seed: 0,
            stop_at_reward: None,
            log_every_secs: 0.0,
        }
    }

    pub fn artifact_id(&self) -> String {
        format!("{}_{}", self.algo, self.env)
    }

    /// The table layout this run trains with: explicit `--tables` spec,
    /// or one default table whose item kind follows `n_step`.
    pub fn table_specs(&self) -> Vec<TableSpec> {
        if !self.tables.is_empty() {
            return self.tables.clone();
        }
        let kind = if self.n_step > 1 {
            ItemKind::NStep { n: self.n_step, gamma: self.gamma_nstep }
        } else {
            ItemKind::OneStep
        };
        vec![TableSpec {
            name: "replay".to_string(),
            kind,
            capacity: None,
            alpha: None,
            beta: None,
            limit: None,
            remove: None,
        }]
    }

    /// The supervised-connection policy every remote handle of this
    /// run dials under (`--rpc-timeout` / `--reconnect-deadline`).
    pub fn connection_policy(&self) -> ConnectionPolicy {
        ConnectionPolicy {
            rpc_timeout: Duration::from_secs_f64(self.rpc_timeout_secs),
            backoff: BackoffPolicy::default()
                .with_deadline(Duration::from_secs_f64(self.reconnect_deadline_secs)),
        }
    }
}

/// Outcome of a run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub env_steps: usize,
    pub learn_steps: usize,
    pub episodes: usize,
    pub elapsed_secs: f64,
    pub final_mean_return: f64,
    pub curve: Vec<CurvePoint>,
    pub env_steps_per_sec: f64,
    pub learn_steps_per_sec: f64,
    pub reached_target: bool,
    /// Final online/target weights and optimizer step count (for
    /// checkpointing and greedy evaluation).
    pub final_weights: Vec<f32>,
    pub final_target_weights: Vec<f32>,
    pub opt_steps: usize,
    /// Per-table service counters (inserts, granted batches, stalls).
    pub table_stats: Vec<(String, TableStatsSnapshot)>,
}

/// Build one replay buffer with explicit capacity, PER exponents and
/// eviction policy (tables may override the run defaults).
fn make_buffer_with(
    cfg: &TrainConfig,
    capacity: usize,
    obs_dim: usize,
    act_dim: usize,
    alpha: f32,
    beta: f32,
    remove: RemoverSpec,
) -> Arc<dyn ReplayBuffer> {
    let prio_cfg = PrioritizedConfig {
        capacity,
        obs_dim,
        act_dim,
        fanout: cfg.fanout,
        alpha,
        beta,
        lazy_writing: true,
        shards: cfg.shards.max(1),
    };
    match cfg.buffer {
        // S=1 keeps the single-tree fast path (no wrapper indirection).
        BufferKind::PalKary if prio_cfg.shards > 1 => {
            Arc::new(ShardedPrioritizedReplay::with_remover(prio_cfg, remove))
        }
        BufferKind::PalKary => Arc::new(PrioritizedReplay::with_remover(prio_cfg, remove)),
        BufferKind::GlobalLock => Arc::new(GlobalLockReplay::with_remover(
            capacity,
            obs_dim,
            act_dim,
            alpha,
            beta,
            remove,
        )),
        BufferKind::Uniform => {
            Arc::new(UniformReplay::with_remover(capacity, obs_dim, act_dim, remove))
        }
        BufferKind::EmulatedPython => Arc::new(NaiveScanReplay::with_remover(
            capacity,
            obs_dim,
            act_dim,
            alpha,
            beta,
            remove,
        )),
        BufferKind::EmulatedBinding => Arc::new(PyBindBinaryReplay::with_remover(
            capacity,
            obs_dim,
            act_dim,
            alpha,
            beta,
            remove,
        )),
    }
}

/// Build the configured replay buffer with the run-default capacity.
pub fn make_buffer(cfg: &TrainConfig, obs_dim: usize, act_dim: usize) -> Arc<dyn ReplayBuffer> {
    make_buffer_with(
        cfg,
        cfg.buffer_capacity,
        obs_dim,
        act_dim,
        cfg.alpha,
        cfg.beta,
        cfg.remove,
    )
}

/// Build the run's replay service: one table per spec, each wrapping a
/// buffer of the configured kind (sequence tables widen their dims by
/// the window length) and carrying the run's rate limiter.
pub fn build_service(cfg: &TrainConfig, obs_dim: usize, act_dim: usize) -> Result<ReplayService> {
    let specs = cfg.table_specs();
    // Learners sample the first table into base-dims batches, so it
    // cannot be a flattened-sequence table.
    if let ItemKind::Sequence { .. } = specs[0].kind {
        bail!(
            "first table `{}` is a sequence table; learners need a 1step or nstep table first",
            specs[0].name
        );
    }
    let mut tables = Vec::with_capacity(specs.len());
    for (i, spec) in specs.iter().enumerate() {
        let mult = spec.kind.dim_multiplier();
        let capacity = spec.capacity.unwrap_or(cfg.buffer_capacity);
        // Per-table PER exponents: a spec's `@alpha=..,beta=..`
        // overrides the run's globals for that table only.
        let alpha = spec.alpha.unwrap_or(cfg.alpha);
        let beta = spec.beta.unwrap_or(cfg.beta);
        // Eviction policy: a spec's `remove=` wins over `--remove`.
        let remove = spec.remove.unwrap_or(cfg.remove);
        let buffer = make_buffer_with(
            cfg,
            capacity,
            obs_dim * mult,
            act_dim * mult,
            alpha,
            beta,
            remove,
        );
        // A spec's `limit=..` overrides the run default. Without one,
        // only the learner-sampled (first) table gets the ratio limiter:
        // the ratio couples inserts to THIS run's sampling, and writers
        // block while ANY table denies inserts — a ratio limiter on an
        // auxiliary table (whose sample counter never moves, nothing in
        // this process samples it) would throttle every actor forever.
        // A per-table `limit=` is the user asserting something DOES
        // sample that table; the default protects the common case.
        let limiter = match spec.limit {
            Some(per_table) => {
                per_table.build(cfg.update_interval, cfg.warmup_steps, cfg.actor_lead)
            }
            None if i == 0 => cfg
                .rate_limit
                .build(cfg.update_interval, cfg.warmup_steps, cfg.actor_lead),
            None => RateLimiter::Unlimited { min_size_to_sample: cfg.warmup_steps },
        };
        tables.push(Table::new(spec.name.clone(), spec.kind, buffer, limiter));
    }
    ReplayService::new(tables)
}

/// File name of the weights checkpoint inside a run-state directory
/// (the replay state sits next to it as [`STATE_FILE`]).
pub const WEIGHTS_FILE: &str = "weights.bin";

/// Write one unified run-state snapshot into `dir`: the parameter
/// server's weights (`weights.bin`, `params::Checkpoint` format) and
/// the whole replay service (`replay_state.bin`,
/// `service::checkpoint::ServiceState` format). Both files are written
/// atomically (temp file + rename), so a crash mid-snapshot leaves the
/// previous complete snapshot in place.
pub fn save_run_state(
    dir: &std::path::Path,
    server: &ParameterServer,
    service: &ReplayService,
) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating run-state dir {}", dir.display()))?;
    Checkpoint::from_server(server).save(dir.join(WEIGHTS_FILE))?;
    ServiceState::capture(service)?.save(dir.join(STATE_FILE))?;
    Ok(())
}

/// Load a unified run-state snapshot from `dir` into a freshly built
/// parameter server + replay service. Everything is validated before
/// anything is mutated; on error both targets are untouched.
pub fn restore_run_state(
    dir: &std::path::Path,
    server: &ParameterServer,
    service: &ReplayService,
) -> Result<()> {
    let ck = Checkpoint::load(dir.join(WEIGHTS_FILE))?;
    let state = ServiceState::load(dir.join(STATE_FILE))?;
    // Validate the replay state against the service BEFORE touching the
    // parameter server, so a bad state file leaves no partial restore;
    // the apply step reuses the validated targets rather than
    // re-running the topology pass.
    let targets = state.validate_against(service)?;
    server.restore(&ck)?;
    state.apply_to(&targets)?;
    Ok(())
}

/// The remote half of a [`ReplayFront`]: the server endpoint (UDS or
/// TCP), the run's client-side append batch size, and one
/// lazily-connected, auto-reconnecting monitor connection shared by
/// every per-tick `Stats` poll and state RPC — the monitor loop no
/// longer dials the server once per tick.
pub struct RemoteFront {
    endpoint: Endpoint,
    batch: usize,
    policy: ConnectionPolicy,
    spill_cap: usize,
    monitor: std::sync::Mutex<Option<RemoteClient>>,
    /// Times the monitor link was re-established (surfaced as ` rc=N`
    /// in the per-tick stats line, so an unstable server is visible).
    monitor_reconnects: std::sync::atomic::AtomicU64,
}

impl RemoteFront {
    fn new(endpoint: Endpoint, batch: usize, policy: ConnectionPolicy, spill_cap: usize) -> Self {
        Self {
            endpoint,
            batch,
            policy,
            spill_cap,
            monitor: std::sync::Mutex::new(None),
            monitor_reconnects: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Run one RPC closure over the cached monitor connection,
    /// dialling on first use. A transport failure triggers one
    /// supervised (backoff + deadline) reconnect and a retry; any
    /// remaining error drops the connection so the next poll redials —
    /// a restarted server heals transparently.
    fn with_monitor<T>(&self, f: impl Fn(&mut RemoteClient) -> Result<T>) -> Result<T> {
        let mut guard = self.monitor.lock().expect("monitor connection poisoned");
        if guard.is_none() {
            *guard =
                Some(RemoteClient::connect_endpoint_with(&self.endpoint, self.policy.clone())?);
        }
        let c = guard.as_mut().expect("connected above");
        let r = match f(c) {
            Err(e) if crate::remote::client::is_transport_error(&e) => {
                c.reconnect().and_then(|()| {
                    self.monitor_reconnects.fetch_add(1, Ordering::Relaxed);
                    f(c)
                })
            }
            r => r,
        };
        if r.is_err() {
            *guard = None;
        }
        r
    }

    fn stats(&self) -> Result<Vec<TableInfo>> {
        self.with_monitor(|c| c.stats())
    }
}

/// The mesh half of a [`ReplayFront`]: N server endpoints carrying one
/// logical table (see [`crate::remote::mesh`]), with one cached monitor
/// connection per server under the same supervised-reconnect
/// discipline as [`RemoteFront`].
pub struct MeshFront {
    endpoints: Vec<Endpoint>,
    batch: usize,
    policy: ConnectionPolicy,
    spill_cap: usize,
    /// Mass-advert cache TTL handed to every [`MeshSampler`].
    mass_ttl: Duration,
    monitors: Vec<RemoteFront>,
}

impl MeshFront {
    fn new(
        endpoints: Vec<Endpoint>,
        batch: usize,
        policy: ConnectionPolicy,
        spill_cap: usize,
        mass_ttl: Duration,
    ) -> Self {
        let monitors = endpoints
            .iter()
            .map(|ep| RemoteFront::new(ep.clone(), batch, policy.clone(), spill_cap))
            .collect();
        Self { endpoints, batch, policy, spill_cap, mass_ttl, monitors }
    }

    /// Per-server stats, mesh order (one cached connection each).
    fn stats(&self) -> Result<Vec<Vec<TableInfo>>> {
        self.monitors
            .iter()
            .enumerate()
            .map(|(s, m)| {
                m.stats()
                    .with_context(|| format!("mesh server {s} ({})", self.endpoints[s]))
            })
            .collect()
    }

    /// The run-state file holding mesh server `server`'s replay state:
    /// a mesh snapshot is one file per server next to `weights.bin`,
    /// each restored to the same server slot on resume.
    pub fn state_file(server: usize) -> String {
        format!("replay_state.s{server}.bin")
    }
}

/// One table's counters for a monitor progress line (shared by the
/// remote and mesh fronts).
fn table_stats_cell(t: &TableInfo) -> String {
    let mut s = format!(
        "{}[n={} in={} out={} stall i/s={}/{}",
        t.name, t.len, t.stats.inserts, t.stats.sample_batches, t.stats.insert_stalls,
        t.stats.sample_stalls,
    );
    if t.stats.steps_dropped > 0 {
        s.push_str(&format!(" drop={}", t.stats.steps_dropped));
    }
    s.push(']');
    s
}

/// The replay front-end of one training run: the in-process
/// [`ReplayService`] this process built, the endpoint of one external
/// `pal serve` process (`--remote ENDPOINT`), or a mesh of several
/// (`--remote EP1,EP2,..`). Everything the trainer needs —
/// writer/sampler handles, stats, checkpoint/restore — goes through
/// here, so `train()` is transport-agnostic.
pub enum ReplayFront {
    Local(Arc<ReplayService>),
    Remote(RemoteFront),
    Mesh(MeshFront),
}

impl ReplayFront {
    /// Build from a run config: local tables, one remote endpoint, or
    /// a mesh of several.
    pub fn from_config(cfg: &TrainConfig, obs_dim: usize, act_dim: usize) -> Result<Self> {
        let batch = cfg.remote_batch.max(1);
        match cfg.remote.len() {
            0 => Ok(ReplayFront::Local(Arc::new(build_service(cfg, obs_dim, act_dim)?))),
            1 => Ok(ReplayFront::Remote(RemoteFront::new(
                cfg.remote[0].clone(),
                batch,
                cfg.connection_policy(),
                cfg.spill_cap,
            ))),
            _ => Ok(ReplayFront::Mesh(MeshFront::new(
                cfg.remote.clone(),
                batch,
                cfg.connection_policy(),
                cfg.spill_cap,
                Duration::from_secs_f64((cfg.mass_ttl_ms / 1000.0).max(0.0)),
            ))),
        }
    }

    /// The wrapped in-process service, if local.
    pub fn service(&self) -> Option<&Arc<ReplayService>> {
        match self {
            ReplayFront::Local(s) => Some(s),
            ReplayFront::Remote(_) | ReplayFront::Mesh(_) => None,
        }
    }

    /// A writer handle for one actor. Remote writers each own a
    /// connection (parallel actors do not serialize on one stream) and
    /// batch their appends per the run's `--remote-batch`.
    pub fn writer(&self, actor_id: usize) -> Result<Box<dyn ExperienceWriter>> {
        Ok(match self {
            ReplayFront::Local(s) => Box::new(s.writer(actor_id)),
            ReplayFront::Remote(r) => Box::new(
                RemoteWriter::connect_endpoint_with(&r.endpoint, actor_id as u64, r.policy.clone())?
                    .with_batch(r.batch)
                    .with_spill_cap(r.spill_cap),
            ),
            ReplayFront::Mesh(m) => Box::new(
                MeshWriter::connect(&m.endpoints, actor_id as u64, m.policy.clone())?
                    .with_batch(m.batch)
                    .with_spill_cap(m.spill_cap),
            ),
        })
    }

    /// A sampler handle on the default (first) table. `seed` seeds the
    /// remote connection's server-side sampling RNG; the in-process
    /// sampler uses the learner's own RNG instead. Remote samplers run
    /// pipelined: one batch kept in flight behind each priority update.
    pub fn sampler(&self, seed: u64) -> Result<Box<dyn ExperienceSampler>> {
        Ok(match self {
            ReplayFront::Local(s) => Box::new(s.default_sampler()),
            ReplayFront::Remote(r) => Box::new(
                RemoteSampler::connect_default_endpoint_with(&r.endpoint, seed, r.policy.clone())?
                    .with_prefetch(true),
            ),
            ReplayFront::Mesh(m) => Box::new(
                MeshSampler::connect_default(&m.endpoints, seed, m.policy.clone())?
                    .with_mass_ttl(m.mass_ttl),
            ),
        })
    }

    /// Total items across all tables (0 if the remote server is
    /// unreachable — monitoring must not kill a run).
    pub fn total_len(&self) -> usize {
        match self {
            ReplayFront::Local(s) => s.total_len(),
            ReplayFront::Remote(r) => r
                .stats()
                .map(|ts| ts.iter().map(|t| t.len as usize).sum())
                .unwrap_or(0),
            ReplayFront::Mesh(m) => m
                .stats()
                .map(|per| per.iter().flatten().map(|t| t.len as usize).sum())
                .unwrap_or(0),
        }
    }

    /// Per-table stats for the monitor's progress line.
    pub fn stats_line(&self) -> String {
        match self {
            ReplayFront::Local(s) => s.stats_line(),
            ReplayFront::Remote(r) => match r.stats() {
                Ok(tables) => {
                    let mut line =
                        tables.iter().map(table_stats_cell).collect::<Vec<_>>().join(" ");
                    let rc = r.monitor_reconnects.load(Ordering::Relaxed);
                    if rc > 0 {
                        line.push_str(&format!(" rc={rc}"));
                    }
                    line
                }
                Err(e) => format!("remote[{}: {e}]", r.endpoint),
            },
            ReplayFront::Mesh(m) => match m.stats() {
                Ok(per) => {
                    let mut line = per
                        .iter()
                        .enumerate()
                        .map(|(s, tables)| {
                            format!(
                                "s{s}:{}",
                                tables.iter().map(table_stats_cell).collect::<Vec<_>>().join(" ")
                            )
                        })
                        .collect::<Vec<_>>()
                        .join(" ");
                    let rc: u64 = m
                        .monitors
                        .iter()
                        .map(|f| f.monitor_reconnects.load(Ordering::Relaxed))
                        .sum();
                    if rc > 0 {
                        line.push_str(&format!(" rc={rc}"));
                    }
                    line
                }
                Err(e) => format!("mesh[{e:#}]"),
            },
        }
    }

    /// Snapshot every table's counters (reported in `TrainReport`).
    /// Unreachable remote → empty (with a warning), not a dead run.
    pub fn stats_snapshots(&self) -> Vec<(String, TableStatsSnapshot)> {
        match self {
            ReplayFront::Local(s) => s.stats_snapshots(),
            ReplayFront::Remote(r) => match r.stats() {
                Ok(tables) => tables.into_iter().map(|t| (t.name, t.stats)).collect(),
                Err(e) => {
                    eprintln!("[pal] WARNING: remote stats unavailable: {e}");
                    Vec::new()
                }
            },
            // Mesh tables are reported per server (`s0/replay`, ...):
            // the counters live server-side and are NOT summed here, so
            // a skewed mesh stays visible in the report.
            ReplayFront::Mesh(m) => match m.stats() {
                Ok(per) => per
                    .into_iter()
                    .enumerate()
                    .flat_map(|(s, tables)| {
                        tables.into_iter().map(move |t| (format!("s{s}/{}", t.name), t.stats))
                    })
                    .collect(),
                Err(e) => {
                    eprintln!("[pal] WARNING: mesh stats unavailable: {e:#}");
                    Vec::new()
                }
            },
        }
    }

    /// Cheap fail-fast probe for `--save-state`: locally, a capture of
    /// the still-empty service proves the buffer kind can snapshot;
    /// remotely, a `Stats` RPC proves the server is reachable WITHOUT
    /// downloading its (possibly huge) existing state just to throw it
    /// away.
    pub fn probe_save_state(&self) -> Result<()> {
        match self {
            ReplayFront::Local(s) => ServiceState::capture(s).map(|_| ()),
            ReplayFront::Remote(r) => r.stats().map(|_| ()),
            ReplayFront::Mesh(m) => m.stats().map(|_| ()),
        }
    }

    /// Serialize every table — locally, or via the chunked checkpoint
    /// stream. State RPCs use a throwaway connection, NOT the cached
    /// monitor one: a reassembled checkpoint can run to hundreds of
    /// MiB and a connection's receive buffer never shrinks, so routing
    /// it through the long-lived monitor client would pin that memory
    /// for the rest of the run. A mesh has one state *per server* —
    /// use [`Self::save_run_state`] / [`Self::restore_run_state`].
    pub fn capture_state(&self) -> Result<ServiceState> {
        match self {
            ReplayFront::Local(s) => ServiceState::capture(s),
            ReplayFront::Remote(r) => {
                RemoteClient::connect_endpoint_with(&r.endpoint, r.policy.clone())?
                    .checkpoint_state()
            }
            ReplayFront::Mesh(_) => {
                bail!("a mesh front has one replay state per server; use save_run_state")
            }
        }
    }

    /// Restore a captured state — locally (two-phase validate/apply),
    /// or via the chunked upload (the server validates every chunk and
    /// the whole state before mutating). Fresh connection for the same
    /// reason as [`Self::capture_state`].
    pub fn restore_state_snapshot(&self, state: &ServiceState) -> Result<()> {
        match self {
            ReplayFront::Local(s) => state.restore_into(s),
            ReplayFront::Remote(r) => {
                RemoteClient::connect_endpoint_with(&r.endpoint, r.policy.clone())?
                    .restore_state(state)
            }
            ReplayFront::Mesh(_) => {
                bail!("a mesh front has one replay state per server; use restore_run_state")
            }
        }
    }

    /// Front-aware [`save_run_state`]: weights from the local parameter
    /// server plus the replay state of whichever side of the socket
    /// holds the tables (local capture, or the `Checkpoint` RPC), both
    /// written atomically.
    pub fn save_run_state(&self, dir: &std::path::Path, server: &ParameterServer) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating run-state dir {}", dir.display()))?;
        Checkpoint::from_server(server).save(dir.join(WEIGHTS_FILE))?;
        match self {
            // A mesh snapshot is one state file per server (each
            // chunk-streamed off its own connection), restored to the
            // same server slot on resume.
            ReplayFront::Mesh(m) => {
                for (s, ep) in m.endpoints.iter().enumerate() {
                    RemoteClient::connect_endpoint_with(ep, m.policy.clone())?
                        .checkpoint_state()
                        .with_context(|| format!("checkpointing mesh server {s} ({ep})"))?
                        .save(dir.join(MeshFront::state_file(s)))?;
                }
            }
            _ => self.capture_state()?.save(dir.join(STATE_FILE))?,
        }
        Ok(())
    }

    /// Front-aware [`restore_run_state`]. For a remote front the
    /// process-local weights are restored FIRST: if they fail, the
    /// long-lived (possibly shared) replay server is untouched; only
    /// then is the replay state pushed through the `Restore` RPC,
    /// which the server validates in full before mutating a table.
    pub fn restore_run_state(&self, dir: &std::path::Path, server: &ParameterServer) -> Result<()> {
        match self {
            ReplayFront::Local(s) => restore_run_state(dir, server, s),
            ReplayFront::Remote(_) => {
                let ck = Checkpoint::load(dir.join(WEIGHTS_FILE))?;
                let state = ServiceState::load(dir.join(STATE_FILE))?;
                server.restore(&ck)?;
                self.restore_state_snapshot(&state)?;
                Ok(())
            }
            ReplayFront::Mesh(m) => {
                let ck = Checkpoint::load(dir.join(WEIGHTS_FILE))?;
                // Load and validate every per-server file BEFORE
                // touching the parameter server or any replay server:
                // a missing file (e.g. the snapshot came from a
                // different mesh size) must leave everything untouched.
                let mut states = Vec::with_capacity(m.endpoints.len());
                for s in 0..m.endpoints.len() {
                    states.push(ServiceState::load(dir.join(MeshFront::state_file(s))).with_context(
                        || {
                            format!(
                                "loading mesh server {s}'s replay state (a {}-server mesh \
                                 resumes from one state file per server)",
                                m.endpoints.len()
                            )
                        },
                    )?);
                }
                server.restore(&ck)?;
                for (s, (ep, state)) in m.endpoints.iter().zip(&states).enumerate() {
                    RemoteClient::connect_endpoint_with(ep, m.policy.clone())?
                        .restore_state(state)
                        .with_context(|| format!("restoring mesh server {s} ({ep})"))?;
                }
                Ok(())
            }
        }
    }
}

/// Run one full training session. Blocks until the env-step budget is
/// exhausted (or early-stop). Thread layout: `actors` actor threads +
/// `learners` learner threads + this monitor thread.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    let info = manifest.get(&cfg.artifact_id())?.clone();
    let kind = AlgoKind::parse(&info.algo)?;

    let init = info.load_initial_params()?;
    let sync = cfg.target_sync.unwrap_or_else(|| kind.default_target_sync());
    let server = Arc::new(ParameterServer::new(
        init,
        AdamConfig { lr: cfg.lr, grad_clip: cfg.grad_clip, ..Default::default() },
        sync,
        cfg.aggregation,
    ));
    let front = ReplayFront::from_config(cfg, info.obs_dim, info.flat_act_dim)?;
    if cfg.checkpoint_every_secs > 0.0 && cfg.save_state.is_none() {
        bail!("--checkpoint-every requires --save-state DIR");
    }
    if cfg.save_state.is_some() {
        // Fail fast on a front-end that cannot snapshot (the emulated
        // plugin buffers) or an unreachable remote server: erroring
        // here beats training for hours and losing the run at the
        // final save.
        front.probe_save_state().context(
            "--save-state: this run's replay front-end cannot be checkpointed",
        )?;
    }
    if let Some(dir) = &cfg.restore_state {
        front
            .restore_run_state(dir, &server)
            .with_context(|| format!("restoring run state from {}", dir.display()))?;
        eprintln!(
            "[pal] resumed from {}: {} replay items, {} optimizer steps",
            dir.display(),
            front.total_len(),
            server.opt_steps(),
        );
    }
    let metrics = Arc::new(Metrics::new());
    let ctl = Arc::new(Control::new(cfg.total_env_steps));

    let mut root_rng = crate::util::rng::Rng::new(cfg.seed);
    let worker_seeds: Vec<u64> = (0..cfg.actors + cfg.learners)
        .map(|_| root_rng.next_u64())
        .collect();

    std::thread::scope(|s| -> Result<()> {
        let front = &front;
        let mut handles = Vec::new();
        for a in 0..cfg.actors {
            let info = info.clone();
            let server = Arc::clone(&server);
            let metrics = Arc::clone(&metrics);
            let ctl = Arc::clone(&ctl);
            let env_name = cfg.env.clone();
            let explore = cfg.exploration;
            let seed = worker_seeds[a];
            handles.push(s.spawn(move || -> Result<()> {
                // Setup errors (missing runtime, unreachable remote
                // server) must stop the run like loop errors do, not
                // leave the other workers spinning.
                let r = (|| -> Result<()> {
                    let rt = Runtime::cpu()?;
                    let model = rt.load_model(&info)?;
                    let mut agent = Agent::new(model, explore)?;
                    let mut env = make_env(&env_name)
                        .ok_or_else(|| anyhow!("unknown env {env_name}"))?;
                    let mut rng = crate::util::rng::Rng::new(seed);
                    let mut writer = front.writer(a)?;
                    run_actor(
                        &mut agent, env.as_mut(), writer.as_mut(), &server, &metrics,
                        &ctl, &mut rng,
                    )
                })();
                // An actor finishing its budget is normal; an actor
                // erroring must stop the whole run.
                if r.is_err() {
                    ctl.request_stop();
                }
                r.with_context(|| format!("actor {a}"))
            }));
        }
        for l in 0..cfg.learners {
            let info = info.clone();
            let server = Arc::clone(&server);
            let metrics = Arc::clone(&metrics);
            let ctl = Arc::clone(&ctl);
            let explore = cfg.exploration;
            let seed = worker_seeds[cfg.actors + l];
            handles.push(s.spawn(move || -> Result<()> {
                let r = (|| -> Result<()> {
                    let rt = Runtime::cpu()?;
                    let model = rt.load_model(&info)?;
                    let mut agent = Agent::new(model, explore)?;
                    let mut rng = crate::util::rng::Rng::new(seed);
                    let mut sampler = front.sampler(seed)?;
                    run_learner(
                        l, &mut agent, sampler.as_mut(), &server, &metrics, &ctl,
                        &mut rng,
                    )
                })();
                if r.is_err() {
                    ctl.request_stop();
                }
                r.with_context(|| format!("learner {l}"))
            }));
        }

        // Monitor loop: progress logging (worker metrics + service
        // limiter/stall stats), periodic run-state snapshots, early
        // stop, shutdown.
        let mut last_log = std::time::Instant::now();
        let mut last_ckpt = std::time::Instant::now();
        loop {
            std::thread::sleep(Duration::from_millis(20));
            let env_steps = ctl.env_steps.load(Ordering::Relaxed);
            if cfg.log_every_secs > 0.0
                && last_log.elapsed().as_secs_f64() >= cfg.log_every_secs
            {
                eprintln!("[pal] {} | {}", metrics.summary(), front.stats_line());
                last_log = std::time::Instant::now();
            }
            if cfg.checkpoint_every_secs > 0.0
                && last_ckpt.elapsed().as_secs_f64() >= cfg.checkpoint_every_secs
            {
                // Snapshot while workers run: each shard is captured
                // under its lock pair, the atomic write keeps the
                // previous snapshot intact until the new one is
                // complete. A failed write warns but never kills the
                // run it exists to protect.
                let dir = cfg.save_state.as_ref().expect("checked above");
                if let Err(e) = front.save_run_state(dir, &server) {
                    eprintln!("[pal] WARNING: periodic checkpoint failed: {e:#}");
                }
                last_ckpt = std::time::Instant::now();
            }
            if let Some(target) = cfg.stop_at_reward {
                if metrics.mean_return().map_or(false, |r| r >= target as f64)
                    && metrics.episodes.load(Ordering::Relaxed) >= 10
                {
                    ctl.request_stop();
                }
            }
            if env_steps >= cfg.total_env_steps || ctl.should_stop() {
                // Give learners a moment to drain the remaining ratio
                // budget, then stop everyone.
                std::thread::sleep(Duration::from_millis(50));
                ctl.request_stop();
                break;
            }
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(())
    })?;

    // Final (quiescent) run-state snapshot: all workers have joined, so
    // this one is exact — the file a later `--restore-state` resumes.
    if let Some(dir) = &cfg.save_state {
        front
            .save_run_state(dir, &server)
            .with_context(|| format!("saving run state to {}", dir.display()))?;
        eprintln!(
            "[pal] run state saved to {} ({} replay items)",
            dir.display(),
            front.total_len(),
        );
    }

    let reached = cfg
        .stop_at_reward
        .map(|t| metrics.mean_return().map_or(false, |r| r >= t as f64))
        .unwrap_or(false);
    Ok(TrainReport {
        final_weights: server.online_copy(),
        final_target_weights: server.target_copy(),
        opt_steps: server.opt_steps(),
        env_steps: ctl.env_steps.load(Ordering::Relaxed),
        learn_steps: ctl.learn_steps.load(Ordering::Relaxed),
        episodes: metrics.episodes.load(Ordering::Relaxed),
        elapsed_secs: metrics.elapsed_secs(),
        final_mean_return: metrics.mean_return().unwrap_or(f64::NAN),
        curve: metrics.curve(),
        env_steps_per_sec: metrics.env_throughput(),
        learn_steps_per_sec: metrics.learn_throughput(),
        reached_target: reached,
        table_stats: front.stats_snapshots(),
    })
}

/// Greedy evaluation: run `episodes` episodes with exploration off using
/// the given weights; returns mean episode return.
pub fn evaluate(cfg: &TrainConfig, weights: &[f32], episodes: usize) -> Result<f64> {
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    let info = manifest.get(&cfg.artifact_id())?.clone();
    let rt = Runtime::cpu()?;
    let model = rt.load_model(&info)?;
    let mut agent = Agent::new(model, cfg.exploration)?;
    let mut env =
        make_env(&cfg.env).ok_or_else(|| anyhow!("unknown env {}", cfg.env))?;
    let mut rng = crate::util::rng::Rng::new(cfg.seed ^ 0xE7A1_5EED);
    let mut total = 0.0f64;
    for _ in 0..episodes {
        let mut obs = env.reset(&mut rng);
        let mut ep = 0.0f32;
        loop {
            let action = agent.act(weights, &obs, usize::MAX, false, &mut rng)?;
            let step = env.step(&action, &mut rng);
            ep += step.reward;
            if step.done || step.truncated {
                break;
            }
            obs = step.obs;
        }
        total += ep as f64;
    }
    Ok(total / episodes as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_table_specs_follow_n_step() {
        let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
        let specs = cfg.table_specs();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].name, "replay");
        assert_eq!(specs[0].kind, ItemKind::OneStep);
        cfg.n_step = 3;
        assert_eq!(
            cfg.table_specs()[0].kind,
            ItemKind::NStep { n: 3, gamma: cfg.gamma_nstep }
        );
    }

    #[test]
    fn build_service_honors_specs_and_rejects_seq_first() {
        let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
        cfg.buffer_capacity = 1_024;
        cfg.tables = vec![
            TableSpec {
                name: "replay".into(),
                kind: ItemKind::OneStep,
                capacity: None,
                alpha: None,
                beta: None,
                limit: None,
                remove: None,
            },
            TableSpec {
                name: "traj".into(),
                kind: ItemKind::Sequence { len: 4 },
                capacity: Some(512),
                alpha: None,
                beta: None,
                limit: None,
                remove: None,
            },
        ];
        let svc = build_service(&cfg, 4, 2).unwrap();
        assert_eq!(svc.tables().len(), 2);
        assert_eq!(svc.default_table().name(), "replay");
        assert_eq!(svc.table("traj").unwrap().capacity(), 512);
        // Auxiliary tables must free-run: nothing in this process
        // samples them, so a ratio limiter there would throttle every
        // writer forever (deadlock).
        assert_eq!(
            *svc.table("traj").unwrap().limiter(),
            RateLimiter::Unlimited { min_size_to_sample: cfg.warmup_steps }
        );
        cfg.tables.rotate_right(1); // sequence table first → error
        assert!(build_service(&cfg, 4, 2).is_err());
    }

    #[test]
    fn per_table_exponents_override_run_globals() {
        // Two tables over one stream: the run's α/β plus a per-table
        // override — both must build, and the override table's
        // prioritization must actually differ (α=0 samples uniformly,
        // so repeated priority feedback must not skew it).
        let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
        cfg.buffer_capacity = 256;
        cfg.alpha = 1.0;
        cfg.beta = 0.4;
        cfg.warmup_steps = 1;
        cfg.tables = TableSpec::parse_list(
            "hot=1step,flat=1step@alpha=0.0,beta=1.0",
            cfg.gamma_nstep,
        )
        .unwrap();
        let svc = build_service(&cfg, 2, 1).unwrap();
        let mut w = svc.writer(0);
        for i in 0..64 {
            w.append(crate::service::WriterStep {
                obs: vec![i as f32, 0.0],
                action: vec![0.0],
                next_obs: vec![i as f32 + 1.0, 0.0],
                reward: 0.0,
                done: false,
                truncated: false,
            });
        }
        // Blow up one item's priority on both tables; with α=1 the hot
        // table concentrates on it, with α=0 the flat table must not.
        for t in svc.tables() {
            t.update_priorities(&[7], &[1_000.0]);
        }
        let mut rng = crate::util::rng::Rng::new(11);
        let mut out = crate::replay::SampleBatch::default();
        let mut count_hits = |table: &str, rng: &mut crate::util::rng::Rng| {
            let mut hits = 0usize;
            let sampler = svc.sampler(table).unwrap();
            for _ in 0..64 {
                assert_eq!(
                    sampler.try_sample(8, rng, &mut out),
                    crate::service::SampleOutcome::Sampled
                );
                hits += out.indices.iter().filter(|&&i| i == 7).count();
            }
            hits
        };
        let hot_hits = count_hits("hot", &mut rng);
        let flat_hits = count_hits("flat", &mut rng);
        assert!(
            hot_hits > flat_hits + 50,
            "α=1 table must concentrate on the boosted item: hot {hot_hits} vs flat {flat_hits}"
        );
    }

    #[test]
    fn remove_spec_overrides_run_default_eviction() {
        // `remove=` on an entry wins over `--remove`; entries without
        // one inherit the run default.
        let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
        cfg.buffer = BufferKind::Uniform;
        cfg.buffer_capacity = 64;
        cfg.remove = RemoverSpec::Lifo;
        cfg.tables =
            TableSpec::parse_list("hot=1step@remove=max_sampled:2,cold=1step", cfg.gamma_nstep)
                .unwrap();
        let svc = build_service(&cfg, 2, 1).unwrap();
        assert_eq!(
            svc.table("hot").unwrap().buffer().remover(),
            RemoverSpec::MaxTimesSampled(2)
        );
        assert_eq!(svc.table("cold").unwrap().buffer().remover(), RemoverSpec::Lifo);
    }

    #[test]
    fn legacy_limiter_built_by_default() {
        let cfg = TrainConfig::new("dqn", "CartPole-v1");
        let svc = build_service(&cfg, 4, 2).unwrap();
        match svc.default_table().limiter() {
            crate::service::RateLimiter::SampleToInsertRatio(r) => {
                assert!((r.samples_per_insert - 1.0).abs() < 1e-12);
                assert_eq!(r.min_size_to_sample, cfg.warmup_steps);
            }
            other => panic!("expected legacy ratio limiter, got {other:?}"),
        }
    }

    #[test]
    fn per_table_limit_specs_override_the_run_default() {
        // `limit=` on an entry wins over the first-table/auxiliary
        // default in both directions: an unlimited learner table next
        // to a ratio-limited auxiliary one.
        let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
        cfg.warmup_steps = 32;
        cfg.tables = TableSpec::parse_list(
            "replay=1step@limit=unlimited,aux=nstep:3@limit=2.0,free=1step",
            cfg.gamma_nstep,
        )
        .unwrap();
        let svc = build_service(&cfg, 4, 2).unwrap();
        assert_eq!(
            *svc.table("replay").unwrap().limiter(),
            RateLimiter::Unlimited { min_size_to_sample: cfg.warmup_steps }
        );
        match svc.table("aux").unwrap().limiter() {
            RateLimiter::SampleToInsertRatio(r) => {
                assert!((r.samples_per_insert - 2.0).abs() < 1e-12);
                assert_eq!(r.min_size_to_sample, cfg.warmup_steps);
            }
            other => panic!("expected ratio limiter on aux, got {other:?}"),
        }
        // No `limit=` on a non-first table keeps the free-run default.
        assert_eq!(
            *svc.table("free").unwrap().limiter(),
            RateLimiter::Unlimited { min_size_to_sample: cfg.warmup_steps }
        );
    }
}
