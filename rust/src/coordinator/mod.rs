//! Coordinator: wires the replay service + parameter server + parallel
//! actors + parallel learners into one training run (paper §V, Fig 7).
//!
//! Every worker thread owns its own PJRT runtime (compiled from the same
//! AOT artifacts); weights move between threads only as flat f32 vectors
//! through the parameter server. Experience moves through the
//! [`ReplayService`]: actors hold [`crate::service::TrajectoryWriter`]s,
//! learners hold [`crate::service::SamplerHandle`]s, and the old
//! `actor_lead` / `update_interval` pacing is each table's rate limiter.

use crate::actor::{run_actor, Control};
use crate::agent::{Agent, AlgoKind, Exploration};
use crate::env::make_env;
use crate::learner::run_learner;
use crate::metrics::{CurvePoint, Metrics};
use crate::params::{AdamConfig, Checkpoint, ParameterServer, TargetSync};
use crate::replay::{
    GlobalLockReplay, NaiveScanReplay, PrioritizedConfig, PrioritizedReplay,
    PyBindBinaryReplay, ReplayBuffer, ShardedPrioritizedReplay, UniformReplay,
};
use crate::runtime::{Manifest, Runtime};
use crate::service::{
    ItemKind, RateLimitSpec, RateLimiter, ReplayService, ServiceState, Table, TableSpec,
    TableStatsSnapshot, STATE_FILE,
};
use anyhow::{anyhow, bail, Context, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Which replay-buffer implementation to train with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufferKind {
    /// The paper's K-ary sum tree + two locks + lazy writing.
    PalKary,
    /// Binary tree + one global lock (baseline framework).
    GlobalLock,
    /// Uniform ring buffer (no prioritization).
    Uniform,
    /// Fig-11 emulations.
    EmulatedPython,
    EmulatedBinding,
}

impl BufferKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "pal" | "kary" | "pal-kary" => BufferKind::PalKary,
            "global-lock" | "baseline" => BufferKind::GlobalLock,
            "uniform" => BufferKind::Uniform,
            "emulated-python" => BufferKind::EmulatedPython,
            "emulated-binding" => BufferKind::EmulatedBinding,
            other => bail!("unknown buffer kind `{other}`"),
        })
    }
}

/// Full configuration of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub algo: String,
    pub env: String,
    pub artifact_dir: std::path::PathBuf,
    pub actors: usize,
    pub learners: usize,
    pub total_env_steps: usize,
    pub warmup_steps: usize,
    /// Desired env-steps per learn-step (Alg 1 update_interval). Feeds
    /// the legacy rate-limiter mapping (σ = 1/update_interval).
    pub update_interval: f64,
    pub buffer: BufferKind,
    pub buffer_capacity: usize,
    /// Replay shards S (PalKary only): >1 splits each table into S
    /// independent sub-trees with actor-affinity insert routing,
    /// two-level sampling and per-shard batched priority updates.
    pub shards: usize,
    pub fanout: usize,
    pub alpha: f32,
    pub beta: f32,
    pub lr: f32,
    pub grad_clip: f32,
    /// Sub-gradients aggregated per optimizer step (paper: one per
    /// learner batch; >1 emulates synchronous parameter-server rounds).
    pub aggregation: usize,
    /// Max env steps collection may lead consumption×ratio (0 = actors
    /// free-run, the paper's fully-asynchronous mode). Feeds the legacy
    /// rate-limiter mapping (`max_diff = actor_lead · σ`).
    pub actor_lead: usize,
    /// N-step return length for the default table (1 = plain
    /// transitions).
    pub n_step: usize,
    /// Discount used for N-step reward folding.
    pub gamma_nstep: f32,
    /// Explicit table layout (`--tables`); empty = one table named
    /// `replay` whose item kind follows `n_step`.
    pub tables: Vec<TableSpec>,
    /// Rate-limiter selection for every table (`--rate-limit`).
    pub rate_limit: RateLimitSpec,
    /// Run-state directory (`--save-state`): weights + replay-service
    /// state are written here atomically at the end of the run and, if
    /// `checkpoint_every_secs > 0`, periodically during it.
    pub save_state: Option<std::path::PathBuf>,
    /// Resume directory (`--restore-state`): weights + replay state are
    /// loaded before any worker starts, so the run continues from the
    /// snapshot's buffers and limiter accounting.
    pub restore_state: Option<std::path::PathBuf>,
    /// Seconds between periodic run-state snapshots (0 = only at the
    /// end of the run). Requires `save_state`.
    pub checkpoint_every_secs: f64,
    pub target_sync: Option<TargetSync>,
    pub exploration: Exploration,
    pub seed: u64,
    /// Stop early once the recent mean return reaches this value.
    pub stop_at_reward: Option<f32>,
    /// Print a progress line every N seconds (0 = silent).
    pub log_every_secs: f64,
}

impl TrainConfig {
    pub fn new(algo: &str, env: &str) -> Self {
        Self {
            algo: algo.to_string(),
            env: env.to_string(),
            artifact_dir: "artifacts".into(),
            actors: 1,
            learners: 1,
            total_env_steps: 20_000,
            warmup_steps: 1_000,
            update_interval: 1.0,
            buffer: BufferKind::PalKary,
            buffer_capacity: 100_000,
            shards: 1,
            fanout: 64,
            alpha: 0.6,
            beta: 0.4,
            lr: 1e-3,
            grad_clip: 10.0,
            aggregation: 1,
            actor_lead: 512,
            n_step: 1,
            gamma_nstep: 0.99,
            tables: Vec::new(),
            rate_limit: RateLimitSpec::Legacy,
            save_state: None,
            restore_state: None,
            checkpoint_every_secs: 0.0,
            target_sync: None,
            exploration: Exploration::default(),
            seed: 0,
            stop_at_reward: None,
            log_every_secs: 0.0,
        }
    }

    pub fn artifact_id(&self) -> String {
        format!("{}_{}", self.algo, self.env)
    }

    /// The table layout this run trains with: explicit `--tables` spec,
    /// or one default table whose item kind follows `n_step`.
    pub fn table_specs(&self) -> Vec<TableSpec> {
        if !self.tables.is_empty() {
            return self.tables.clone();
        }
        let kind = if self.n_step > 1 {
            ItemKind::NStep { n: self.n_step, gamma: self.gamma_nstep }
        } else {
            ItemKind::OneStep
        };
        vec![TableSpec { name: "replay".to_string(), kind, capacity: None }]
    }
}

/// Outcome of a run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub env_steps: usize,
    pub learn_steps: usize,
    pub episodes: usize,
    pub elapsed_secs: f64,
    pub final_mean_return: f64,
    pub curve: Vec<CurvePoint>,
    pub env_steps_per_sec: f64,
    pub learn_steps_per_sec: f64,
    pub reached_target: bool,
    /// Final online/target weights and optimizer step count (for
    /// checkpointing and greedy evaluation).
    pub final_weights: Vec<f32>,
    pub final_target_weights: Vec<f32>,
    pub opt_steps: usize,
    /// Per-table service counters (inserts, granted batches, stalls).
    pub table_stats: Vec<(String, TableStatsSnapshot)>,
}

/// Build one replay buffer with an explicit capacity (tables may
/// override the run default).
fn make_buffer_with(
    cfg: &TrainConfig,
    capacity: usize,
    obs_dim: usize,
    act_dim: usize,
) -> Arc<dyn ReplayBuffer> {
    let prio_cfg = PrioritizedConfig {
        capacity,
        obs_dim,
        act_dim,
        fanout: cfg.fanout,
        alpha: cfg.alpha,
        beta: cfg.beta,
        lazy_writing: true,
        shards: cfg.shards.max(1),
    };
    match cfg.buffer {
        // S=1 keeps the single-tree fast path (no wrapper indirection).
        BufferKind::PalKary if prio_cfg.shards > 1 => {
            Arc::new(ShardedPrioritizedReplay::new(prio_cfg))
        }
        BufferKind::PalKary => Arc::new(PrioritizedReplay::new(prio_cfg)),
        BufferKind::GlobalLock => Arc::new(GlobalLockReplay::new(
            capacity,
            obs_dim,
            act_dim,
            cfg.alpha,
            cfg.beta,
        )),
        BufferKind::Uniform => Arc::new(UniformReplay::new(capacity, obs_dim, act_dim)),
        BufferKind::EmulatedPython => Arc::new(NaiveScanReplay::new(
            capacity,
            obs_dim,
            act_dim,
            cfg.alpha,
            cfg.beta,
        )),
        BufferKind::EmulatedBinding => Arc::new(PyBindBinaryReplay::new(
            capacity,
            obs_dim,
            act_dim,
            cfg.alpha,
            cfg.beta,
        )),
    }
}

/// Build the configured replay buffer with the run-default capacity.
pub fn make_buffer(cfg: &TrainConfig, obs_dim: usize, act_dim: usize) -> Arc<dyn ReplayBuffer> {
    make_buffer_with(cfg, cfg.buffer_capacity, obs_dim, act_dim)
}

/// Build the run's replay service: one table per spec, each wrapping a
/// buffer of the configured kind (sequence tables widen their dims by
/// the window length) and carrying the run's rate limiter.
pub fn build_service(cfg: &TrainConfig, obs_dim: usize, act_dim: usize) -> Result<ReplayService> {
    let specs = cfg.table_specs();
    // Learners sample the first table into base-dims batches, so it
    // cannot be a flattened-sequence table.
    if let ItemKind::Sequence { .. } = specs[0].kind {
        bail!(
            "first table `{}` is a sequence table; learners need a 1step or nstep table first",
            specs[0].name
        );
    }
    let mut tables = Vec::with_capacity(specs.len());
    for (i, spec) in specs.iter().enumerate() {
        let mult = spec.kind.dim_multiplier();
        let capacity = spec.capacity.unwrap_or(cfg.buffer_capacity);
        let buffer = make_buffer_with(cfg, capacity, obs_dim * mult, act_dim * mult);
        // Only the learner-sampled (first) table gets the ratio limiter:
        // the ratio couples inserts to THIS run's sampling, and writers
        // block while ANY table denies inserts — a ratio limiter on an
        // auxiliary table (whose sample counter never moves, nothing in
        // this process samples it) would throttle every actor forever.
        // Auxiliary tables free-run until per-table limiter specs land
        // (see ROADMAP).
        let limiter = if i == 0 {
            cfg.rate_limit
                .build(cfg.update_interval, cfg.warmup_steps, cfg.actor_lead)
        } else {
            RateLimiter::Unlimited { min_size_to_sample: cfg.warmup_steps }
        };
        tables.push(Table::new(spec.name.clone(), spec.kind, buffer, limiter));
    }
    ReplayService::new(tables)
}

/// File name of the weights checkpoint inside a run-state directory
/// (the replay state sits next to it as [`STATE_FILE`]).
pub const WEIGHTS_FILE: &str = "weights.bin";

/// Write one unified run-state snapshot into `dir`: the parameter
/// server's weights (`weights.bin`, `params::Checkpoint` format) and
/// the whole replay service (`replay_state.bin`,
/// `service::checkpoint::ServiceState` format). Both files are written
/// atomically (temp file + rename), so a crash mid-snapshot leaves the
/// previous complete snapshot in place.
pub fn save_run_state(
    dir: &std::path::Path,
    server: &ParameterServer,
    service: &ReplayService,
) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating run-state dir {}", dir.display()))?;
    Checkpoint::from_server(server).save(dir.join(WEIGHTS_FILE))?;
    ServiceState::capture(service)?.save(dir.join(STATE_FILE))?;
    Ok(())
}

/// Load a unified run-state snapshot from `dir` into a freshly built
/// parameter server + replay service. Everything is validated before
/// anything is mutated; on error both targets are untouched.
pub fn restore_run_state(
    dir: &std::path::Path,
    server: &ParameterServer,
    service: &ReplayService,
) -> Result<()> {
    let ck = Checkpoint::load(dir.join(WEIGHTS_FILE))?;
    let state = ServiceState::load(dir.join(STATE_FILE))?;
    // Validate the replay state against the service BEFORE touching the
    // parameter server, so a bad state file leaves no partial restore;
    // the apply step reuses the validated targets rather than
    // re-running the topology pass.
    let targets = state.validate_against(service)?;
    server.restore(&ck)?;
    state.apply_to(&targets)?;
    Ok(())
}

/// Run one full training session. Blocks until the env-step budget is
/// exhausted (or early-stop). Thread layout: `actors` actor threads +
/// `learners` learner threads + this monitor thread.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    let info = manifest.get(&cfg.artifact_id())?.clone();
    let kind = AlgoKind::parse(&info.algo)?;

    let init = info.load_initial_params()?;
    let sync = cfg.target_sync.unwrap_or_else(|| kind.default_target_sync());
    let server = Arc::new(ParameterServer::new(
        init,
        AdamConfig { lr: cfg.lr, grad_clip: cfg.grad_clip, ..Default::default() },
        sync,
        cfg.aggregation,
    ));
    let service = Arc::new(build_service(cfg, info.obs_dim, info.flat_act_dim)?);
    if cfg.checkpoint_every_secs > 0.0 && cfg.save_state.is_none() {
        bail!("--checkpoint-every requires --save-state DIR");
    }
    if cfg.save_state.is_some() {
        // Fail fast on a buffer kind that cannot snapshot (the emulated
        // plugin buffers): the capture of the still-empty service is
        // cheap, and erroring here beats training for hours and losing
        // the run at the final save.
        ServiceState::capture(&service).context(
            "--save-state: this run's buffer kind does not support checkpointing",
        )?;
    }
    if let Some(dir) = &cfg.restore_state {
        restore_run_state(dir, &server, &service)
            .with_context(|| format!("restoring run state from {}", dir.display()))?;
        eprintln!(
            "[pal] resumed from {}: {} replay items, {} optimizer steps",
            dir.display(),
            service.total_len(),
            server.opt_steps(),
        );
    }
    let metrics = Arc::new(Metrics::new());
    let ctl = Arc::new(Control::new(cfg.total_env_steps));

    let mut root_rng = crate::util::rng::Rng::new(cfg.seed);
    let worker_seeds: Vec<u64> = (0..cfg.actors + cfg.learners)
        .map(|_| root_rng.next_u64())
        .collect();

    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for a in 0..cfg.actors {
            let info = info.clone();
            let service = Arc::clone(&service);
            let server = Arc::clone(&server);
            let metrics = Arc::clone(&metrics);
            let ctl = Arc::clone(&ctl);
            let env_name = cfg.env.clone();
            let explore = cfg.exploration;
            let seed = worker_seeds[a];
            handles.push(s.spawn(move || -> Result<()> {
                let rt = Runtime::cpu()?;
                let model = rt.load_model(&info)?;
                let mut agent = Agent::new(model, explore)?;
                let mut env = make_env(&env_name)
                    .ok_or_else(|| anyhow!("unknown env {env_name}"))?;
                let mut rng = crate::util::rng::Rng::new(seed);
                let mut writer = service.writer(a);
                let r = run_actor(
                    &mut agent, env.as_mut(), &mut writer, &server, &metrics, &ctl,
                    &mut rng,
                );
                // An actor finishing its budget is normal; an actor
                // erroring must stop the whole run.
                if r.is_err() {
                    ctl.request_stop();
                }
                r.with_context(|| format!("actor {a}"))
            }));
        }
        for l in 0..cfg.learners {
            let info = info.clone();
            let service = Arc::clone(&service);
            let server = Arc::clone(&server);
            let metrics = Arc::clone(&metrics);
            let ctl = Arc::clone(&ctl);
            let explore = cfg.exploration;
            let seed = worker_seeds[cfg.actors + l];
            handles.push(s.spawn(move || -> Result<()> {
                let rt = Runtime::cpu()?;
                let model = rt.load_model(&info)?;
                let mut agent = Agent::new(model, explore)?;
                let mut rng = crate::util::rng::Rng::new(seed);
                let sampler = service.default_sampler();
                let r = run_learner(
                    l, &mut agent, &sampler, &server, &metrics, &ctl, &mut rng,
                );
                if r.is_err() {
                    ctl.request_stop();
                }
                r.with_context(|| format!("learner {l}"))
            }));
        }

        // Monitor loop: progress logging (worker metrics + service
        // limiter/stall stats), periodic run-state snapshots, early
        // stop, shutdown.
        let mut last_log = std::time::Instant::now();
        let mut last_ckpt = std::time::Instant::now();
        loop {
            std::thread::sleep(Duration::from_millis(20));
            let env_steps = ctl.env_steps.load(Ordering::Relaxed);
            if cfg.log_every_secs > 0.0
                && last_log.elapsed().as_secs_f64() >= cfg.log_every_secs
            {
                eprintln!("[pal] {} | {}", metrics.summary(), service.stats_line());
                last_log = std::time::Instant::now();
            }
            if cfg.checkpoint_every_secs > 0.0
                && last_ckpt.elapsed().as_secs_f64() >= cfg.checkpoint_every_secs
            {
                // Snapshot while workers run: each shard is captured
                // under its lock pair, the atomic write keeps the
                // previous snapshot intact until the new one is
                // complete. A failed write warns but never kills the
                // run it exists to protect.
                let dir = cfg.save_state.as_ref().expect("checked above");
                if let Err(e) = save_run_state(dir, &server, &service) {
                    eprintln!("[pal] WARNING: periodic checkpoint failed: {e:#}");
                }
                last_ckpt = std::time::Instant::now();
            }
            if let Some(target) = cfg.stop_at_reward {
                if metrics.mean_return().map_or(false, |r| r >= target as f64)
                    && metrics.episodes.load(Ordering::Relaxed) >= 10
                {
                    ctl.request_stop();
                }
            }
            if env_steps >= cfg.total_env_steps || ctl.should_stop() {
                // Give learners a moment to drain the remaining ratio
                // budget, then stop everyone.
                std::thread::sleep(Duration::from_millis(50));
                ctl.request_stop();
                break;
            }
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(())
    })?;

    // Final (quiescent) run-state snapshot: all workers have joined, so
    // this one is exact — the file a later `--restore-state` resumes.
    if let Some(dir) = &cfg.save_state {
        save_run_state(dir, &server, &service)
            .with_context(|| format!("saving run state to {}", dir.display()))?;
        eprintln!(
            "[pal] run state saved to {} ({} replay items)",
            dir.display(),
            service.total_len(),
        );
    }

    let reached = cfg
        .stop_at_reward
        .map(|t| metrics.mean_return().map_or(false, |r| r >= t as f64))
        .unwrap_or(false);
    Ok(TrainReport {
        final_weights: server.online_copy(),
        final_target_weights: server.target_copy(),
        opt_steps: server.opt_steps(),
        env_steps: ctl.env_steps.load(Ordering::Relaxed),
        learn_steps: ctl.learn_steps.load(Ordering::Relaxed),
        episodes: metrics.episodes.load(Ordering::Relaxed),
        elapsed_secs: metrics.elapsed_secs(),
        final_mean_return: metrics.mean_return().unwrap_or(f64::NAN),
        curve: metrics.curve(),
        env_steps_per_sec: metrics.env_throughput(),
        learn_steps_per_sec: metrics.learn_throughput(),
        reached_target: reached,
        table_stats: service.stats_snapshots(),
    })
}

/// Greedy evaluation: run `episodes` episodes with exploration off using
/// the given weights; returns mean episode return.
pub fn evaluate(cfg: &TrainConfig, weights: &[f32], episodes: usize) -> Result<f64> {
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    let info = manifest.get(&cfg.artifact_id())?.clone();
    let rt = Runtime::cpu()?;
    let model = rt.load_model(&info)?;
    let mut agent = Agent::new(model, cfg.exploration)?;
    let mut env =
        make_env(&cfg.env).ok_or_else(|| anyhow!("unknown env {}", cfg.env))?;
    let mut rng = crate::util::rng::Rng::new(cfg.seed ^ 0xE7A1_5EED);
    let mut total = 0.0f64;
    for _ in 0..episodes {
        let mut obs = env.reset(&mut rng);
        let mut ep = 0.0f32;
        loop {
            let action = agent.act(weights, &obs, usize::MAX, false, &mut rng)?;
            let step = env.step(&action, &mut rng);
            ep += step.reward;
            if step.done || step.truncated {
                break;
            }
            obs = step.obs;
        }
        total += ep as f64;
    }
    Ok(total / episodes as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_table_specs_follow_n_step() {
        let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
        let specs = cfg.table_specs();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].name, "replay");
        assert_eq!(specs[0].kind, ItemKind::OneStep);
        cfg.n_step = 3;
        assert_eq!(
            cfg.table_specs()[0].kind,
            ItemKind::NStep { n: 3, gamma: cfg.gamma_nstep }
        );
    }

    #[test]
    fn build_service_honors_specs_and_rejects_seq_first() {
        let mut cfg = TrainConfig::new("dqn", "CartPole-v1");
        cfg.buffer_capacity = 1_024;
        cfg.tables = vec![
            TableSpec { name: "replay".into(), kind: ItemKind::OneStep, capacity: None },
            TableSpec {
                name: "traj".into(),
                kind: ItemKind::Sequence { len: 4 },
                capacity: Some(512),
            },
        ];
        let svc = build_service(&cfg, 4, 2).unwrap();
        assert_eq!(svc.tables().len(), 2);
        assert_eq!(svc.default_table().name(), "replay");
        assert_eq!(svc.table("traj").unwrap().capacity(), 512);
        // Auxiliary tables must free-run: nothing in this process
        // samples them, so a ratio limiter there would throttle every
        // writer forever (deadlock).
        assert_eq!(
            *svc.table("traj").unwrap().limiter(),
            RateLimiter::Unlimited { min_size_to_sample: cfg.warmup_steps }
        );
        cfg.tables.rotate_right(1); // sequence table first → error
        assert!(build_service(&cfg, 4, 2).is_err());
    }

    #[test]
    fn legacy_limiter_built_by_default() {
        let cfg = TrainConfig::new("dqn", "CartPole-v1");
        let svc = build_service(&cfg, 4, 2).unwrap();
        match svc.default_table().limiter() {
            crate::service::RateLimiter::SampleToInsertRatio(r) => {
                assert!((r.samples_per_insert - 1.0).abs() < 1e-12);
                assert_eq!(r.min_size_to_sample, cfg.warmup_steps);
            }
            other => panic!("expected legacy ratio limiter, got {other:?}"),
        }
    }
}
