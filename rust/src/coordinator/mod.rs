//! Coordinator: wires buffer + parameter server + parallel actors +
//! parallel learners into one training run (paper §V, Fig 7).
//!
//! Every worker thread owns its own PJRT runtime (compiled from the same
//! AOT artifacts); weights move between threads only as flat f32 vectors
//! through the parameter server.

use crate::actor::{run_actor, Control};
use crate::agent::{Agent, AlgoKind, Exploration};
use crate::env::make_env;
use crate::learner::run_learner;
use crate::metrics::{CurvePoint, Metrics};
use crate::params::{AdamConfig, ParameterServer, TargetSync};
use crate::replay::{
    GlobalLockReplay, NaiveScanReplay, PrioritizedConfig, PrioritizedReplay,
    PyBindBinaryReplay, ReplayBuffer, ShardedPrioritizedReplay, UniformReplay,
};
use crate::runtime::{Manifest, Runtime};
use anyhow::{anyhow, bail, Context, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Which replay-buffer implementation to train with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BufferKind {
    /// The paper's K-ary sum tree + two locks + lazy writing.
    PalKary,
    /// Binary tree + one global lock (baseline framework).
    GlobalLock,
    /// Uniform ring buffer (no prioritization).
    Uniform,
    /// Fig-11 emulations.
    EmulatedPython,
    EmulatedBinding,
}

impl BufferKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "pal" | "kary" | "pal-kary" => BufferKind::PalKary,
            "global-lock" | "baseline" => BufferKind::GlobalLock,
            "uniform" => BufferKind::Uniform,
            "emulated-python" => BufferKind::EmulatedPython,
            "emulated-binding" => BufferKind::EmulatedBinding,
            other => bail!("unknown buffer kind `{other}`"),
        })
    }
}

/// Full configuration of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub algo: String,
    pub env: String,
    pub artifact_dir: std::path::PathBuf,
    pub actors: usize,
    pub learners: usize,
    pub total_env_steps: usize,
    pub warmup_steps: usize,
    /// Desired env-steps per learn-step (Alg 1 update_interval).
    pub update_interval: f64,
    pub buffer: BufferKind,
    pub buffer_capacity: usize,
    /// Replay shards S (PalKary only): >1 splits the buffer into S
    /// independent sub-trees with actor-affinity insert routing,
    /// two-level sampling and per-shard batched priority updates.
    pub shards: usize,
    pub fanout: usize,
    pub alpha: f32,
    pub beta: f32,
    pub lr: f32,
    pub grad_clip: f32,
    /// Sub-gradients aggregated per optimizer step (paper: one per
    /// learner batch; >1 emulates synchronous parameter-server rounds).
    pub aggregation: usize,
    /// Max env steps collection may lead consumption×ratio (0 = actors
    /// free-run, the paper's fully-asynchronous mode).
    pub actor_lead: usize,
    pub target_sync: Option<TargetSync>,
    pub exploration: Exploration,
    pub seed: u64,
    /// Stop early once the recent mean return reaches this value.
    pub stop_at_reward: Option<f32>,
    /// Print a progress line every N seconds (0 = silent).
    pub log_every_secs: f64,
}

impl TrainConfig {
    pub fn new(algo: &str, env: &str) -> Self {
        Self {
            algo: algo.to_string(),
            env: env.to_string(),
            artifact_dir: "artifacts".into(),
            actors: 1,
            learners: 1,
            total_env_steps: 20_000,
            warmup_steps: 1_000,
            update_interval: 1.0,
            buffer: BufferKind::PalKary,
            buffer_capacity: 100_000,
            shards: 1,
            fanout: 64,
            alpha: 0.6,
            beta: 0.4,
            lr: 1e-3,
            grad_clip: 10.0,
            aggregation: 1,
            actor_lead: 512,
            target_sync: None,
            exploration: Exploration::default(),
            seed: 0,
            stop_at_reward: None,
            log_every_secs: 0.0,
        }
    }

    pub fn artifact_id(&self) -> String {
        format!("{}_{}", self.algo, self.env)
    }
}

/// Outcome of a run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub env_steps: usize,
    pub learn_steps: usize,
    pub episodes: usize,
    pub elapsed_secs: f64,
    pub final_mean_return: f64,
    pub curve: Vec<CurvePoint>,
    pub env_steps_per_sec: f64,
    pub learn_steps_per_sec: f64,
    pub reached_target: bool,
    /// Final online/target weights and optimizer step count (for
    /// checkpointing and greedy evaluation).
    pub final_weights: Vec<f32>,
    pub final_target_weights: Vec<f32>,
    pub opt_steps: usize,
}

/// Build the configured replay buffer.
pub fn make_buffer(cfg: &TrainConfig, obs_dim: usize, act_dim: usize) -> Arc<dyn ReplayBuffer> {
    let prio_cfg = PrioritizedConfig {
        capacity: cfg.buffer_capacity,
        obs_dim,
        act_dim,
        fanout: cfg.fanout,
        alpha: cfg.alpha,
        beta: cfg.beta,
        lazy_writing: true,
        shards: cfg.shards.max(1),
    };
    match cfg.buffer {
        // S=1 keeps the single-tree fast path (no wrapper indirection).
        BufferKind::PalKary if prio_cfg.shards > 1 => {
            Arc::new(ShardedPrioritizedReplay::new(prio_cfg))
        }
        BufferKind::PalKary => Arc::new(PrioritizedReplay::new(prio_cfg)),
        BufferKind::GlobalLock => Arc::new(GlobalLockReplay::new(
            cfg.buffer_capacity,
            obs_dim,
            act_dim,
            cfg.alpha,
            cfg.beta,
        )),
        BufferKind::Uniform => {
            Arc::new(UniformReplay::new(cfg.buffer_capacity, obs_dim, act_dim))
        }
        BufferKind::EmulatedPython => Arc::new(NaiveScanReplay::new(
            cfg.buffer_capacity,
            obs_dim,
            act_dim,
            cfg.alpha,
            cfg.beta,
        )),
        BufferKind::EmulatedBinding => Arc::new(PyBindBinaryReplay::new(
            cfg.buffer_capacity,
            obs_dim,
            act_dim,
            cfg.alpha,
            cfg.beta,
        )),
    }
}

/// Run one full training session. Blocks until the env-step budget is
/// exhausted (or early-stop). Thread layout: `actors` actor threads +
/// `learners` learner threads + this monitor thread.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    let info = manifest.get(&cfg.artifact_id())?.clone();
    let kind = AlgoKind::parse(&info.algo)?;

    let init = info.load_initial_params()?;
    let sync = cfg.target_sync.unwrap_or_else(|| kind.default_target_sync());
    let server = Arc::new(ParameterServer::new(
        init,
        AdamConfig { lr: cfg.lr, grad_clip: cfg.grad_clip, ..Default::default() },
        sync,
        cfg.aggregation,
    ));
    let buffer = make_buffer(cfg, info.obs_dim, info.flat_act_dim);
    let metrics = Arc::new(Metrics::new());
    let mut control = Control::new(
        cfg.total_env_steps,
        cfg.update_interval,
        cfg.warmup_steps,
    );
    control.actor_lead = cfg.actor_lead;
    let ctl = Arc::new(control);

    let mut root_rng = crate::util::rng::Rng::new(cfg.seed);
    let worker_seeds: Vec<u64> = (0..cfg.actors + cfg.learners)
        .map(|_| root_rng.next_u64())
        .collect();

    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::new();
        for a in 0..cfg.actors {
            let info = info.clone();
            let buffer = Arc::clone(&buffer);
            let server = Arc::clone(&server);
            let metrics = Arc::clone(&metrics);
            let ctl = Arc::clone(&ctl);
            let env_name = cfg.env.clone();
            let explore = cfg.exploration;
            let seed = worker_seeds[a];
            handles.push(s.spawn(move || -> Result<()> {
                let rt = Runtime::cpu()?;
                let model = rt.load_model(&info)?;
                let mut agent = Agent::new(model, explore)?;
                let mut env = make_env(&env_name)
                    .ok_or_else(|| anyhow!("unknown env {env_name}"))?;
                let mut rng = crate::util::rng::Rng::new(seed);
                let r = run_actor(
                    a, &mut agent, env.as_mut(), buffer.as_ref(), &server, &metrics,
                    &ctl, &mut rng,
                );
                // An actor finishing its budget is normal; an actor
                // erroring must stop the whole run.
                if r.is_err() {
                    ctl.request_stop();
                }
                r.with_context(|| format!("actor {a}"))
            }));
        }
        for l in 0..cfg.learners {
            let info = info.clone();
            let buffer = Arc::clone(&buffer);
            let server = Arc::clone(&server);
            let metrics = Arc::clone(&metrics);
            let ctl = Arc::clone(&ctl);
            let explore = cfg.exploration;
            let seed = worker_seeds[cfg.actors + l];
            handles.push(s.spawn(move || -> Result<()> {
                let rt = Runtime::cpu()?;
                let model = rt.load_model(&info)?;
                let mut agent = Agent::new(model, explore)?;
                let mut rng = crate::util::rng::Rng::new(seed);
                let r = run_learner(
                    l, &mut agent, buffer.as_ref(), &server, &metrics, &ctl, &mut rng,
                );
                if r.is_err() {
                    ctl.request_stop();
                }
                r.with_context(|| format!("learner {l}"))
            }));
        }

        // Monitor loop: progress logging, early stop, learner shutdown.
        let mut last_log = std::time::Instant::now();
        let mut reached = false;
        loop {
            std::thread::sleep(Duration::from_millis(20));
            let env_steps = ctl.env_steps.load(Ordering::Relaxed);
            if cfg.log_every_secs > 0.0
                && last_log.elapsed().as_secs_f64() >= cfg.log_every_secs
            {
                eprintln!("[pal] {}", metrics.summary());
                last_log = std::time::Instant::now();
            }
            if let Some(target) = cfg.stop_at_reward {
                if metrics.mean_return().map_or(false, |r| r >= target as f64)
                    && metrics.episodes.load(Ordering::Relaxed) >= 10
                {
                    reached = true;
                    ctl.request_stop();
                }
            }
            if env_steps >= cfg.total_env_steps || ctl.should_stop() {
                // Give learners a moment to drain the remaining ratio
                // budget, then stop everyone.
                std::thread::sleep(Duration::from_millis(50));
                ctl.request_stop();
                break;
            }
        }
        let _ = reached;
        if reached {
            // Stash in metrics via curve? Report computed below reads ctl.
        }
        for h in handles {
            h.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(())
    })?;

    let reached = cfg
        .stop_at_reward
        .map(|t| metrics.mean_return().map_or(false, |r| r >= t as f64))
        .unwrap_or(false);
    Ok(TrainReport {
        final_weights: server.online_copy(),
        final_target_weights: server.target_copy(),
        opt_steps: server.opt_steps(),
        env_steps: ctl.env_steps.load(Ordering::Relaxed),
        learn_steps: ctl.learn_steps.load(Ordering::Relaxed),
        episodes: metrics.episodes.load(Ordering::Relaxed),
        elapsed_secs: metrics.elapsed_secs(),
        final_mean_return: metrics.mean_return().unwrap_or(f64::NAN),
        curve: metrics.curve(),
        env_steps_per_sec: metrics.env_throughput(),
        learn_steps_per_sec: metrics.learn_throughput(),
        reached_target: reached,
    })
}

/// Greedy evaluation: run `episodes` episodes with exploration off using
/// the given weights; returns mean episode return.
pub fn evaluate(cfg: &TrainConfig, weights: &[f32], episodes: usize) -> Result<f64> {
    let manifest = Manifest::load(&cfg.artifact_dir)?;
    let info = manifest.get(&cfg.artifact_id())?.clone();
    let rt = Runtime::cpu()?;
    let model = rt.load_model(&info)?;
    let mut agent = Agent::new(model, cfg.exploration)?;
    let mut env =
        make_env(&cfg.env).ok_or_else(|| anyhow!("unknown env {}", cfg.env))?;
    let mut rng = crate::util::rng::Rng::new(cfg.seed ^ 0xE7A1_5EED);
    let mut total = 0.0f64;
    for _ in 0..episodes {
        let mut obs = env.reset(&mut rng);
        let mut ep = 0.0f32;
        loop {
            let action = agent.act(weights, &obs, usize::MAX, false, &mut rng)?;
            let step = env.step(&action, &mut rng);
            ep += step.reward;
            if step.done || step.truncated {
                break;
            }
            obs = step.obs;
        }
        total += ep as f64;
    }
    Ok(total / episodes as f64)
}
