//! Length-prefixed wire frames for the remote replay protocol.
//!
//! One frame = `magic "PALRPC02" (8 bytes) + u32 payload length +
//! payload + crc32(payload)` — the same magic/CRC discipline as the
//! on-disk [`crate::util::blob`] format, adapted to a stream: the
//! length prefix delimits frames, the trailing CRC catches corruption
//! in flight, and the magic doubles as the protocol version (a client
//! speaking a different version, like the pre-session `PALRPC01`, is
//! rejected as a bad magic, not misparsed).
//!
//! Every failure mode of [`read_frame`] — truncated stream, wrong
//! magic, oversized length, checksum mismatch — is a descriptive
//! `Err`, never a panic, and the decoder allocates nothing before the
//! length field has been bounds-checked. A clean EOF before the first
//! byte of a frame is `Ok(None)` (the peer hung up between frames),
//! distinct from EOF mid-frame (an error: the frame was truncated).

use crate::util::blob::crc32;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Frame magic; the trailing `02` is the protocol version (bumped from
/// `01` when sessions and request sequence numbers joined the payload
/// layouts).
pub const FRAME_MAGIC: &[u8; 8] = b"PALRPC02";

/// Upper bound on one frame's payload. Large enough for any single
/// RPC, small enough that a corrupted or hostile length field cannot
/// drive an absurd allocation. Whole-state transfers are NOT bounded by
/// this: `CheckpointChunked` and the `ChunkBegin`/`Chunk`/`ChunkEnd`
/// restore stream (see [`super::proto`]) move a table state of up to
/// `MAX_CHUNKED_STATE` bytes as a sequence of frames each no larger
/// than `MAX_CHUNK_LEN` — far under this cap.
pub const MAX_FRAME_LEN: usize = 1 << 28; // 256 MiB

/// Write one frame. The payload is the caller's encoded request or
/// response; framing (magic, length, checksum) is added here.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME_LEN {
        bail!(
            "refusing to send a {}-byte frame (the protocol caps frames at {} bytes)",
            payload.len(),
            MAX_FRAME_LEN
        );
    }
    w.write_all(FRAME_MAGIC).context("writing frame magic")?;
    w.write_all(&(payload.len() as u32).to_le_bytes())
        .context("writing frame length")?;
    w.write_all(payload).context("writing frame payload")?;
    w.write_all(&crc32(payload).to_le_bytes())
        .context("writing frame checksum")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read until `buf` is full, treating EOF as an error naming `what`.
fn read_exact_or(r: &mut impl Read, buf: &mut [u8], what: &str) -> Result<()> {
    r.read_exact(buf)
        .with_context(|| format!("reading {what} (truncated frame)"))
}

/// Read one frame's payload. `Ok(None)` on clean EOF before any frame
/// byte; every malformed input is a descriptive error.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut payload = Vec::new();
    Ok(read_frame_into(r, &mut payload)?.then_some(payload))
}

/// As [`read_frame`], but into a caller-owned buffer (cleared first) so
/// a connection loop reads every frame into one reused allocation.
/// Returns `false` on clean EOF before any frame byte.
pub fn read_frame_into(r: &mut impl Read, payload: &mut Vec<u8>) -> Result<bool> {
    // Read the first byte by hand so "peer closed between frames" is
    // distinguishable from "frame cut off mid-flight".
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(false),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("reading frame magic"),
        }
    }
    let mut magic = [0u8; 8];
    magic[0] = first[0];
    read_exact_or(r, &mut magic[1..], "frame magic")?;
    if &magic != FRAME_MAGIC {
        bail!(
            "bad frame magic {:02x?} (want `{}` — not a PAL replay protocol stream, \
             or a protocol version mismatch)",
            magic,
            String::from_utf8_lossy(FRAME_MAGIC)
        );
    }
    let mut len4 = [0u8; 4];
    read_exact_or(r, &mut len4, "frame length")?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > MAX_FRAME_LEN {
        bail!(
            "frame length {len} exceeds the {MAX_FRAME_LEN}-byte protocol bound \
             (corrupted or hostile frame)"
        );
    }
    payload.clear();
    payload.resize(len, 0);
    read_exact_or(r, payload, "frame payload")?;
    let mut crc4 = [0u8; 4];
    read_exact_or(r, &mut crc4, "frame checksum")?;
    let stored = u32::from_le_bytes(crc4);
    let computed = crc32(payload);
    if computed != stored {
        bail!(
            "frame checksum mismatch: payload crc {computed:#010x}, frame says \
             {stored:#010x} (corrupted frame)"
        );
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn frame_bytes(payload: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, payload).unwrap();
        buf
    }

    #[test]
    fn roundtrip_and_clean_eof() {
        let mut buf = frame_bytes(b"hello");
        buf.extend_from_slice(&frame_bytes(b""));
        let mut cur = Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"");
        assert!(read_frame(&mut cur).unwrap().is_none(), "clean EOF is Ok(None)");
    }

    #[test]
    fn truncation_anywhere_is_an_error_not_a_panic() {
        let full = frame_bytes(b"payload bytes");
        for cut in 1..full.len() {
            let mut cur = Cursor::new(full[..cut].to_vec());
            assert!(read_frame(&mut cur).is_err(), "cut at {cut} must error");
        }
    }

    #[test]
    fn wrong_magic_rejected_with_message() {
        let mut buf = frame_bytes(b"x");
        buf[0] ^= 0xFF;
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(FRAME_MAGIC);
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn flipped_payload_bit_fails_checksum() {
        let mut buf = frame_bytes(b"payload bytes");
        buf[10] ^= 0x01;
        let err = read_frame(&mut Cursor::new(buf)).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn writer_refuses_oversized_payload() {
        // The zeroed vec is virtual-only: write_frame checks the length
        // and bails before a single payload byte is read, so the
        // MAX_FRAME_LEN + 1 pages are never touched.
        let payload = vec![0u8; MAX_FRAME_LEN + 1];
        let mut sink = Vec::new();
        assert!(write_frame(&mut sink, &payload).is_err());
    }
}
