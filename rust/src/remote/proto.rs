//! Request/response payloads of the remote replay protocol.
//!
//! Every payload rides inside one [`super::frame`] frame; the first
//! byte is the opcode, the rest is little-endian fields through the
//! shared [`crate::util::blob`] cursors, so every decode failure is a
//! bounds-checked, field-named error — a malformed request can never
//! panic the server or half-apply (the whole payload is decoded before
//! any table is touched).
//!
//! The RPC surface mirrors the in-process [`crate::service`] API:
//!
//! | RPC | in-process equivalent |
//! |-----|----------------------|
//! | `Append` | [`TrajectoryWriter::append`](crate::service::TrajectoryWriter::append) (server-side writer, one per `(connection, actor)`) |
//! | `Sample` | [`SamplerHandle::try_sample`](crate::service::SamplerHandle::try_sample) |
//! | `UpdatePriorities` | [`SamplerHandle::update_priorities`](crate::service::SamplerHandle::update_priorities) |
//! | `Stats` | [`ReplayService::stats_snapshots`](crate::service::ReplayService::stats_snapshots) |
//! | `Checkpoint` / `Restore` | [`ReplayService::checkpoint`](crate::service::ReplayService::checkpoint) / `restore` |
//!
//! Rate-limiter semantics cross the wire as *retriable* outcomes: a
//! stalled sample (or an insert batch the limiter only partially
//! admits) is a [`Response::WouldStall`] / short [`Response::Appended`]
//! frame the client polls on, never a blocked connection.
//!
//! ## Sessions and exactly-once requests
//!
//! `Hello` carries a session id (0 = "start fresh"); the server answers
//! with the session it bound — `resumed` says whether server-side state
//! (the per-actor [`crate::service::TrajectoryWriter`] assembly windows,
//! the sampling RNG, the reply cache) survived from a previous
//! connection. The mutating RPCs (`Append`, `Sample`,
//! `UpdatePriorities`) carry a session-scoped sequence number (`seq`,
//! starting at 1; `seq == 0` opts out of sequencing): the server
//! executes each sequence number at most once and caches the encoded
//! reply, so a client that re-sends an unacked request after a
//! reconnect either gets the cached reply verbatim (the request DID
//! execute before the link died) or a fresh execution — never a
//! double-apply. This is what makes reconnecting writers exactly-once:
//! replayed appends dedupe instead of double-inserting.

use crate::replay::SampleBatch;
use crate::service::{TableStatsSnapshot, WriterStep};
use crate::util::blob::{ByteReader, ByteWriter};
use anyhow::{bail, Result};

/// Most steps one `Append` may carry (bounds a corrupted count field).
pub const MAX_APPEND_STEPS: usize = 65_536;
/// Largest sample batch a client may request.
pub const MAX_SAMPLE_BATCH: usize = 1 << 20;
/// Most indices one `UpdatePriorities` may carry.
pub const MAX_UPDATE_INDICES: usize = 1 << 20;
/// Most tables a `Stats` response may list (matches the checkpoint
/// decoder's bound).
pub const MAX_TABLES: usize = 4_096;

/// Largest single chunk either side may put in a `Chunk` frame. Well
/// under [`super::frame::MAX_FRAME_LEN`] so a chunk frame plus its
/// header always fits, and small enough that a hostile `chunk_len`
/// cannot force a huge single allocation.
pub const MAX_CHUNK_LEN: usize = 1 << 26; // 64 MiB
/// Default chunk size for chunked state transfers: big enough to
/// amortize per-frame overhead, small enough that progress is steady
/// and per-chunk buffers stay cheap.
pub const DEFAULT_CHUNK_LEN: usize = 1 << 22; // 4 MiB
/// Largest total state a chunked transfer may declare (bounds the
/// server-side staging buffer a hostile `ChunkBegin` could demand).
pub const MAX_CHUNKED_STATE: u64 = 1 << 34; // 16 GiB

const OP_HELLO: u8 = 1;
const OP_APPEND: u8 = 2;
const OP_SAMPLE: u8 = 3;
const OP_UPDATE_PRIORITIES: u8 = 4;
const OP_STATS: u8 = 5;
const OP_CHECKPOINT: u8 = 6;
const OP_RESTORE: u8 = 7;
const OP_SHUTDOWN: u8 = 8;
const OP_MASS: u8 = 9;
const OP_CHECKPOINT_CHUNKED: u8 = 10;
const OP_CHUNK_BEGIN: u8 = 11;
const OP_CHUNK: u8 = 12;
const OP_CHUNK_END: u8 = 13;
const OP_PING: u8 = 14;
const OP_DRAIN: u8 = 15;
const OP_HANDOFF_END: u8 = 16;

const RESP_OK: u8 = 1;
const RESP_APPENDED: u8 = 2;
const RESP_SAMPLED: u8 = 3;
const RESP_WOULD_STALL: u8 = 4;
const RESP_STATS: u8 = 5;
const RESP_STATE: u8 = 6;
const RESP_ERROR: u8 = 7;
const RESP_HELLO: u8 = 8;
const RESP_MASS: u8 = 9;
const RESP_CHUNK_BEGIN: u8 = 10;
const RESP_CHUNK: u8 = 11;
const RESP_CHUNK_END: u8 = 12;
const RESP_PONG: u8 = 13;

/// Why a `Sample` (or a whole `Append` batch) was denied; the client
/// maps these straight onto [`crate::service::SampleOutcome`] and
/// sleep-polls, exactly like an in-process learner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StallReason {
    /// The table's rate limiter denied the batch.
    Throttled,
    /// The table is below `min_size_to_sample`.
    NotEnoughData,
    /// A tenant quota denied the request: the connection's insert
    /// budget is spent, or the table's writer cap is full. Retriable
    /// by design (another tenant releasing capacity unblocks it) —
    /// quota rejections are never connection errors.
    QuotaExhausted,
}

/// One request frame, client → server.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Bind (or resume) a server-side session and seed its sampling
    /// RNG. `session == 0` asks for a fresh session; a non-zero id from
    /// a previous [`Response::Hello`] asks to resume that session's
    /// state (writer assembly windows, RNG stream, reply cache). An
    /// unknown or evicted id is not an error — the server hands back a
    /// fresh session with `resumed == false` (this is exactly the
    /// server-restart path). A connection that never says hello gets a
    /// non-resumable session seeded from its connection id. With a
    /// fixed seed, a remote `Sample`/`UpdatePriorities` loop is
    /// bit-reproducible against an in-process
    /// [`crate::service::SamplerHandle`] loop using `Rng::new(seed)` on
    /// the same table contents.
    ///
    /// `tables` is the connection's table ACL: the set of table names
    /// this client wants to touch (empty = all tables). The server
    /// binds it at `Hello` time — a later `Append`/`Sample` against a
    /// table outside the list is a hard [`Response::Error`], and a
    /// re-sent `Hello` (redial, resume) rebinds the list.
    Hello { rng_seed: u64, session: u64, tables: Vec<String> },
    /// Append raw env steps for one actor; the server-side
    /// [`crate::service::TrajectoryWriter`] owns item assembly (N-step
    /// folding, sequence windows, boundary rules) so remote actors get
    /// byte-identical items to local ones. `seq` is the session request
    /// sequence (0 = unsequenced); `dropped` reports how many steps the
    /// client spilled and dropped client-side since its last acked
    /// append (a delta, folded into the `steps_dropped` stat
    /// exactly-once by the reply cache).
    Append { actor_id: u64, seq: u64, dropped: u64, steps: Vec<WriterStep> },
    /// Draw one batch from a named table (`seq` as in `Append`).
    Sample { table: String, batch: u32, seq: u64 },
    /// Feed |TD| errors back for previously sampled indices (`seq` as
    /// in `Append`).
    UpdatePriorities { table: String, indices: Vec<u64>, td_abs: Vec<f32>, seq: u64 },
    /// Per-table sizes and counters.
    Stats,
    /// Serialize the whole service (a `ServiceState` payload).
    Checkpoint,
    /// Restore a `ServiceState` payload into the served tables
    /// (validated server-side before anything is mutated).
    Restore { state: Vec<u8> },
    /// Stop the server's accept loop (the serving process then runs its
    /// `--save-state` hook, if any, and exits).
    Shutdown,
    /// One table's sampleable mass: its length and total priority (for
    /// prioritized tables, the sum-tree root; uniform tables report
    /// their length). The mesh sampler's server-selection input — one
    /// tiny frame, cheap enough to refresh every sampling round.
    Mass { table: String },
    /// Ask for the service checkpoint as a chunked stream: the server
    /// answers with `ChunkBegin`, `chunk_count` × `Chunk`, `ChunkEnd`
    /// back-to-back (the one RPC that returns more than one frame), so
    /// arbitrarily large states cross the wire in bounded frames
    /// instead of hitting the frame cap. `max_chunk` bounds the data
    /// bytes per chunk.
    CheckpointChunked { max_chunk: u32 },
    /// Open a chunked `Restore` upload: declares the exact total size
    /// and chunking so the server can validate every following frame
    /// against it. Nothing is applied until `ChunkEnd` verifies and the
    /// assembled state passes the same validation as `Restore`.
    ChunkBegin { total_len: u64, chunk_len: u32, chunk_count: u32 },
    /// One chunk of a chunked upload: strict 0-based sequence and a
    /// CRC over `data` (the frame CRC guards the wire; the chunk CRC
    /// guards reassembly).
    Chunk { seq: u32, crc: u32, data: Vec<u8> },
    /// Close a chunked upload: `total_crc` is the CRC over the entire
    /// reassembled payload. On match the state is validated and
    /// restored atomically; on any mismatch nothing was applied.
    ChunkEnd { total_crc: u32 },
    /// Table-agnostic liveness probe: the server echoes `nonce` in a
    /// [`Response::Pong`] without touching any table, session, or
    /// writer state. The mesh membership layer's health check — cheap
    /// enough to ride every probe interval, and answered even by a
    /// draining server (drain refuses *work*, not liveness).
    Ping { nonce: u64 },
    /// Operator command: put the server into drain mode and hand its
    /// tables to `peers`. A draining server refuses new sessions and
    /// appends, advertises zero mass (so mesh samplers stop drawing
    /// from it), streams its full service state to the first reachable
    /// peer as a chunked *merge* upload (closed by
    /// [`Request::HandoffEnd`]), then stops its accept loop. `max_chunk`
    /// bounds the handoff chunk size (0 = default).
    Drain { max_chunk: u32, peers: Vec<String> },
    /// Close a chunked *handoff* upload (same staging and CRC rules as
    /// [`Request::ChunkEnd`]), but the assembled `ServiceState` is
    /// **merged** into the receiver's live tables — rows inserted with
    /// their exact checkpointed priorities — instead of replacing them.
    HandoffEnd { total_crc: u32 },
}

/// One response frame, server → client.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Request applied; nothing to return.
    Ok,
    /// `Hello` acknowledged; carries the server's default (first) table
    /// name so a sampler can bind to it without a separate `Stats`
    /// round-trip, plus the bound session: its id (quote it in the next
    /// `Hello` to resume), whether prior state was `resumed`, and the
    /// next request sequence number the server expects.
    Hello { default_table: String, session: u64, resumed: bool, next_seq: u64 },
    /// `Append` outcome: the first `consumed` steps were applied (the
    /// rest hit a rate-limiter stall — retriable), emitting `emitted`
    /// items across the tables.
    Appended { consumed: u32, emitted: u32 },
    /// A sampled batch.
    Sampled(SampleBatch),
    /// The sample was denied; retry later. The connection never blocks.
    WouldStall { reason: StallReason },
    /// Per-table stats.
    Stats { tables: Vec<TableInfo> },
    /// A serialized `ServiceState` payload (from `Checkpoint`).
    State { state: Vec<u8> },
    /// One table's sampleable mass (answer to [`Request::Mass`]).
    Mass { len: u64, mass: f32 },
    /// Opens a chunked checkpoint download (answer to
    /// [`Request::CheckpointChunked`]); `chunk_count` `Chunk` frames
    /// and a `ChunkEnd` follow on the same connection.
    ChunkBegin { total_len: u64, chunk_len: u32, chunk_count: u32 },
    /// One chunk of a chunked download (same layout and validation
    /// rules as [`Request::Chunk`]).
    Chunk { seq: u32, crc: u32, data: Vec<u8> },
    /// Closes a chunked checkpoint download with the whole-payload CRC.
    ChunkEnd { total_crc: u32 },
    /// Liveness echo (answer to [`Request::Ping`]): carries the probe's
    /// `nonce` back verbatim so a client can match probe to answer.
    Pong { nonce: u64 },
    /// The request was understood but failed; the message is the
    /// server-side error chain.
    Error { message: String },
}

/// Shared validation of a `ChunkBegin` header (both directions): the
/// declared chunking must be internally consistent, bounded, and
/// nonempty, so a corrupt or hostile header can never set up an
/// unbounded or self-contradictory transfer.
pub fn validate_chunk_begin(total_len: u64, chunk_len: u32, chunk_count: u32) -> Result<()> {
    if total_len == 0 {
        bail!("chunked transfer declares an empty state");
    }
    if total_len > MAX_CHUNKED_STATE {
        bail!("chunked transfer declares {total_len} bytes (cap {MAX_CHUNKED_STATE})");
    }
    if chunk_len == 0 || chunk_len as usize > MAX_CHUNK_LEN {
        bail!("chunk length {chunk_len} out of range [1, {MAX_CHUNK_LEN}]");
    }
    let expect = total_len.div_ceil(chunk_len as u64);
    if chunk_count as u64 != expect {
        bail!(
            "chunked transfer declares {chunk_count} chunks but {total_len} bytes / \
             {chunk_len}-byte chunks needs {expect}"
        );
    }
    Ok(())
}

/// One table's row in a `Stats` response.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TableInfo {
    pub name: String,
    pub len: u64,
    pub capacity: u64,
    pub stats: TableStatsSnapshot,
}

fn encode_step(w: &mut ByteWriter, s: &WriterStep) {
    w.f32s(&s.obs);
    w.f32s(&s.action);
    w.f32s(&s.next_obs);
    w.f32(s.reward);
    w.u8(s.done as u8);
    w.u8(s.truncated as u8);
}

/// Encode an `Append` request straight from borrowed steps — the
/// writer hot path: no `Request` value, no step clones, the frame
/// payload lands in the caller's reused [`ByteWriter`].
pub fn encode_append<'a>(
    w: &mut ByteWriter,
    actor_id: u64,
    seq: u64,
    dropped: u64,
    steps: impl ExactSizeIterator<Item = &'a WriterStep>,
) {
    w.u8(OP_APPEND);
    w.u64(actor_id);
    w.u64(seq);
    w.u64(dropped);
    w.u32(steps.len() as u32);
    for s in steps {
        encode_step(w, s);
    }
}

/// Encode a `Sample` request without cloning the table name.
pub fn encode_sample(w: &mut ByteWriter, table: &str, batch: u32, seq: u64) {
    w.u8(OP_SAMPLE);
    w.str_(table);
    w.u32(batch);
    w.u64(seq);
}

/// Encode an `UpdatePriorities` request straight from the learner's
/// `usize` indices (no intermediate `Vec<u64>`).
pub fn encode_update_priorities(
    w: &mut ByteWriter,
    table: &str,
    indices: &[usize],
    td_abs: &[f32],
    seq: u64,
) {
    encode_update_raw(w, table, indices.iter().map(|&i| i as u64), td_abs, seq);
}

/// The one definition of the `UpdatePriorities` wire layout; both the
/// hot path above and `Request::encode_into` delegate here so the two
/// can never drift.
fn encode_update_raw(
    w: &mut ByteWriter,
    table: &str,
    indices: impl ExactSizeIterator<Item = u64>,
    td_abs: &[f32],
    seq: u64,
) {
    w.u8(OP_UPDATE_PRIORITIES);
    w.str_(table);
    w.u64(indices.len() as u64);
    for i in indices {
        w.u64(i);
    }
    w.f32s(td_abs);
    w.u64(seq);
}

fn decode_step(r: &mut ByteReader) -> Result<WriterStep> {
    Ok(WriterStep {
        obs: r.f32s("step obs")?,
        action: r.f32s("step action")?,
        next_obs: r.f32s("step next_obs")?,
        reward: r.f32("step reward")?,
        done: r.u8("step done")? != 0,
        truncated: r.u8("step truncated")? != 0,
    })
}

/// Encode a `Sampled` *response* straight from the server's scratch
/// batch — the sampler hot path: no `Response` value, no batch clone.
pub fn encode_sampled(w: &mut ByteWriter, b: &SampleBatch) {
    w.u8(RESP_SAMPLED);
    encode_batch(w, b);
}

/// Encode a `Chunk` *response* straight from a borrowed slice of the
/// serialized state — the chunked-download hot path: no data clone,
/// the CRC computed in place.
pub fn encode_chunk(w: &mut ByteWriter, seq: u32, data: &[u8]) {
    w.u8(RESP_CHUNK);
    w.u32(seq);
    w.u32(crate::util::blob::crc32(data));
    w.bytes(data);
}

/// Encode a `Chunk` *request* straight from a borrowed slice of the
/// serialized state — the chunked-upload hot path: no data clone, the
/// CRC computed in place.
pub fn encode_chunk_request(w: &mut ByteWriter, seq: u32, data: &[u8]) {
    w.u8(OP_CHUNK);
    w.u32(seq);
    w.u32(crate::util::blob::crc32(data));
    w.bytes(data);
}

fn encode_batch(w: &mut ByteWriter, b: &SampleBatch) {
    w.u32(b.len() as u32);
    w.u64(b.indices.len() as u64);
    for &i in &b.indices {
        w.u64(i as u64);
    }
    w.f32s(&b.priorities);
    w.f32s(&b.is_weights);
    w.f32s(&b.obs);
    w.f32s(&b.action);
    w.f32s(&b.next_obs);
    w.f32s(&b.reward);
    w.f32s(&b.done);
}

/// Decode a sampled batch into a caller-owned [`SampleBatch`] (every
/// field vector cleared and refilled in place), so a learner's receive
/// loop reuses one set of allocations. On error `out` may hold partial
/// data and must not be used.
fn decode_batch_into(r: &mut ByteReader, out: &mut SampleBatch) -> Result<()> {
    let n = r.u32("batch size")? as usize;
    if n == 0 || n > MAX_SAMPLE_BATCH {
        bail!("implausible sampled-batch size {n}");
    }
    let idx_count = r.u64("batch indices")? as usize;
    if idx_count > MAX_SAMPLE_BATCH {
        bail!("implausible sampled-batch index count {idx_count}");
    }
    out.indices.clear();
    out.indices.reserve(idx_count);
    for _ in 0..idx_count {
        out.indices.push(r.u64("batch index")? as usize);
    }
    r.f32s_into("batch priorities", &mut out.priorities)?;
    r.f32s_into("batch is_weights", &mut out.is_weights)?;
    r.f32s_into("batch obs", &mut out.obs)?;
    r.f32s_into("batch action", &mut out.action)?;
    r.f32s_into("batch next_obs", &mut out.next_obs)?;
    r.f32s_into("batch reward", &mut out.reward)?;
    r.f32s_into("batch done", &mut out.done)?;
    if out.indices.len() != n
        || out.priorities.len() != n
        || out.reward.len() != n
        || out.done.len() != n
        || !(out.is_weights.is_empty() || out.is_weights.len() == n)
    {
        bail!(
            "inconsistent sampled batch: {n} items but {} indices / {} priorities / \
             {} rewards / {} dones / {} is_weights",
            out.indices.len(),
            out.priorities.len(),
            out.reward.len(),
            out.done.len(),
            out.is_weights.len()
        );
    }
    if out.obs.len() % n != 0 || out.action.len() % n != 0 || out.next_obs.len() != out.obs.len() {
        bail!(
            "inconsistent sampled batch: {} obs / {} next_obs / {} action values \
             do not divide into {n} items",
            out.obs.len(),
            out.next_obs.len(),
            out.action.len()
        );
    }
    Ok(())
}

fn decode_batch(r: &mut ByteReader) -> Result<SampleBatch> {
    let mut out = SampleBatch::default();
    decode_batch_into(r, &mut out)?;
    Ok(out)
}

/// Parse one *response* payload as a sample outcome, decoding a
/// `Sampled` batch into `out` without allocating. The client's receive
/// half of [`encode_sample`]; any other opcode (including `Error`) is
/// an `Err`.
pub fn decode_sample_response(payload: &[u8], out: &mut SampleBatch) -> Result<SampleOutcomeWire> {
    let mut r = ByteReader::new(payload);
    match r.u8("response opcode")? {
        RESP_SAMPLED => {
            decode_batch_into(&mut r, out)?;
            r.expect_end()?;
            Ok(SampleOutcomeWire::Sampled)
        }
        RESP_WOULD_STALL => {
            let reason = match r.u8("stall reason")? {
                0 => StallReason::Throttled,
                1 => StallReason::NotEnoughData,
                2 => StallReason::QuotaExhausted,
                other => bail!("unknown stall reason {other}"),
            };
            r.expect_end()?;
            Ok(SampleOutcomeWire::WouldStall(reason))
        }
        RESP_ERROR => bail!("replay server error: {}", r.str_("error message")?),
        other => bail!("unexpected response opcode {other} to Sample"),
    }
}

/// Outcome of [`decode_sample_response`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SampleOutcomeWire {
    Sampled,
    WouldStall(StallReason),
}

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        self.encode_into(&mut w);
        w.finish()
    }

    /// Encode into a caller-owned (typically reused) [`ByteWriter`].
    pub fn encode_into(&self, w: &mut ByteWriter) {
        match self {
            Request::Hello { rng_seed, session, tables } => {
                w.u8(OP_HELLO);
                w.u64(*rng_seed);
                w.u64(*session);
                w.u32(tables.len() as u32);
                for t in tables {
                    w.str_(t);
                }
            }
            Request::Append { actor_id, seq, dropped, steps } => {
                encode_append(w, *actor_id, *seq, *dropped, steps.iter())
            }
            Request::Sample { table, batch, seq } => encode_sample(w, table, *batch, *seq),
            Request::UpdatePriorities { table, indices, td_abs, seq } => {
                encode_update_raw(w, table, indices.iter().copied(), td_abs, *seq)
            }
            Request::Stats => w.u8(OP_STATS),
            Request::Checkpoint => w.u8(OP_CHECKPOINT),
            Request::Restore { state } => {
                w.u8(OP_RESTORE);
                w.bytes(state);
            }
            Request::Shutdown => w.u8(OP_SHUTDOWN),
            Request::Mass { table } => {
                w.u8(OP_MASS);
                w.str_(table);
            }
            Request::CheckpointChunked { max_chunk } => {
                w.u8(OP_CHECKPOINT_CHUNKED);
                w.u32(*max_chunk);
            }
            Request::ChunkBegin { total_len, chunk_len, chunk_count } => {
                w.u8(OP_CHUNK_BEGIN);
                w.u64(*total_len);
                w.u32(*chunk_len);
                w.u32(*chunk_count);
            }
            Request::Chunk { seq, crc, data } => {
                w.u8(OP_CHUNK);
                w.u32(*seq);
                w.u32(*crc);
                w.bytes(data);
            }
            Request::ChunkEnd { total_crc } => {
                w.u8(OP_CHUNK_END);
                w.u32(*total_crc);
            }
            Request::Ping { nonce } => {
                w.u8(OP_PING);
                w.u64(*nonce);
            }
            Request::Drain { max_chunk, peers } => {
                w.u8(OP_DRAIN);
                w.u32(*max_chunk);
                w.u32(peers.len() as u32);
                for p in peers {
                    w.str_(p);
                }
            }
            Request::HandoffEnd { total_crc } => {
                w.u8(OP_HANDOFF_END);
                w.u32(*total_crc);
            }
        }
    }

    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(payload);
        let op = r.u8("request opcode")?;
        let req = match op {
            OP_HELLO => {
                let rng_seed = r.u64("rng seed")?;
                let session = r.u64("session id")?;
                let count = r.u32("acl table count")? as usize;
                if count > MAX_TABLES {
                    bail!("hello claims {count} ACL tables (protocol cap {MAX_TABLES})");
                }
                let mut tables = Vec::with_capacity(count);
                for _ in 0..count {
                    tables.push(r.str_("acl table name")?);
                }
                Request::Hello { rng_seed, session, tables }
            }
            OP_APPEND => {
                let actor_id = r.u64("actor id")?;
                let seq = r.u64("request seq")?;
                let dropped = r.u64("dropped count")?;
                let count = r.u32("step count")? as usize;
                if count > MAX_APPEND_STEPS {
                    bail!("append claims {count} steps (protocol cap {MAX_APPEND_STEPS})");
                }
                let mut steps = Vec::with_capacity(count);
                for _ in 0..count {
                    steps.push(decode_step(&mut r)?);
                }
                Request::Append { actor_id, seq, dropped, steps }
            }
            OP_SAMPLE => {
                let table = r.str_("table name")?;
                let batch = r.u32("batch size")?;
                if batch == 0 || batch as usize > MAX_SAMPLE_BATCH {
                    bail!("sample batch {batch} out of range [1, {MAX_SAMPLE_BATCH}]");
                }
                Request::Sample { table, batch, seq: r.u64("request seq")? }
            }
            OP_UPDATE_PRIORITIES => {
                let table = r.str_("table name")?;
                let indices = r.u64s("priority indices")?;
                let td_abs = r.f32s("priority values")?;
                if indices.len() > MAX_UPDATE_INDICES {
                    bail!(
                        "priority update claims {} indices (protocol cap {MAX_UPDATE_INDICES})",
                        indices.len()
                    );
                }
                if indices.len() != td_abs.len() {
                    bail!(
                        "priority update has {} indices but {} values",
                        indices.len(),
                        td_abs.len()
                    );
                }
                // Reject poisonous priorities at the wire: a NaN stored
                // into the sum tree corrupts every interior sum up to the
                // root permanently, and ±inf/negative values corrupt the
                // sampling distribution. Decode failure → error frame.
                if let Some(bad) = td_abs.iter().find(|v| !v.is_finite() || **v < 0.0) {
                    bail!("priority update carries invalid |TD| value {bad} (must be finite and non-negative)");
                }
                Request::UpdatePriorities { table, indices, td_abs, seq: r.u64("request seq")? }
            }
            OP_STATS => Request::Stats,
            OP_CHECKPOINT => Request::Checkpoint,
            OP_RESTORE => Request::Restore { state: r.bytes("state payload")? },
            OP_SHUTDOWN => Request::Shutdown,
            OP_MASS => Request::Mass { table: r.str_("table name")? },
            OP_CHECKPOINT_CHUNKED => {
                let max_chunk = r.u32("max chunk length")?;
                if max_chunk == 0 || max_chunk as usize > MAX_CHUNK_LEN {
                    bail!("chunk length {max_chunk} out of range [1, {MAX_CHUNK_LEN}]");
                }
                Request::CheckpointChunked { max_chunk }
            }
            OP_CHUNK_BEGIN => {
                let total_len = r.u64("chunked total length")?;
                let chunk_len = r.u32("chunk length")?;
                let chunk_count = r.u32("chunk count")?;
                validate_chunk_begin(total_len, chunk_len, chunk_count)?;
                Request::ChunkBegin { total_len, chunk_len, chunk_count }
            }
            OP_CHUNK => {
                let seq = r.u32("chunk seq")?;
                let crc = r.u32("chunk crc")?;
                let data = r.bytes("chunk data")?;
                if data.is_empty() || data.len() > MAX_CHUNK_LEN {
                    bail!("chunk of {} bytes out of range [1, {MAX_CHUNK_LEN}]", data.len());
                }
                Request::Chunk { seq, crc, data }
            }
            OP_CHUNK_END => Request::ChunkEnd { total_crc: r.u32("chunked total crc")? },
            OP_PING => Request::Ping { nonce: r.u64("ping nonce")? },
            OP_DRAIN => {
                let max_chunk = r.u32("drain max chunk")?;
                if max_chunk as usize > MAX_CHUNK_LEN {
                    bail!("chunk length {max_chunk} out of range [0, {MAX_CHUNK_LEN}]");
                }
                let count = r.u32("drain peer count")? as usize;
                if count > MAX_TABLES {
                    bail!("drain claims {count} peers (protocol cap {MAX_TABLES})");
                }
                let mut peers = Vec::with_capacity(count);
                for _ in 0..count {
                    peers.push(r.str_("drain peer endpoint")?);
                }
                Request::Drain { max_chunk, peers }
            }
            OP_HANDOFF_END => Request::HandoffEnd { total_crc: r.u32("handoff total crc")? },
            other => bail!("unknown request opcode {other}"),
        };
        r.expect_end()?;
        Ok(req)
    }
}

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        self.encode_into(&mut w);
        w.finish()
    }

    /// Encode into a caller-owned (typically reused) [`ByteWriter`].
    pub fn encode_into(&self, w: &mut ByteWriter) {
        match self {
            Response::Ok => w.u8(RESP_OK),
            Response::Hello { default_table, session, resumed, next_seq } => {
                w.u8(RESP_HELLO);
                w.str_(default_table);
                w.u64(*session);
                w.u8(*resumed as u8);
                w.u64(*next_seq);
            }
            Response::Appended { consumed, emitted } => {
                w.u8(RESP_APPENDED);
                w.u32(*consumed);
                w.u32(*emitted);
            }
            Response::Sampled(b) => encode_sampled(w, b),
            Response::WouldStall { reason } => {
                w.u8(RESP_WOULD_STALL);
                w.u8(match reason {
                    StallReason::Throttled => 0,
                    StallReason::NotEnoughData => 1,
                    StallReason::QuotaExhausted => 2,
                });
            }
            Response::Stats { tables } => {
                w.u8(RESP_STATS);
                w.u32(tables.len() as u32);
                for t in tables {
                    w.str_(&t.name);
                    w.u64(t.len);
                    w.u64(t.capacity);
                    w.u64(t.stats.inserts as u64);
                    w.u64(t.stats.sample_batches as u64);
                    w.u64(t.stats.sampled_items as u64);
                    w.u64(t.stats.priority_updates as u64);
                    w.u64(t.stats.insert_stalls as u64);
                    w.u64(t.stats.sample_stalls as u64);
                    w.u64(t.stats.steps_dropped as u64);
                    w.u64(t.stats.evict_fifo as u64);
                    w.u64(t.stats.evict_lifo as u64);
                    w.u64(t.stats.evict_lowest as u64);
                    w.u64(t.stats.evict_sampled as u64);
                    w.u64(t.stats.max_times_sampled as u64);
                }
            }
            Response::State { state } => {
                w.u8(RESP_STATE);
                w.bytes(state);
            }
            Response::Mass { len, mass } => {
                w.u8(RESP_MASS);
                w.u64(*len);
                w.f32(*mass);
            }
            Response::ChunkBegin { total_len, chunk_len, chunk_count } => {
                w.u8(RESP_CHUNK_BEGIN);
                w.u64(*total_len);
                w.u32(*chunk_len);
                w.u32(*chunk_count);
            }
            Response::Chunk { seq, crc, data } => {
                w.u8(RESP_CHUNK);
                w.u32(*seq);
                w.u32(*crc);
                w.bytes(data);
            }
            Response::ChunkEnd { total_crc } => {
                w.u8(RESP_CHUNK_END);
                w.u32(*total_crc);
            }
            Response::Pong { nonce } => {
                w.u8(RESP_PONG);
                w.u64(*nonce);
            }
            Response::Error { message } => {
                w.u8(RESP_ERROR);
                w.str_(message);
            }
        }
    }

    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut r = ByteReader::new(payload);
        let op = r.u8("response opcode")?;
        let resp = match op {
            RESP_OK => Response::Ok,
            RESP_HELLO => Response::Hello {
                default_table: r.str_("default table name")?,
                session: r.u64("session id")?,
                resumed: r.u8("resumed flag")? != 0,
                next_seq: r.u64("next seq")?,
            },
            RESP_APPENDED => Response::Appended {
                consumed: r.u32("consumed count")?,
                emitted: r.u32("emitted count")?,
            },
            RESP_SAMPLED => Response::Sampled(decode_batch(&mut r)?),
            RESP_WOULD_STALL => {
                let reason = match r.u8("stall reason")? {
                    0 => StallReason::Throttled,
                    1 => StallReason::NotEnoughData,
                    2 => StallReason::QuotaExhausted,
                    other => bail!("unknown stall reason {other}"),
                };
                Response::WouldStall { reason }
            }
            RESP_STATS => {
                let count = r.u32("table count")? as usize;
                if count > MAX_TABLES {
                    bail!("stats claim {count} tables (protocol cap {MAX_TABLES})");
                }
                let mut tables = Vec::with_capacity(count);
                for _ in 0..count {
                    tables.push(TableInfo {
                        name: r.str_("table name")?,
                        len: r.u64("table len")?,
                        capacity: r.u64("table capacity")?,
                        stats: TableStatsSnapshot {
                            inserts: r.u64("inserts")? as usize,
                            sample_batches: r.u64("sample_batches")? as usize,
                            sampled_items: r.u64("sampled_items")? as usize,
                            priority_updates: r.u64("priority_updates")? as usize,
                            insert_stalls: r.u64("insert_stalls")? as usize,
                            sample_stalls: r.u64("sample_stalls")? as usize,
                            steps_dropped: r.u64("steps_dropped")? as usize,
                            evict_fifo: r.u64("evict_fifo")? as usize,
                            evict_lifo: r.u64("evict_lifo")? as usize,
                            evict_lowest: r.u64("evict_lowest")? as usize,
                            evict_sampled: r.u64("evict_sampled")? as usize,
                            max_times_sampled: r.u64("max_times_sampled")? as usize,
                        },
                    });
                }
                Response::Stats { tables }
            }
            RESP_STATE => Response::State { state: r.bytes("state payload")? },
            RESP_MASS => Response::Mass { len: r.u64("table len")?, mass: r.f32("table mass")? },
            RESP_CHUNK_BEGIN => {
                let total_len = r.u64("chunked total length")?;
                let chunk_len = r.u32("chunk length")?;
                let chunk_count = r.u32("chunk count")?;
                validate_chunk_begin(total_len, chunk_len, chunk_count)?;
                Response::ChunkBegin { total_len, chunk_len, chunk_count }
            }
            RESP_CHUNK => {
                let seq = r.u32("chunk seq")?;
                let crc = r.u32("chunk crc")?;
                let data = r.bytes("chunk data")?;
                if data.is_empty() || data.len() > MAX_CHUNK_LEN {
                    bail!("chunk of {} bytes out of range [1, {MAX_CHUNK_LEN}]", data.len());
                }
                Response::Chunk { seq, crc, data }
            }
            RESP_CHUNK_END => Response::ChunkEnd { total_crc: r.u32("chunked total crc")? },
            RESP_PONG => Response::Pong { nonce: r.u64("pong nonce")? },
            RESP_ERROR => Response::Error { message: r.str_("error message")? },
            other => bail!("unknown response opcode {other}"),
        };
        r.expect_end()?;
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(i: usize) -> WriterStep {
        WriterStep {
            obs: vec![i as f32, -1.0],
            action: vec![0.5],
            next_obs: vec![i as f32 + 1.0, -1.0],
            reward: i as f32,
            done: i % 2 == 0,
            truncated: i % 3 == 0,
        }
    }

    #[test]
    fn every_request_roundtrips() {
        let reqs = vec![
            Request::Hello { rng_seed: 0xDEAD_BEEF, session: 0, tables: vec![] },
            Request::Hello {
                rng_seed: 1,
                session: 0xFEED_F00D,
                tables: vec!["hot".into(), "cold".into()],
            },
            Request::Append { actor_id: 3, seq: 7, dropped: 0, steps: vec![step(0), step(1)] },
            Request::Append { actor_id: 0, seq: 0, dropped: 12, steps: vec![] },
            Request::Sample { table: "replay".into(), batch: 32, seq: 9 },
            Request::UpdatePriorities {
                table: "replay".into(),
                indices: vec![0, 7, 1 << 40],
                td_abs: vec![0.1, 2.0, 0.0],
                seq: 10,
            },
            Request::Stats,
            Request::Checkpoint,
            Request::Restore { state: vec![1, 2, 3, 4] },
            Request::Shutdown,
            Request::Mass { table: "replay".into() },
            Request::CheckpointChunked { max_chunk: 4096 },
            Request::ChunkBegin { total_len: 10, chunk_len: 4, chunk_count: 3 },
            Request::Chunk { seq: 2, crc: 0xDEAD_BEEF, data: vec![7; 16] },
            Request::ChunkEnd { total_crc: 0x1234_5678 },
            Request::Ping { nonce: 0xFACE_CAFE },
            Request::Drain { max_chunk: 0, peers: vec![] },
            Request::Drain {
                max_chunk: 4096,
                peers: vec!["tcp://10.0.0.1:9000".into(), "/tmp/peer.sock".into()],
            },
            Request::HandoffEnd { total_crc: 0x8765_4321 },
        ];
        for req in reqs {
            let decoded = Request::decode(&req.encode()).unwrap();
            assert_eq!(decoded, req);
        }
    }

    #[test]
    fn every_response_roundtrips() {
        let batch = SampleBatch {
            indices: vec![4, 9],
            priorities: vec![0.5, 1.5],
            is_weights: vec![1.0, 0.25],
            obs: vec![0.0, 1.0, 2.0, 3.0],
            action: vec![0.1, 0.2],
            next_obs: vec![1.0, 2.0, 3.0, 4.0],
            reward: vec![1.0, -1.0],
            done: vec![0.0, 1.0],
        };
        let resps = vec![
            Response::Ok,
            Response::Hello {
                default_table: "replay".into(),
                session: 0xABCD,
                resumed: true,
                next_seq: 42,
            },
            Response::Hello {
                default_table: "replay".into(),
                session: 1,
                resumed: false,
                next_seq: 1,
            },
            Response::Appended { consumed: 5, emitted: 9 },
            Response::Sampled(batch),
            Response::WouldStall { reason: StallReason::Throttled },
            Response::WouldStall { reason: StallReason::NotEnoughData },
            Response::WouldStall { reason: StallReason::QuotaExhausted },
            Response::Stats {
                tables: vec![TableInfo {
                    name: "replay".into(),
                    len: 128,
                    capacity: 1024,
                    stats: TableStatsSnapshot {
                        inserts: 200,
                        sample_batches: 12,
                        sampled_items: 384,
                        priority_updates: 384,
                        insert_stalls: 3,
                        sample_stalls: 9,
                        steps_dropped: 4,
                        evict_fifo: 72,
                        evict_lifo: 0,
                        evict_lowest: 5,
                        evict_sampled: 11,
                        max_times_sampled: 6,
                    },
                }],
            },
            Response::State { state: vec![9, 9, 9] },
            Response::Mass { len: 4096, mass: 17.25 },
            Response::ChunkBegin { total_len: 9, chunk_len: 3, chunk_count: 3 },
            Response::Chunk { seq: 0, crc: 1, data: vec![0xAB; 3] },
            Response::ChunkEnd { total_crc: 0xFFFF_0000 },
            Response::Pong { nonce: 0xBEEF_0042 },
            Response::Error { message: "unknown table `x`".into() },
        ];
        for resp in resps {
            let decoded = Response::decode(&resp.encode()).unwrap();
            assert_eq!(decoded, resp);
        }
    }

    #[test]
    fn decode_rejects_malformed_payloads() {
        // Unknown opcodes.
        assert!(Request::decode(&[0xEE]).is_err());
        assert!(Response::decode(&[0xEE]).is_err());
        // Empty payloads.
        assert!(Request::decode(&[]).is_err());
        assert!(Response::decode(&[]).is_err());
        // Truncated mid-field.
        let full =
            Request::Append { actor_id: 1, seq: 3, dropped: 0, steps: vec![step(0)] }.encode();
        for cut in 1..full.len() {
            assert!(Request::decode(&full[..cut]).is_err(), "cut at {cut}");
        }
        // Truncated session-resume Hello (ACL list included so the
        // string path is cut too): every cut must error.
        let hello =
            Request::Hello { rng_seed: 0x1234, session: 0x5678, tables: vec!["hot".into()] }
                .encode();
        for cut in 1..hello.len() {
            assert!(Request::decode(&hello[..cut]).is_err(), "hello cut at {cut}");
        }
        let hello_resp = Response::Hello {
            default_table: "replay".into(),
            session: 0x9ABC,
            resumed: true,
            next_seq: 17,
        }
        .encode();
        for cut in 1..hello_resp.len() {
            assert!(Response::decode(&hello_resp[..cut]).is_err(), "hello resp cut at {cut}");
        }
        // Trailing garbage after a valid request.
        let mut padded = Request::Stats.encode();
        padded.push(0);
        assert!(Request::decode(&padded).is_err());
        // Mismatched priority-update lengths.
        let mut w = ByteWriter::new();
        w.u8(OP_UPDATE_PRIORITIES);
        w.str_("replay");
        w.u64s(&[1, 2, 3]);
        w.f32s(&[0.5]);
        w.u64(1);
        let err = Request::decode(&w.finish()).unwrap_err().to_string();
        assert!(err.contains("3 indices"), "{err}");
        // Zero-batch sample.
        let zero = Request::Sample { table: "t".into(), batch: 0, seq: 1 }.encode();
        assert!(Request::decode(&zero).is_err());
        // Truncated chunked-transfer frames: every cut must error.
        let chunk = Request::Chunk { seq: 1, crc: 0xABCD, data: vec![3; 9] }.encode();
        for cut in 1..chunk.len() {
            assert!(Request::decode(&chunk[..cut]).is_err(), "chunk cut at {cut}");
        }
        let begin = Response::ChunkBegin { total_len: 8, chunk_len: 4, chunk_count: 2 }.encode();
        for cut in 1..begin.len() {
            assert!(Response::decode(&begin[..cut]).is_err(), "chunk-begin cut at {cut}");
        }
        // Truncated membership/drain frames: every cut must error.
        let ping = Request::Ping { nonce: 0x1122_3344_5566_7788 }.encode();
        for cut in 1..ping.len() {
            assert!(Request::decode(&ping[..cut]).is_err(), "ping cut at {cut}");
        }
        let drain =
            Request::Drain { max_chunk: 512, peers: vec!["tcp://h:1".into(), "b".into()] }.encode();
        for cut in 1..drain.len() {
            assert!(Request::decode(&drain[..cut]).is_err(), "drain cut at {cut}");
        }
        let pong = Response::Pong { nonce: 0x99AA_BBCC_DDEE_FF00 }.encode();
        for cut in 1..pong.len() {
            assert!(Response::decode(&pong[..cut]).is_err(), "pong cut at {cut}");
        }
        // A drain chunk bound past the protocol cap is refused.
        let huge =
            Request::Drain { max_chunk: (MAX_CHUNK_LEN + 1) as u32, peers: vec![] }.encode();
        assert!(Request::decode(&huge).is_err());
    }

    #[test]
    fn chunk_begin_validation_rejects_inconsistent_headers() {
        // A consistent header passes both decode directions.
        assert!(validate_chunk_begin(10, 4, 3).is_ok());
        assert!(validate_chunk_begin(8, 4, 2).is_ok());
        // Empty, oversized-total, zero/oversized chunk length,
        // chunk count inconsistent with total/len — all rejected.
        assert!(validate_chunk_begin(0, 4, 0).is_err());
        assert!(validate_chunk_begin(MAX_CHUNKED_STATE + 1, 1 << 20, u32::MAX).is_err());
        assert!(validate_chunk_begin(10, 0, 1).is_err());
        assert!(validate_chunk_begin(10, (MAX_CHUNK_LEN + 1) as u32, 1).is_err());
        assert!(validate_chunk_begin(10, 4, 2).is_err());
        assert!(validate_chunk_begin(10, 4, 4).is_err());
        // The wire decoders enforce the same rules.
        let bad = Request::ChunkBegin { total_len: 10, chunk_len: 4, chunk_count: 9 };
        assert!(Request::decode(&bad.encode()).is_err());
        let bad = Response::ChunkBegin { total_len: 10, chunk_len: 4, chunk_count: 9 };
        assert!(Response::decode(&bad.encode()).is_err());
        // An oversized or empty single chunk is refused at decode.
        let empty = Request::Chunk { seq: 0, crc: 0, data: vec![] };
        assert!(Request::decode(&empty.encode()).is_err());
    }

    #[test]
    fn writer_step_flags_roundtrip() {
        for (done, truncated) in [(false, false), (true, false), (false, true), (true, true)] {
            let req = Request::Append {
                actor_id: 0,
                seq: 0,
                dropped: 0,
                steps: vec![WriterStep { done, truncated, ..step(1) }],
            };
            match Request::decode(&req.encode()).unwrap() {
                Request::Append { steps, .. } => {
                    assert_eq!(steps[0].done, done);
                    assert_eq!(steps[0].truncated, truncated);
                }
                other => panic!("decoded {other:?}"),
            }
        }
    }
}
