//! The remote replay server: a Unix-domain-socket front-end over one
//! [`ReplayService`] (Reverb's `reverb.Server` shape, std-only).
//!
//! One accept loop, one detached thread per connection. Each
//! connection owns its server-side state: a sampling RNG (seeded by
//! the client's `Hello`, or from the connection id) and one
//! [`TrajectoryWriter`] per actor id, so remote actors get the same
//! item assembly (N-step folding, sequence windows, boundary rules) as
//! local ones and sharded tables keep their actor-affinity routing.
//!
//! # Failure semantics
//!
//! * A malformed *frame* (truncated, bit-flipped, oversized length,
//!   wrong magic) gets a best-effort [`Response::Error`] and the
//!   connection is dropped — the stream can no longer be trusted to be
//!   on a frame boundary. Nothing was applied: a request is decoded in
//!   full before any table is touched.
//! * A malformed *payload* inside a checksummed frame (bad opcode,
//!   inconsistent lengths) gets a [`Response::Error`] and the
//!   connection stays up (the frame boundary is intact).
//! * Application errors (unknown table, out-of-range indices,
//!   non-finite priorities, failed restore) get a [`Response::Error`]
//!   carrying the server-side error chain; the connection stays up.
//! * A stalled sample is a retriable [`Response::WouldStall`]; a
//!   partially admitted insert batch is a short
//!   [`Response::Appended`]. The server never blocks a connection on a
//!   rate limiter.

use super::frame::{read_frame_into, write_frame};
use super::proto::{self, Request, Response, StallReason, TableInfo};
use crate::replay::SampleBatch;
use crate::service::{ReplayService, SampleOutcome, ServiceState, TrajectoryWriter};
use crate::util::blob::ByteWriter;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Decrements the server's live-connection count when a connection
/// thread exits by any path (EOF, protocol error, shutdown, panic).
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Release);
    }
}

/// Most distinct actor ids one connection may write for. Every other
/// hostile count in the protocol is bounded; this bounds the
/// server-side writer map (a buggy client passing a step counter as
/// its actor id would otherwise grow it without limit).
pub const MAX_WRITERS_PER_CONN: usize = 1_024;

/// A bound replay server. [`Self::serve`] runs the accept loop until a
/// client sends `Shutdown` (or [`Self::stop_handle`] is flipped).
pub struct ReplayServer {
    service: Arc<ReplayService>,
    listener: UnixListener,
    path: PathBuf,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    seed: u64,
    /// Expected base step dims (obs, action), when known: `Append`
    /// steps are rejected with a descriptive error on mismatch instead
    /// of silently truncating/padding rows in storage.
    dims: Option<(usize, usize)>,
}

impl ReplayServer {
    /// Bind a Unix-domain socket at `path`. A stale socket file left by
    /// a dead server is replaced; a socket another server still answers
    /// on, or any other kind of file, is refused. `seed` derives the
    /// default per-connection sampling RNGs.
    pub fn bind(service: Arc<ReplayService>, path: impl AsRef<Path>, seed: u64) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Ok(meta) = std::fs::symlink_metadata(&path) {
            if !std::os::unix::fs::FileTypeExt::is_socket(&meta.file_type()) {
                bail!(
                    "{} exists and is not a socket — refusing to replace it",
                    path.display()
                );
            }
            // Liveness probe: only a DEAD server's socket may be
            // replaced. Stealing a live server's path would split the
            // experience stream between two servers with no error.
            if UnixStream::connect(&path).is_ok() {
                bail!(
                    "a replay server is already listening on {} — refusing to replace it",
                    path.display()
                );
            }
            std::fs::remove_file(&path)
                .with_context(|| format!("removing stale socket {}", path.display()))?;
        }
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("binding replay server socket {}", path.display()))?;
        // Non-blocking accept so the loop can notice a stop request.
        listener
            .set_nonblocking(true)
            .context("setting the listener non-blocking")?;
        Ok(Self {
            service,
            listener,
            path,
            stop: Arc::new(AtomicBool::new(false)),
            active: Arc::new(AtomicUsize::new(0)),
            seed,
            dims: None,
        })
    }

    /// Enforce base step dims on every `Append` (what `pal serve`'s
    /// `--obs-dim`/`--act-dim` declare): mismatched clients get a
    /// descriptive error on their first frame instead of silently
    /// corrupted rows.
    pub fn expect_dims(mut self, obs_dim: usize, act_dim: usize) -> Self {
        self.dims = Some((obs_dim, act_dim));
        self
    }

    /// Flag that ends the accept loop (also set by a `Shutdown` RPC).
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    pub fn socket_path(&self) -> &Path {
        &self.path
    }

    /// Accept loop. Returns after `Shutdown` (or an external stop);
    /// connection threads are detached and exit when their client hangs
    /// up. On the way out the loop drains in-flight connections
    /// (bounded wait) so a post-`serve` state capture cannot race a
    /// request the server already acknowledged, then removes the
    /// socket file.
    pub fn serve(&self) -> Result<()> {
        let mut conn_id = 0u64;
        while !self.stop.load(Ordering::Relaxed) {
            match self.listener.accept() {
                Ok((stream, _addr)) => {
                    conn_id += 1;
                    let service = Arc::clone(&self.service);
                    let stop = Arc::clone(&self.stop);
                    let guard = ConnGuard(Arc::clone(&self.active));
                    self.active.fetch_add(1, Ordering::Acquire);
                    let dims = self.dims;
                    let seed = self
                        .seed
                        .wrapping_add(conn_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    std::thread::spawn(move || {
                        let _guard = guard;
                        handle_connection(service, stream, seed, stop, dims);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("accepting on replay server socket {}", self.path.display())
                    });
                }
            }
        }
        // Drain: clients that quiesced before Shutdown disconnect
        // promptly; an idle client parked in a blocking read cannot be
        // joined, so the wait is bounded and reported.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while self.active.load(Ordering::Acquire) > 0 {
            if std::time::Instant::now() >= deadline {
                eprintln!(
                    "[pal] WARNING: {} connection(s) still open at shutdown; \
                     a concurrent state capture may miss their in-flight requests",
                    self.active.load(Ordering::Acquire)
                );
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        std::fs::remove_file(&self.path).ok();
        Ok(())
    }
}

/// Per-connection loop: read frame → decode → dispatch → respond. One
/// read buffer and one response encoder per connection, reused for
/// every frame, so framing and response encoding allocate nothing per
/// RPC (request *decoding* still materializes owned payloads — an
/// `Append`'s steps become storage rows).
fn handle_connection(
    service: Arc<ReplayService>,
    mut stream: UnixStream,
    seed: u64,
    stop: Arc<AtomicBool>,
    dims: Option<(usize, usize)>,
) {
    // Accepted sockets may inherit the listener's non-blocking mode;
    // connection I/O is plain blocking reads.
    let _ = stream.set_nonblocking(false);
    let mut rng = Rng::new(seed);
    let mut writers: HashMap<u64, TrajectoryWriter> = HashMap::new();
    let mut scratch = SampleBatch::default();
    let mut rbuf: Vec<u8> = Vec::new();
    let mut enc = ByteWriter::new();
    loop {
        match read_frame_into(&mut stream, &mut rbuf) {
            Ok(true) => {}
            // Client hung up between frames.
            Ok(false) => break,
            Err(e) => {
                // The stream may be mid-frame; answer and drop it.
                let resp = Response::Error { message: format!("protocol error: {e}") };
                let _ = write_frame(&mut stream, &resp.encode());
                break;
            }
        }
        enc.reset();
        let mut shutdown = false;
        match Request::decode(&rbuf) {
            // Frame boundaries are intact (the frame checksum passed);
            // a bad payload is answerable without closing.
            Err(e) => {
                Response::Error { message: format!("bad request: {e}") }.encode_into(&mut enc)
            }
            Ok(Request::Shutdown) => {
                Response::Ok.encode_into(&mut enc);
                shutdown = true;
            }
            Ok(req) => {
                dispatch_into(&service, &mut writers, &mut rng, &mut scratch, dims, req, &mut enc)
            }
        }
        if shutdown {
            // Set the stop flag BEFORE attempting the Ok response: a
            // client that hangs up right after sending Shutdown must
            // still stop the server (the reply is best-effort).
            stop.store(true, Ordering::Relaxed);
            let _ = write_frame(&mut stream, enc.as_slice());
            break;
        }
        if write_frame(&mut stream, enc.as_slice()).is_err() {
            break;
        }
    }
}

/// Apply one decoded request against the service, encoding the
/// response into `enc`. Infallible by construction: every failure is
/// an encoded [`Response::Error`], so a hostile request can never take
/// the connection thread down. The `Sampled` hot path encodes the
/// scratch batch directly (no clone, no `Response` value).
fn dispatch_into(
    service: &Arc<ReplayService>,
    writers: &mut HashMap<u64, TrajectoryWriter>,
    rng: &mut Rng,
    scratch: &mut SampleBatch,
    dims: Option<(usize, usize)>,
    req: Request,
    enc: &mut ByteWriter,
) {
    if let Request::Sample { table, batch } = &req {
        match service.sampler(table) {
            None => {
                Response::Error { message: format!("unknown table `{table}`") }.encode_into(enc)
            }
            Some(sampler) => match sampler.try_sample(*batch as usize, rng, scratch) {
                SampleOutcome::Sampled => proto::encode_sampled(enc, scratch),
                SampleOutcome::Throttled => {
                    Response::WouldStall { reason: StallReason::Throttled }.encode_into(enc)
                }
                SampleOutcome::NotEnoughData => {
                    Response::WouldStall { reason: StallReason::NotEnoughData }.encode_into(enc)
                }
            },
        }
        return;
    }
    dispatch_cold(service, writers, rng, dims, req).encode_into(enc);
}

/// The non-`Sample` requests, as plain response values (their payloads
/// are either tiny or intrinsically owned, so value construction costs
/// nothing that matters).
fn dispatch_cold(
    service: &Arc<ReplayService>,
    writers: &mut HashMap<u64, TrajectoryWriter>,
    rng: &mut Rng,
    dims: Option<(usize, usize)>,
    req: Request,
) -> Response {
    match req {
        Request::Hello { rng_seed } => {
            *rng = Rng::new(rng_seed);
            Response::Hello { default_table: service.default_table().name().to_string() }
        }
        Request::Append { actor_id, steps } => {
            // Validate the WHOLE batch before applying any of it, so a
            // malformed batch never half-applies. Without declared dims
            // only self-consistency is checkable; with them a
            // mismatched client fails on its first frame instead of
            // silently truncating/padding rows in storage.
            for (i, s) in steps.iter().enumerate() {
                let self_consistent =
                    !s.obs.is_empty() && !s.action.is_empty() && s.obs.len() == s.next_obs.len();
                let dims_ok = dims
                    .map_or(true, |(od, ad)| s.obs.len() == od && s.action.len() == ad);
                if !self_consistent || !dims_ok {
                    let expected = match dims {
                        Some((od, ad)) => format!("obs_dim {od}, act_dim {ad}"),
                        None => "non-empty obs/action with obs_dim == next_obs dim".to_string(),
                    };
                    return Response::Error {
                        message: format!(
                            "append step {i} has dims obs={}/next_obs={}/action={}, server \
                             expects {expected}",
                            s.obs.len(),
                            s.next_obs.len(),
                            s.action.len(),
                        ),
                    };
                }
            }
            if !writers.contains_key(&actor_id) && writers.len() >= MAX_WRITERS_PER_CONN {
                return Response::Error {
                    message: format!(
                        "connection already writes for {MAX_WRITERS_PER_CONN} distinct \
                         actor ids — actor id {actor_id} rejected (buggy id generation?)"
                    ),
                };
            }
            let writer = writers
                .entry(actor_id)
                .or_insert_with(|| service.writer(actor_id as usize));
            let mut consumed = 0u32;
            let mut emitted = 0u32;
            for step in steps {
                // Stop at the first limiter stall; the client retries
                // the tail. An admitted step is fully fanned out, so an
                // insert is never half-applied.
                if writer.throttled() {
                    break;
                }
                emitted += writer.append(step) as u32;
                consumed += 1;
            }
            Response::Appended { consumed, emitted }
        }
        // Handled by the hot path in `dispatch_into`.
        Request::Sample { .. } => unreachable!("Sample is dispatched before the cold path"),
        Request::UpdatePriorities { table, indices, td_abs } => match service.table(&table) {
            None => Response::Error { message: format!("unknown table `{table}`") },
            Some(t) => {
                let cap = t.capacity() as u64;
                if let Some(bad) = indices.iter().find(|&&i| i >= cap) {
                    return Response::Error {
                        message: format!(
                            "priority index {bad} out of range for table `{table}` \
                             (capacity {cap})"
                        ),
                    };
                }
                if let Some(bad) = td_abs.iter().find(|v| !v.is_finite()) {
                    return Response::Error {
                        message: format!("non-finite priority value {bad} rejected"),
                    };
                }
                let idx: Vec<usize> = indices.iter().map(|&i| i as usize).collect();
                t.update_priorities(&idx, &td_abs);
                Response::Ok
            }
        },
        Request::Stats => Response::Stats {
            tables: service
                .tables()
                .iter()
                .map(|t| TableInfo {
                    name: t.name().to_string(),
                    len: t.len() as u64,
                    capacity: t.capacity() as u64,
                    stats: t.stats_snapshot(),
                })
                .collect(),
        },
        Request::Checkpoint => match service.checkpoint() {
            Ok(state) => {
                let state = state.encode();
                // A state payload the framing layer cannot carry must be
                // a clear error frame, not a dropped connection.
                if state.len() + 64 > super::frame::MAX_FRAME_LEN {
                    Response::Error {
                        message: format!(
                            "checkpoint is {} bytes, larger than the {}-byte frame cap — \
                             checkpoint the serving process directly (`pal serve --save-state`)",
                            state.len(),
                            super::frame::MAX_FRAME_LEN
                        ),
                    }
                } else {
                    Response::State { state }
                }
            }
            Err(e) => Response::Error { message: format!("checkpoint failed: {e}") },
        },
        Request::Restore { state } => {
            match ServiceState::decode(&state).and_then(|s| service.restore(&s)) {
                Ok(()) => Response::Ok,
                Err(e) => Response::Error { message: format!("restore failed: {e}") },
            }
        }
        // Handled (and answered) by the connection loop before dispatch.
        Request::Shutdown => Response::Ok,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::UniformReplay;
    use crate::service::{ItemKind, RateLimiter, Table};

    /// Round one request through the encoding dispatch path back to a
    /// decoded `Response` (what tests assert on).
    fn dispatch(
        service: &Arc<ReplayService>,
        writers: &mut HashMap<u64, TrajectoryWriter>,
        rng: &mut Rng,
        scratch: &mut SampleBatch,
        dims: Option<(usize, usize)>,
        req: Request,
    ) -> Response {
        let mut enc = ByteWriter::new();
        dispatch_into(service, writers, rng, scratch, dims, req, &mut enc);
        Response::decode(enc.as_slice()).expect("dispatch must encode a decodable response")
    }

    fn tiny_service() -> Arc<ReplayService> {
        Arc::new(
            ReplayService::new(vec![Table::new(
                "replay",
                ItemKind::OneStep,
                Arc::new(UniformReplay::new(32, 2, 1)),
                RateLimiter::Unlimited { min_size_to_sample: 1 },
            )])
            .unwrap(),
        )
    }

    #[test]
    fn bind_refuses_non_socket_files_and_replaces_stale_sockets() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("pal_srv_bind_{}.sock", std::process::id()));
        std::fs::write(&path, b"not a socket").unwrap();
        assert!(ReplayServer::bind(tiny_service(), &path, 0).is_err());
        std::fs::remove_file(&path).unwrap();

        // A stale socket (no listener behind it) is replaced.
        {
            let first = ReplayServer::bind(tiny_service(), &path, 0).unwrap();
            drop(first); // listener gone, socket file left behind
        }
        assert!(path.exists(), "dropping the server leaves the socket file");
        let second = ReplayServer::bind(tiny_service(), &path, 0).unwrap();
        assert_eq!(second.socket_path(), path.as_path());
        drop(second);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dispatch_rejects_hostile_priority_updates() {
        let service = tiny_service();
        let mut writers = HashMap::new();
        let mut rng = Rng::new(1);
        let mut scratch = SampleBatch::default();
        // Out-of-range index.
        let resp = dispatch(
            &service,
            &mut writers,
            &mut rng,
            &mut scratch,
            None,
            Request::UpdatePriorities {
                table: "replay".into(),
                indices: vec![1 << 50],
                td_abs: vec![1.0],
            },
        );
        match resp {
            Response::Error { message } => assert!(message.contains("out of range"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        // Non-finite priority.
        let resp = dispatch(
            &service,
            &mut writers,
            &mut rng,
            &mut scratch,
            None,
            Request::UpdatePriorities {
                table: "replay".into(),
                indices: vec![0],
                td_abs: vec![f32::NAN],
            },
        );
        assert!(matches!(resp, Response::Error { .. }));
        // Unknown table.
        let resp = dispatch(
            &service,
            &mut writers,
            &mut rng,
            &mut scratch,
            None,
            Request::Sample { table: "nope".into(), batch: 4 },
        );
        assert!(matches!(resp, Response::Error { .. }));
    }

    fn step_with_dims(obs: usize, act: usize) -> crate::service::WriterStep {
        crate::service::WriterStep {
            obs: vec![0.5; obs],
            action: vec![0.1; act],
            next_obs: vec![0.6; obs],
            reward: 1.0,
            done: false,
            truncated: false,
        }
    }

    #[test]
    fn dispatch_rejects_mismatched_step_dims_atomically() {
        let service = tiny_service(); // tables are obs_dim 2, act_dim 1
        let mut writers = HashMap::new();
        let mut rng = Rng::new(1);
        let mut scratch = SampleBatch::default();
        // Declared dims: a wrong-width step is rejected and NOTHING of
        // the batch (even its valid steps) is applied.
        let resp = dispatch(
            &service,
            &mut writers,
            &mut rng,
            &mut scratch,
            Some((2, 1)),
            Request::Append {
                actor_id: 0,
                steps: vec![step_with_dims(2, 1), step_with_dims(8, 1)],
            },
        );
        match resp {
            Response::Error { message } => assert!(message.contains("expects"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        assert_eq!(service.table("replay").unwrap().len(), 0);
        // Without declared dims, self-inconsistent steps still fail.
        let mut bad = step_with_dims(2, 1);
        bad.next_obs = vec![0.0; 5];
        let resp = dispatch(
            &service,
            &mut writers,
            &mut rng,
            &mut scratch,
            None,
            Request::Append { actor_id: 0, steps: vec![bad] },
        );
        assert!(matches!(resp, Response::Error { .. }));
        assert_eq!(service.table("replay").unwrap().len(), 0);
        // A well-formed batch passes.
        let resp = dispatch(
            &service,
            &mut writers,
            &mut rng,
            &mut scratch,
            Some((2, 1)),
            Request::Append { actor_id: 0, steps: vec![step_with_dims(2, 1)] },
        );
        assert!(matches!(resp, Response::Appended { consumed: 1, .. }));
        assert_eq!(service.table("replay").unwrap().len(), 1);
    }
}
