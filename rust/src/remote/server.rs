//! The remote replay server: a socket front-end over one
//! [`ReplayService`] (Reverb's `reverb.Server` shape, std-only),
//! listening on a Unix-domain socket or TCP ([`Endpoint`]) — the exact
//! same frames, sessions and reply-cache semantics on both transports.
//!
//! One accept loop, one detached thread per connection. Each
//! connection binds a server-side *session*: a sampling RNG (seeded by
//! the client's `Hello`, or from the connection id), one
//! [`TrajectoryWriter`] per actor id — so remote actors get the same
//! item assembly (N-step folding, sequence windows, boundary rules) as
//! local ones and sharded tables keep their actor-affinity routing —
//! plus the session's request-sequence state and reply cache.
//!
//! # Sessions and exactly-once requests
//!
//! A `Hello` with `session == 0` registers a fresh session and returns
//! its id; a reconnecting client quotes that id and, if the session is
//! still registered (it survives a dropped connection, with a TTL),
//! reattaches to ALL of its state: the sampling RNG stream continues,
//! per-actor `TrajectoryWriter` assembly windows reattach instead of
//! resetting, and the reply cache dedupes replayed requests. The
//! mutating RPCs carry a session-scoped sequence number: the server
//! executes each number once, caches the encoded reply, and answers a
//! replay (a request the client re-sent because the link died before
//! the ack arrived) from the cache verbatim — an append can therefore
//! never double-insert across reconnects. An unknown or expired
//! session id simply binds a fresh session (`resumed == false` in the
//! response) — the server-restart path, where clients re-send all
//! unacked work under new sequence numbers.
//!
//! # Failure semantics
//!
//! * A malformed *frame* (truncated, bit-flipped, oversized length,
//!   wrong magic) gets a best-effort [`Response::Error`] and the
//!   connection is dropped — the stream can no longer be trusted to be
//!   on a frame boundary. Nothing was applied: a request is decoded in
//!   full before any table is touched.
//! * A malformed *payload* inside a checksummed frame (bad opcode,
//!   inconsistent lengths) gets a [`Response::Error`] and the
//!   connection stays up (the frame boundary is intact).
//! * Application errors (unknown table, out-of-range indices,
//!   non-finite priorities, failed restore) get a [`Response::Error`]
//!   carrying the server-side error chain; the connection stays up.
//! * A stalled sample is a retriable [`Response::WouldStall`]; a
//!   partially admitted insert batch is a short
//!   [`Response::Appended`]. The server never blocks a connection on a
//!   rate limiter.
//!
//! # Tenant quotas and table ACLs
//!
//! [`ReplayServer::with_quotas`] turns on multi-tenant policing: every
//! session gets an insert budget (total steps it may append, spent
//! across reconnects — resuming a session resumes its remaining
//! budget) and each table caps how many sessions may hold writers on
//! it at once. Both rejections cross the wire as retriable
//! [`StallReason::QuotaExhausted`] stalls, never connection errors —
//! a tenant releasing capacity unblocks the retry. A `Hello`'s table
//! list is the connection's ACL (empty = all tables): the session's
//! writers fan out only to ACL tables, and a `Sample` or
//! `UpdatePriorities` against a table outside the list is a hard
//! [`Response::Error`] (a config bug, not a capacity condition).

use super::frame::{read_frame_into, write_frame};
use super::proto::{self, Request, Response, StallReason, TableInfo, MAX_CHUNK_LEN};
use super::transport::{Endpoint, RpcListener, RpcStream};
use crate::replay::SampleBatch;
use crate::service::{ReplayService, SampleOutcome, ServiceState, TrajectoryWriter};
use crate::util::blob::{crc32, ByteWriter};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Decrements the server's live-connection count when a connection
/// thread exits by any path (EOF, protocol error, shutdown, panic).
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Release);
    }
}

/// Most distinct actor ids one session may write for. Every other
/// hostile count in the protocol is bounded; this bounds the
/// server-side writer map (a buggy client passing a step counter as
/// its actor id would otherwise grow it without limit).
pub const MAX_WRITERS_PER_CONN: usize = 1_024;

/// Most registered sessions the server keeps; past this, the oldest
/// detached session is evicted to make room.
pub const MAX_SESSIONS: usize = 4_096;

/// How long a detached session's state survives before it may be
/// evicted (a reconnect after this binds a fresh session).
pub const SESSION_TTL: Duration = Duration::from_secs(900);

/// Encoded replies kept per session for request dedupe. Deeper than
/// any client's in-flight pipeline (the sampler keeps at most 2
/// requests outstanding, the writer 1).
pub const REPLY_CACHE_DEPTH: usize = 8;

/// The default bound on the post-stop connection drain (override with
/// [`ReplayServer::with_drain_deadline`] / `pal serve --drain-deadline`).
pub const DEFAULT_DRAIN_DEADLINE: Duration = Duration::from_secs(5);

/// Settle time between flipping the drain flag and capturing the
/// handoff state: appends admitted before the flip get this long to
/// land, so the capture includes them instead of losing acked rows.
pub const DRAIN_GRACE: Duration = Duration::from_millis(50);

/// Server-wide count of sessions holding writer slots, per table.
/// Claims are all-or-nothing across a session's table set and are
/// released when the session is dropped (TTL eviction, connection end
/// for implicit sessions) or rebinds to a different ACL.
struct WriterLedger {
    max_per_table: usize,
    counts: Mutex<HashMap<String, usize>>,
}

impl WriterLedger {
    fn new(max_per_table: usize) -> Self {
        Self { max_per_table, counts: Mutex::new(HashMap::new()) }
    }

    /// Claim one writer slot on every named table, atomically: either
    /// every table has room and every count is bumped, or nothing is.
    fn claim(&self, tables: &[String]) -> bool {
        let mut counts = self.counts.lock().expect("writer ledger poisoned");
        if tables.iter().any(|t| counts.get(t).copied().unwrap_or(0) >= self.max_per_table) {
            return false;
        }
        for t in tables {
            *counts.entry(t.clone()).or_insert(0) += 1;
        }
        true
    }

    fn release(&self, tables: &[String]) {
        let mut counts = self.counts.lock().expect("writer ledger poisoned");
        for t in tables {
            match counts.get_mut(t) {
                Some(c) if *c > 1 => *c -= 1,
                Some(_) => {
                    counts.remove(t);
                }
                None => {}
            }
        }
    }
}

/// The server's tenant policy, shared by every connection. The
/// default (`writer_budget == 0`, no ledger) polices nothing.
#[derive(Clone, Default)]
struct Quotas {
    /// Total steps each session may insert (0 = unlimited).
    writer_budget: u64,
    /// Writers-per-table cap, when one is configured.
    ledger: Option<Arc<WriterLedger>>,
}

/// One session's server-side state. Owned by the registry (detached
/// sessions keep it alive for [`SESSION_TTL`]); a connection locks it
/// per request.
struct Session {
    id: u64,
    rng: Rng,
    writers: HashMap<u64, TrajectoryWriter>,
    /// Next expected sequenced-request number (sequenced requests start
    /// at 1; `seq == 0` opts out of sequencing).
    next_seq: u64,
    /// Encoded replies of the most recent sequenced requests, for
    /// replay dedupe.
    replies: VecDeque<(u64, Vec<u8>)>,
    /// Remaining insert budget in steps (`None` = unlimited). Lives in
    /// the session, not the connection, so a resumed session resumes
    /// its spend instead of minting a fresh allowance.
    budget: Option<u64>,
    /// Table ACL bound by the latest `Hello` (`None` = all tables).
    acl: Option<Vec<String>>,
    /// Table names this session holds writer-ledger claims on.
    claims: Vec<String>,
    /// The server's writer cap, when one is configured.
    ledger: Option<Arc<WriterLedger>>,
}

impl Session {
    fn new(id: u64, seed: u64) -> Self {
        Self {
            id,
            rng: Rng::new(seed),
            writers: HashMap::new(),
            next_seq: 1,
            replies: VecDeque::new(),
            budget: None,
            acl: None,
            claims: Vec::new(),
            ledger: None,
        }
    }

    /// Arm a fresh session with the server's tenant policy (resumed
    /// sessions keep their partially spent state instead).
    fn set_quotas(&mut self, quotas: &Quotas) {
        self.budget = (quotas.writer_budget > 0).then_some(quotas.writer_budget);
        self.ledger = quotas.ledger.clone();
    }

    /// Bind (or rebind) the table ACL from a `Hello` (empty = all
    /// tables; the latest `Hello` wins). A *changed* list drops the
    /// session's writers and ledger claims — their fan-out no longer
    /// matches what the client may touch — while an identical rebind
    /// (the redial path) keeps assembly windows intact.
    fn set_acl(&mut self, tables: &[String]) {
        let acl = if tables.is_empty() { None } else { Some(tables.to_vec()) };
        if acl != self.acl {
            self.writers.clear();
            if let Some(ledger) = &self.ledger {
                ledger.release(&self.claims);
            }
            self.claims.clear();
            self.acl = acl;
        }
    }

    /// Whether the session's ACL admits `table`.
    fn allows(&self, table: &str) -> bool {
        self.acl.as_ref().map_or(true, |acl| acl.iter().any(|t| t == table))
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        if let Some(ledger) = &self.ledger {
            ledger.release(&self.claims);
        }
    }
}

struct SessionEntry {
    slot: Arc<Mutex<Session>>,
    last_seen: Instant,
}

/// Registry of resumable sessions. Ids mix a per-boot nonce with a
/// counter so a restarted server can never wrongly resume a session id
/// minted by a previous incarnation.
struct SessionRegistry {
    inner: Mutex<HashMap<u64, SessionEntry>>,
    next: AtomicU64,
}

impl SessionRegistry {
    fn new() -> Self {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default()
            .as_nanos() as u64;
        let nonce = nanos ^ ((std::process::id() as u64) << 32);
        // Odd base + even strides keeps every id odd, hence nonzero
        // (0 means "fresh" on the wire).
        Self { inner: Mutex::new(HashMap::new()), next: AtomicU64::new(nonce | 1) }
    }

    /// Bind a `Hello`: resume `requested` if it is still registered,
    /// else mint a fresh session seeded with `seed`. Returns the slot
    /// and whether prior state was resumed.
    fn hello(&self, requested: u64, seed: u64) -> (Arc<Mutex<Session>>, bool) {
        let mut map = self.inner.lock().expect("session registry poisoned");
        let now = Instant::now();
        // Evict expired detached sessions (attached slots have a second
        // Arc holder: the connection).
        map.retain(|_, e| {
            Arc::strong_count(&e.slot) > 1 || now.duration_since(e.last_seen) < SESSION_TTL
        });
        if requested != 0 {
            if let Some(e) = map.get_mut(&requested) {
                e.last_seen = now;
                return (Arc::clone(&e.slot), true);
            }
        }
        if map.len() >= MAX_SESSIONS {
            let oldest = map
                .iter()
                .filter(|(_, e)| Arc::strong_count(&e.slot) == 1)
                .min_by_key(|(_, e)| e.last_seen)
                .map(|(&id, _)| id);
            if let Some(id) = oldest {
                map.remove(&id);
            }
        }
        let id = self.next.fetch_add(2, Ordering::Relaxed);
        let slot = Arc::new(Mutex::new(Session::new(id, seed)));
        map.insert(id, SessionEntry { slot: Arc::clone(&slot), last_seen: now });
        (slot, false)
    }

    /// Record detach time so the TTL measures time since last use.
    fn touch(&self, id: u64) {
        if let Some(e) = self.inner.lock().expect("session registry poisoned").get_mut(&id) {
            e.last_seen = Instant::now();
        }
    }
}

/// A bound replay server. [`Self::serve`] runs the accept loop until a
/// client sends `Shutdown` (or [`Self::stop_handle`] is flipped).
pub struct ReplayServer {
    service: Arc<ReplayService>,
    listener: RpcListener,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    seed: u64,
    /// Expected base step dims (obs, action), when known: `Append`
    /// steps are rejected with a descriptive error on mismatch instead
    /// of silently truncating/padding rows in storage.
    dims: Option<(usize, usize)>,
    sessions: Arc<SessionRegistry>,
    drain_deadline: Duration,
    quotas: Quotas,
    /// Set by a `Drain` RPC: new sessions refused, appends stalled,
    /// `Mass` advertises zero so mesh samplers renormalize away.
    draining: Arc<AtomicBool>,
    /// Default handoff targets for a `Drain` that names none (`pal
    /// serve --drain-to`).
    drain_peers: Vec<Endpoint>,
}

impl ReplayServer {
    /// Bind a Unix-domain socket at `path`. A stale socket file left by
    /// a dead server is replaced; a socket another server still answers
    /// on, or any other kind of file, is refused. `seed` derives the
    /// default per-connection sampling RNGs.
    pub fn bind(service: Arc<ReplayService>, path: impl AsRef<Path>, seed: u64) -> Result<Self> {
        Self::bind_endpoint(service, &Endpoint::from(path.as_ref()), seed)
    }

    /// Bind either transport: a UDS path (with the stale-socket probe —
    /// a live server's socket is never stolen) or `tcp://HOST:PORT`
    /// (`:0` binds an ephemeral port; [`Self::endpoint`] reports where
    /// it landed). The served protocol is identical on both.
    pub fn bind_endpoint(
        service: Arc<ReplayService>,
        endpoint: &Endpoint,
        seed: u64,
    ) -> Result<Self> {
        let listener = RpcListener::bind(endpoint)
            .with_context(|| format!("binding replay server endpoint {endpoint}"))?;
        Ok(Self {
            service,
            listener,
            stop: Arc::new(AtomicBool::new(false)),
            active: Arc::new(AtomicUsize::new(0)),
            seed,
            dims: None,
            sessions: Arc::new(SessionRegistry::new()),
            drain_deadline: DEFAULT_DRAIN_DEADLINE,
            quotas: Quotas::default(),
            draining: Arc::new(AtomicBool::new(false)),
            drain_peers: Vec::new(),
        })
    }

    /// Turn on tenant quotas (`pal serve --writer-budget` /
    /// `--max-writers-per-table`; 0 = unlimited for either): every
    /// session may insert at most `writer_budget` steps total, and at
    /// most `max_writers_per_table` sessions may hold writers on any
    /// one table at once. Exhaustion is answered with a retriable
    /// [`StallReason::QuotaExhausted`], never a dropped connection.
    pub fn with_quotas(mut self, writer_budget: u64, max_writers_per_table: usize) -> Self {
        self.quotas = Quotas {
            writer_budget,
            ledger: (max_writers_per_table > 0)
                .then(|| Arc::new(WriterLedger::new(max_writers_per_table))),
        };
        self
    }

    /// Bound the post-stop wait for open connections to drain (`pal
    /// serve --drain-deadline`).
    pub fn with_drain_deadline(mut self, deadline: Duration) -> Self {
        self.drain_deadline = deadline;
        self
    }

    /// Default handoff targets for a `Drain` RPC that names no peers
    /// (`pal serve --drain-to`): the first reachable one receives this
    /// server's tables when it is told to leave the mesh.
    pub fn with_drain_peers(mut self, peers: Vec<Endpoint>) -> Self {
        self.drain_peers = peers;
        self
    }

    /// The drain-mode flag (tests and the serve CLI observe it; a
    /// `Drain` RPC sets it).
    pub fn draining_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.draining)
    }

    /// Enforce base step dims on every `Append` (what `pal serve`'s
    /// `--obs-dim`/`--act-dim` declare): mismatched clients get a
    /// descriptive error on their first frame instead of silently
    /// corrupted rows.
    pub fn expect_dims(mut self, obs_dim: usize, act_dim: usize) -> Self {
        self.dims = Some((obs_dim, act_dim));
        self
    }

    /// Flag that ends the accept loop (also set by a `Shutdown` RPC).
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// The endpoint being served (for TCP, the resolved bound address —
    /// what clients should dial after an ephemeral `:0` bind).
    pub fn endpoint(&self) -> Endpoint {
        self.listener.endpoint()
    }

    /// The UDS socket path (UDS-bound servers only).
    ///
    /// # Panics
    /// If the server is bound to TCP — use [`Self::endpoint`] there.
    pub fn socket_path(&self) -> &Path {
        match &self.listener {
            RpcListener::Unix { path, .. } => path,
            RpcListener::Tcp { addr, .. } => {
                panic!("socket_path() on a TCP-bound server (tcp://{addr})")
            }
        }
    }

    /// Accept loop. Returns after `Shutdown` (or an external stop);
    /// connection threads are detached and exit when their client hangs
    /// up. On the way out the loop drains in-flight connections
    /// (bounded wait) so a post-`serve` state capture cannot race a
    /// request the server already acknowledged, then removes the
    /// socket file.
    pub fn serve(&self) -> Result<()> {
        let shared = Arc::new(ConnShared {
            service: Arc::clone(&self.service),
            stop: Arc::clone(&self.stop),
            dims: self.dims,
            sessions: Arc::clone(&self.sessions),
            quotas: self.quotas.clone(),
            drain: DrainCtl {
                flag: Arc::clone(&self.draining),
                peers: self.drain_peers.clone(),
            },
        });
        let mut conn_id = 0u64;
        while !self.stop.load(Ordering::Relaxed) {
            match self.listener.accept() {
                Ok(stream) => {
                    conn_id += 1;
                    let shared = Arc::clone(&shared);
                    let guard = ConnGuard(Arc::clone(&self.active));
                    self.active.fetch_add(1, Ordering::Acquire);
                    let seed = self
                        .seed
                        .wrapping_add(conn_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    std::thread::spawn(move || {
                        let _guard = guard;
                        handle_connection(shared, stream, seed);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("accepting on replay server endpoint {}", self.listener.endpoint())
                    });
                }
            }
        }
        // Drain: clients that quiesced before Shutdown disconnect
        // promptly; an idle client parked in a blocking read cannot be
        // joined, so the wait is bounded and reported.
        let deadline = Instant::now() + self.drain_deadline;
        while self.active.load(Ordering::Acquire) > 0 {
            if Instant::now() >= deadline {
                eprintln!(
                    "[pal] WARNING: {} connection(s) still open at shutdown; \
                     a concurrent state capture may miss their in-flight requests",
                    self.active.load(Ordering::Acquire)
                );
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        self.listener.cleanup();
        Ok(())
    }
}

/// Everything a connection thread shares with its server: the service,
/// the stop flag, the dim contract, the session registry, the tenant
/// policy and the live drain-mode control. One `Arc` per server,
/// cloned per connection.
struct ConnShared {
    service: Arc<ReplayService>,
    stop: Arc<AtomicBool>,
    dims: Option<(usize, usize)>,
    sessions: Arc<SessionRegistry>,
    quotas: Quotas,
    drain: DrainCtl,
}

/// Live drain-mode control: the flag flips the serving policy (new
/// sessions refused, appends stalled, zero advertised mass), `peers`
/// are the handoff targets configured at startup.
struct DrainCtl {
    flag: Arc<AtomicBool>,
    peers: Vec<Endpoint>,
}

/// Per-connection loop: read frame → decode → dispatch → respond. One
/// read buffer and one response encoder per connection, reused for
/// every frame, so framing and response encoding allocate nothing per
/// RPC (request *decoding* still materializes owned payloads — an
/// `Append`'s steps become storage rows).
fn handle_connection(shared: Arc<ConnShared>, mut stream: RpcStream, seed: u64) {
    // Accepted sockets may inherit the listener's non-blocking mode;
    // connection I/O is plain blocking reads.
    let _ = stream.set_nonblocking(false);
    let service = &shared.service;
    // Until (unless) the client says Hello, the connection runs on an
    // implicit session: same state shape (including quotas), but
    // unregistered — it dies with the connection, exactly the
    // pre-session behavior.
    let mut session: Arc<Mutex<Session>> = {
        let mut s = Session::new(0, seed);
        s.set_quotas(&shared.quotas);
        Arc::new(Mutex::new(s))
    };
    let mut registered = 0u64;
    // In-progress chunked Restore upload, if any. Connection-local on
    // purpose: a dropped link aborts the upload (nothing was applied —
    // the client redials and restarts the stream from ChunkBegin), so
    // no half-assembled state can ever outlive its connection.
    let mut upload: Option<ChunkUpload> = None;
    let mut scratch = SampleBatch::default();
    let mut rbuf: Vec<u8> = Vec::new();
    let mut enc = ByteWriter::new();
    loop {
        match read_frame_into(&mut stream, &mut rbuf) {
            Ok(true) => {}
            // Client hung up between frames.
            Ok(false) => break,
            Err(e) => {
                // The stream may be mid-frame; answer and drop it.
                let resp = Response::Error { message: format!("protocol error: {e}") };
                let _ = write_frame(&mut stream, &resp.encode());
                break;
            }
        }
        enc.reset();
        let mut shutdown = false;
        match Request::decode(&rbuf) {
            // Frame boundaries are intact (the frame checksum passed);
            // a bad payload is answerable without closing.
            Err(e) => {
                Response::Error { message: format!("bad request: {e}") }.encode_into(&mut enc)
            }
            Ok(Request::Shutdown) => {
                Response::Ok.encode_into(&mut enc);
                shutdown = true;
            }
            // Stateless liveness probe: no session, no table reads, and
            // answered even while draining — it is how the membership
            // layer tells a draining or restarting server from a dead
            // one.
            Ok(Request::Ping { nonce }) => Response::Pong { nonce }.encode_into(&mut enc),
            Ok(Request::Drain { max_chunk, peers }) => {
                match handle_drain(service, &shared.drain, max_chunk, &peers) {
                    Ok(moved) => {
                        eprintln!("[pal] drain: handed {moved} items to a peer; stopping");
                        Response::Ok.encode_into(&mut enc);
                        // The handoff landed: stop serving, like a
                        // Shutdown (the tables now live on the peer).
                        shutdown = true;
                    }
                    Err(e) => Response::Error { message: format!("drain failed: {e:#}") }
                        .encode_into(&mut enc),
                }
            }
            Ok(Request::Hello { rng_seed, session: requested, tables }) => {
                if shared.drain.flag.load(Ordering::SeqCst) {
                    // A draining server binds no new sessions — the
                    // redialing client moves on to a live peer.
                    Response::Error { message: "server is draining".to_string() }
                        .encode_into(&mut enc);
                } else if let Some(bad) = tables.iter().find(|t| service.table(t).is_none()) {
                    // Validate the ACL against the served tables BEFORE
                    // binding anything: an unknown name is a config
                    // error answered on the current session, not a
                    // quota.
                    Response::Error { message: format!("unknown table `{bad}` in hello ACL") }
                        .encode_into(&mut enc);
                } else {
                    let (slot, resumed) = shared.sessions.hello(requested, rng_seed);
                    let (id, next_seq) = {
                        let mut s = slot.lock().expect("session poisoned");
                        if !resumed {
                            s.set_quotas(&shared.quotas);
                        }
                        // The latest Hello wins (a redial re-sends the
                        // same list and reattaches cleanly).
                        s.set_acl(&tables);
                        (s.id, s.next_seq)
                    };
                    session = slot;
                    registered = id;
                    Response::Hello {
                        default_table: service.default_table().name().to_string(),
                        session: id,
                        resumed,
                        next_seq,
                    }
                    .encode_into(&mut enc);
                }
            }
            // The one RPC answered by MORE than one frame: the chunked
            // checkpoint download streams ChunkBegin + chunks + ChunkEnd
            // back-to-back, then the loop resumes normal request/reply.
            Ok(Request::CheckpointChunked { max_chunk }) => {
                if stream_checkpoint(service, &mut stream, &mut enc, max_chunk as usize).is_err()
                {
                    break;
                }
                continue;
            }
            // The chunked upload: connection-local staging with strict
            // sequencing and per-chunk CRCs; nothing touches the tables
            // until the closing frame (`ChunkEnd` = replace, a peer's
            // `HandoffEnd` = merge) verifies the whole payload.
            Ok(
                req @ (Request::ChunkBegin { .. }
                | Request::Chunk { .. }
                | Request::ChunkEnd { .. }
                | Request::HandoffEnd { .. }),
            ) => {
                if shared.drain.flag.load(Ordering::SeqCst) {
                    // A draining server must not absorb state it is
                    // about to hand off itself.
                    Response::Error { message: "server is draining".to_string() }
                        .encode_into(&mut enc);
                } else {
                    handle_chunk_upload(service, &mut upload, req).encode_into(&mut enc);
                }
            }
            Ok(req) => {
                let draining = shared.drain.flag.load(Ordering::SeqCst);
                let mut s = session.lock().expect("session poisoned");
                dispatch_into(service, &mut s, &mut scratch, shared.dims, draining, req, &mut enc)
            }
        }
        if shutdown {
            // Set the stop flag BEFORE attempting the Ok response: a
            // client that hangs up right after sending Shutdown must
            // still stop the server (the reply is best-effort).
            shared.stop.store(true, Ordering::Relaxed);
            let _ = write_frame(&mut stream, enc.as_slice());
            break;
        }
        if write_frame(&mut stream, enc.as_slice()).is_err() {
            break;
        }
    }
    if registered != 0 {
        // Stamp detach time so the session TTL measures idleness, not
        // age.
        shared.sessions.touch(registered);
    }
}

/// Execute a `Drain` RPC: flip the server into drain mode, hand the
/// tables to the first reachable peer, and report how many items
/// moved. A failed handoff (no peers, every peer unreachable or
/// refusing) clears the flag again — the server resumes normal service
/// and the operator retries with better targets.
fn handle_drain(
    service: &Arc<ReplayService>,
    drain: &DrainCtl,
    max_chunk: u32,
    requested: &[String],
) -> Result<usize> {
    if drain.flag.swap(true, Ordering::SeqCst) {
        bail!("server is already draining");
    }
    let result = run_drain(service, &drain.peers, max_chunk, requested);
    if result.is_err() {
        drain.flag.store(false, Ordering::SeqCst);
    }
    result
}

fn run_drain(
    service: &Arc<ReplayService>,
    configured: &[Endpoint],
    max_chunk: u32,
    requested: &[String],
) -> Result<usize> {
    // Peers named in the request win over the configured defaults.
    let peers: Vec<Endpoint> = if requested.is_empty() {
        configured.to_vec()
    } else {
        requested
            .iter()
            .map(|s| Endpoint::parse(s))
            .collect::<Result<_>>()
            .context("parsing drain peers")?
    };
    if peers.is_empty() {
        bail!("no drain peers (configure `pal serve --drain-to` or name them in the request)");
    }
    // Appends are already stalling on the drain flag; the grace period
    // lets in-flight ones that were admitted before the flip land so
    // the capture includes them.
    std::thread::sleep(DRAIN_GRACE);
    let state = service.checkpoint().context("capturing state for the drain handoff")?;
    let moved = state.total_len();
    let bytes = state.encode();
    let chunk = if max_chunk == 0 {
        proto::DEFAULT_CHUNK_LEN
    } else {
        (max_chunk as usize).min(MAX_CHUNK_LEN)
    };
    let mut failures = Vec::new();
    for peer in &peers {
        let attempt = super::client::RemoteClient::connect_endpoint(peer)
            .and_then(|mut c| c.handoff_state_bytes(&bytes, chunk));
        match attempt {
            Ok(()) => return Ok(moved),
            Err(e) => failures.push(format!("{peer}: {e:#}")),
        }
    }
    bail!("every drain peer refused the handoff: [{}]", failures.join("; "));
}

/// Stream the service checkpoint as `ChunkBegin` + N×`Chunk` +
/// `ChunkEnd` frames (the reply to [`Request::CheckpointChunked`]).
/// Application-level failures become one `Error` frame; the `Err`
/// return is transport-only (connection must drop).
fn stream_checkpoint(
    service: &Arc<ReplayService>,
    stream: &mut RpcStream,
    enc: &mut ByteWriter,
    max_chunk: usize,
) -> std::io::Result<()> {
    let mut error = |enc: &mut ByteWriter, stream: &mut RpcStream, message: String| {
        enc.reset();
        Response::Error { message }.encode_into(enc);
        write_frame(stream, enc.as_slice())
    };
    let state = match service.checkpoint() {
        Ok(s) => s.encode(),
        Err(e) => return error(enc, stream, format!("checkpoint failed: {e}")),
    };
    let chunk_len = max_chunk.clamp(1, MAX_CHUNK_LEN);
    let total_len = state.len() as u64;
    if total_len == 0 || total_len > proto::MAX_CHUNKED_STATE {
        return error(
            enc,
            stream,
            format!("checkpoint is {total_len} bytes — outside the chunked-transfer bounds"),
        );
    }
    let chunk_count = total_len.div_ceil(chunk_len as u64) as u32;
    enc.reset();
    Response::ChunkBegin { total_len, chunk_len: chunk_len as u32, chunk_count }.encode_into(enc);
    write_frame(stream, enc.as_slice())?;
    for (seq, piece) in state.chunks(chunk_len).enumerate() {
        enc.reset();
        proto::encode_chunk(enc, seq as u32, piece);
        write_frame(stream, enc.as_slice())?;
    }
    enc.reset();
    Response::ChunkEnd { total_crc: crc32(&state) }.encode_into(enc);
    write_frame(stream, enc.as_slice())
}

/// Connection-local staging state of one chunked `Restore` upload.
struct ChunkUpload {
    total_len: u64,
    chunk_len: u32,
    chunk_count: u32,
    next_seq: u32,
    data: Vec<u8>,
}

/// One step of the chunked-upload state machine. Any violation —
/// out-of-order sequence, wrong chunk size, CRC mismatch, a close
/// before every chunk arrived, a failed final validation — aborts the
/// whole upload (staging discarded, tables untouched) with a
/// descriptive error; the client must restart from `ChunkBegin`.
fn handle_chunk_upload(
    service: &Arc<ReplayService>,
    upload: &mut Option<ChunkUpload>,
    req: Request,
) -> Response {
    let what = if matches!(req, Request::HandoffEnd { .. }) { "handoff" } else { "restore" };
    let result = match req {
        Request::ChunkBegin { total_len, chunk_len, chunk_count } => {
            // Header consistency was enforced at decode. An unfinished
            // upload is superseded (its staging dropped) — the client
            // gave up on it and started over.
            *upload = Some(ChunkUpload {
                total_len,
                chunk_len,
                chunk_count,
                next_seq: 0,
                // Grown chunk-by-chunk, NOT reserved up front: a hostile
                // header may declare up to MAX_CHUNKED_STATE bytes, but
                // memory is only committed for bytes actually sent.
                data: Vec::new(),
            });
            Ok(())
        }
        Request::Chunk { seq, crc, data } => stage_chunk(upload, seq, crc, &data),
        Request::ChunkEnd { total_crc } => finish_chunked_restore(service, upload, total_crc),
        Request::HandoffEnd { total_crc } => finish_chunked_merge(service, upload, total_crc),
        _ => unreachable!("non-chunk request routed to the chunk-upload handler"),
    };
    match result {
        Ok(()) => Response::Ok,
        Err(e) => {
            *upload = None;
            Response::Error { message: format!("chunked {what} failed: {e:#}") }
        }
    }
}

fn stage_chunk(upload: &mut Option<ChunkUpload>, seq: u32, crc: u32, data: &[u8]) -> Result<()> {
    let Some(up) = upload.as_mut() else {
        bail!("chunk {seq} outside a chunked upload (no ChunkBegin)");
    };
    if seq != up.next_seq {
        bail!("chunk seq {seq} out of order: upload expects {}", up.next_seq);
    }
    // Every chunk's size is fully determined by the declared header, so
    // a truncated, padded or oversized chunk is caught the moment it
    // arrives — including an oversized SINGLE chunk that would have fit
    // the declared total.
    let expected = if seq + 1 == up.chunk_count {
        up.total_len - (up.chunk_count as u64 - 1) * up.chunk_len as u64
    } else {
        up.chunk_len as u64
    };
    if data.len() as u64 != expected {
        bail!("chunk {seq} is {} bytes, upload declared {expected}", data.len());
    }
    if crc32(data) != crc {
        bail!("chunk {seq} CRC mismatch (payload corrupted in flight)");
    }
    up.data.extend_from_slice(data);
    up.next_seq += 1;
    Ok(())
}

/// Close out a staged upload: every chunk arrived, whole-payload CRC
/// verified. Shared by both closing frames (`ChunkEnd` and
/// `HandoffEnd`).
fn take_finished_upload(
    upload: &mut Option<ChunkUpload>,
    total_crc: u32,
    closer: &str,
) -> Result<Vec<u8>> {
    let Some(up) = upload.take() else {
        bail!("{closer} outside a chunked upload (no ChunkBegin)");
    };
    if up.next_seq != up.chunk_count {
        bail!("upload closed after {} of {} chunks", up.next_seq, up.chunk_count);
    }
    if crc32(&up.data) != total_crc {
        bail!("reassembled state CRC mismatch");
    }
    Ok(up.data)
}

fn finish_chunked_restore(
    service: &Arc<ReplayService>,
    upload: &mut Option<ChunkUpload>,
    total_crc: u32,
) -> Result<()> {
    let data = take_finished_upload(upload, total_crc, "ChunkEnd")?;
    // Same two-phase validate-then-apply as the plain Restore RPC: a
    // payload that decodes but does not fit the served tables leaves
    // them untouched.
    let state = ServiceState::decode(&data).context("decoding reassembled state")?;
    service.restore(&state)
}

/// `HandoffEnd` closes the same upload stream as `ChunkEnd`, but the
/// payload is MERGED into the live tables — every donor row inserted
/// at its checkpointed priority on top of what is already here —
/// instead of replacing them: the receiving half of a peer's drain.
fn finish_chunked_merge(
    service: &Arc<ReplayService>,
    upload: &mut Option<ChunkUpload>,
    total_crc: u32,
) -> Result<()> {
    let data = take_finished_upload(upload, total_crc, "HandoffEnd")?;
    let state = ServiceState::decode(&data).context("decoding handoff state")?;
    let absorbed = service.merge_state(&state)?;
    eprintln!("[pal] handoff: absorbed {absorbed} items from a draining peer");
    Ok(())
}

/// Apply one decoded request against the service, encoding the
/// response into `enc`. Infallible by construction: every failure is
/// an encoded [`Response::Error`], so a hostile request can never take
/// the connection thread down. The `Sampled` hot path encodes the
/// scratch batch directly (no clone, no `Response` value).
///
/// Sequenced requests (`seq > 0`) pass the session's exactly-once
/// gate first: in-order requests execute and their encoded reply is
/// cached; a replayed number answers from the cache verbatim (no
/// re-execution); a number older than the cache window or ahead of the
/// expected one is a descriptive error.
fn dispatch_into(
    service: &Arc<ReplayService>,
    session: &mut Session,
    scratch: &mut SampleBatch,
    dims: Option<(usize, usize)>,
    draining: bool,
    req: Request,
    enc: &mut ByteWriter,
) {
    let seq = match &req {
        Request::Append { seq, .. }
        | Request::Sample { seq, .. }
        | Request::UpdatePriorities { seq, .. }
            if *seq > 0 =>
        {
            Some(*seq)
        }
        _ => None,
    };
    if let Some(seq) = seq {
        if seq < session.next_seq {
            if let Some((_, bytes)) = session.replies.iter().find(|(s, _)| *s == seq) {
                enc.raw(bytes);
            } else {
                Response::Error {
                    message: format!(
                        "stale request seq {seq}: session expects {} and the reply \
                         cache no longer holds it",
                        session.next_seq
                    ),
                }
                .encode_into(enc);
            }
            return;
        }
        if seq > session.next_seq {
            Response::Error {
                message: format!(
                    "request seq gap: got {seq}, session expects {} (requests lost \
                     or reordered)",
                    session.next_seq
                ),
            }
            .encode_into(enc);
            return;
        }
    }
    if let Request::Sample { table, batch, .. } = &req {
        if !session.allows(table) {
            Response::Error {
                message: format!("table `{table}` is outside this connection's ACL"),
            }
            .encode_into(enc);
            // Still a sequenced, cacheable reply (falls through below).
        } else {
            match service.sampler(table) {
                None => Response::Error { message: format!("unknown table `{table}`") }
                    .encode_into(enc),
                Some(sampler) => {
                    match sampler.try_sample(*batch as usize, &mut session.rng, scratch) {
                        SampleOutcome::Sampled => proto::encode_sampled(enc, scratch),
                        SampleOutcome::Throttled => {
                            Response::WouldStall { reason: StallReason::Throttled }
                                .encode_into(enc)
                        }
                        SampleOutcome::NotEnoughData => {
                            Response::WouldStall { reason: StallReason::NotEnoughData }
                                .encode_into(enc)
                        }
                    }
                }
            }
        }
    } else {
        dispatch_cold(service, session, dims, draining, req).encode_into(enc);
    }
    if let Some(seq) = seq {
        session.next_seq = seq + 1;
        session.replies.push_back((seq, enc.as_slice().to_vec()));
        while session.replies.len() > REPLY_CACHE_DEPTH {
            session.replies.pop_front();
        }
    }
}

/// The non-`Sample` requests, as plain response values (their payloads
/// are either tiny or intrinsically owned, so value construction costs
/// nothing that matters).
fn dispatch_cold(
    service: &Arc<ReplayService>,
    session: &mut Session,
    dims: Option<(usize, usize)>,
    draining: bool,
    req: Request,
) -> Response {
    match req {
        // Session binding happens in the connection loop (it swaps the
        // session slot itself); reaching here means a decoder bug.
        Request::Hello { .. } => Response::Error {
            message: "internal: Hello reached the dispatch path".to_string(),
        },
        Request::Append { actor_id, seq: _, dropped, steps } => {
            // A client reporting spill-queue drops folds the delta into
            // server-side stats even when the limiter admits nothing:
            // the reply (cached under this request's seq) is the ack, so
            // the count is applied exactly once.
            if dropped > 0 {
                for t in service.tables() {
                    t.add_steps_dropped(dropped as usize);
                }
            }
            // Validate the WHOLE batch before applying any of it, so a
            // malformed batch never half-applies. Without declared dims
            // only self-consistency is checkable; with them a
            // mismatched client fails on its first frame instead of
            // silently truncating/padding rows in storage.
            for (i, s) in steps.iter().enumerate() {
                let self_consistent =
                    !s.obs.is_empty() && !s.action.is_empty() && s.obs.len() == s.next_obs.len();
                let dims_ok = dims
                    .map_or(true, |(od, ad)| s.obs.len() == od && s.action.len() == ad);
                if !self_consistent || !dims_ok {
                    let expected = match dims {
                        Some((od, ad)) => format!("obs_dim {od}, act_dim {ad}"),
                        None => "non-empty obs/action with obs_dim == next_obs dim".to_string(),
                    };
                    return Response::Error {
                        message: format!(
                            "append step {i} has dims obs={}/next_obs={}/action={}, server \
                             expects {expected}",
                            s.obs.len(),
                            s.next_obs.len(),
                            s.action.len(),
                        ),
                    };
                }
            }
            // A draining server admits no new experience: a retriable
            // stall (the reply still acks the dropped delta exactly
            // once), so a writer that has not failed over yet is
            // stalled, not errored — its next transport failure or
            // probe re-routes it to a live peer.
            if draining && !steps.is_empty() {
                return Response::WouldStall { reason: StallReason::QuotaExhausted };
            }
            // A spent insert budget is a retriable quota stall, not an
            // error: the reply is cached under this seq, so a replay
            // after reconnect sees the same verdict.
            let budget_left = session.budget.unwrap_or(u64::MAX);
            if budget_left == 0 && !steps.is_empty() {
                return Response::WouldStall { reason: StallReason::QuotaExhausted };
            }
            if !session.writers.contains_key(&actor_id) {
                if session.writers.len() >= MAX_WRITERS_PER_CONN {
                    return Response::Error {
                        message: format!(
                            "session already writes for {MAX_WRITERS_PER_CONN} distinct \
                             actor ids — actor id {actor_id} rejected (buggy id generation?)"
                        ),
                    };
                }
                // First writer of the session: claim one writer slot on
                // each table the session may write to (all-or-nothing).
                // A full table is a retriable stall — another tenant
                // detaching frees the slot.
                if session.claims.is_empty() {
                    if let Some(ledger) = session.ledger.clone() {
                        let targets: Vec<String> = match &session.acl {
                            Some(acl) => acl.clone(),
                            None => {
                                service.tables().iter().map(|t| t.name().to_string()).collect()
                            }
                        };
                        if !ledger.claim(&targets) {
                            return Response::WouldStall {
                                reason: StallReason::QuotaExhausted,
                            };
                        }
                        session.claims = targets;
                    }
                }
                let writer = service.writer_for(actor_id as usize, session.acl.as_deref());
                session.writers.insert(actor_id, writer);
            }
            let writer = session.writers.get_mut(&actor_id).expect("writer just ensured");
            let mut consumed = 0u32;
            let mut emitted = 0u32;
            for step in steps {
                // Stop at the first limiter stall or the last budgeted
                // step; the client retries the tail. An admitted step is
                // fully fanned out, so an insert is never half-applied.
                if consumed as u64 >= budget_left || writer.throttled() {
                    break;
                }
                emitted += writer.append(step) as u32;
                consumed += 1;
            }
            if let Some(budget) = session.budget.as_mut() {
                *budget -= consumed as u64;
            }
            Response::Appended { consumed, emitted }
        }
        // Handled by the hot path in `dispatch_into`.
        Request::Sample { .. } => unreachable!("Sample is dispatched before the cold path"),
        Request::UpdatePriorities { table, indices, td_abs, seq: _ } => match service.table(&table)
        {
            _ if !session.allows(&table) => Response::Error {
                message: format!("table `{table}` is outside this connection's ACL"),
            },
            None => Response::Error { message: format!("unknown table `{table}`") },
            Some(t) => {
                let cap = t.capacity() as u64;
                if let Some(bad) = indices.iter().find(|&&i| i >= cap) {
                    return Response::Error {
                        message: format!(
                            "priority index {bad} out of range for table `{table}` \
                             (capacity {cap})"
                        ),
                    };
                }
                // Defense in depth: decode already rejects these, but an
                // in-process caller could hand-build the request.
                if let Some(bad) = td_abs.iter().find(|v| !v.is_finite() || **v < 0.0) {
                    return Response::Error {
                        message: format!("invalid priority value {bad} rejected"),
                    };
                }
                let idx: Vec<usize> = indices.iter().map(|&i| i as usize).collect();
                t.update_priorities(&idx, &td_abs);
                Response::Ok
            }
        },
        Request::Stats => Response::Stats {
            tables: service
                .tables()
                .iter()
                .map(|t| TableInfo {
                    name: t.name().to_string(),
                    len: t.len() as u64,
                    capacity: t.capacity() as u64,
                    stats: t.stats_snapshot(),
                })
                .collect(),
        },
        Request::Checkpoint => match service.checkpoint() {
            Ok(state) => {
                let state = state.encode();
                // A state payload the framing layer cannot carry must be
                // a clear error frame, not a dropped connection.
                if state.len() + 64 > super::frame::MAX_FRAME_LEN {
                    Response::Error {
                        message: format!(
                            "checkpoint is {} bytes, larger than the {}-byte frame cap — \
                             checkpoint the serving process directly (`pal serve --save-state`)",
                            state.len(),
                            super::frame::MAX_FRAME_LEN
                        ),
                    }
                } else {
                    Response::State { state }
                }
            }
            Err(e) => Response::Error { message: format!("checkpoint failed: {e}") },
        },
        Request::Restore { state } => {
            match ServiceState::decode(&state).and_then(|s| service.restore(&s)) {
                Ok(()) => Response::Ok,
                Err(e) => Response::Error { message: format!("restore failed: {e}") },
            }
        }
        Request::Mass { table } => match service.table(&table) {
            None => Response::Error { message: format!("unknown table `{table}`") },
            // A draining server advertises zero mass so mesh samplers
            // renormalize their level-1 draw over the remaining peers.
            Some(_) if draining => Response::Mass { len: 0, mass: 0.0 },
            Some(t) => Response::Mass { len: t.len() as u64, mass: t.total_priority() },
        },
        // Handled (and answered) by the connection loop before dispatch;
        // mirrored here so an in-process caller sees the same behavior.
        Request::Shutdown => Response::Ok,
        Request::Ping { nonce } => Response::Pong { nonce },
        Request::Drain { .. } => Response::Error {
            message: "internal: Drain reached the dispatch path".to_string(),
        },
        Request::CheckpointChunked { .. }
        | Request::ChunkBegin { .. }
        | Request::Chunk { .. }
        | Request::ChunkEnd { .. }
        | Request::HandoffEnd { .. } => Response::Error {
            message: "internal: chunked-transfer request reached the dispatch path".to_string(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::UniformReplay;
    use crate::service::{ItemKind, RateLimiter, Table};

    /// Round one request through the encoding dispatch path back to a
    /// decoded `Response` (what tests assert on).
    fn dispatch(
        service: &Arc<ReplayService>,
        session: &mut Session,
        scratch: &mut SampleBatch,
        dims: Option<(usize, usize)>,
        req: Request,
    ) -> Response {
        let mut enc = ByteWriter::new();
        dispatch_into(service, session, scratch, dims, false, req, &mut enc);
        Response::decode(enc.as_slice()).expect("dispatch must encode a decodable response")
    }

    /// Like `dispatch`, with the server in drain mode.
    fn dispatch_draining(
        service: &Arc<ReplayService>,
        session: &mut Session,
        scratch: &mut SampleBatch,
        req: Request,
    ) -> Response {
        let mut enc = ByteWriter::new();
        dispatch_into(service, session, scratch, None, true, req, &mut enc);
        Response::decode(enc.as_slice()).expect("dispatch must encode a decodable response")
    }

    fn tiny_service() -> Arc<ReplayService> {
        Arc::new(
            ReplayService::new(vec![Table::new(
                "replay",
                ItemKind::OneStep,
                Arc::new(UniformReplay::new(32, 2, 1)),
                RateLimiter::Unlimited { min_size_to_sample: 1 },
            )])
            .unwrap(),
        )
    }

    #[test]
    fn bind_refuses_non_socket_files_and_replaces_stale_sockets() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("pal_srv_bind_{}.sock", std::process::id()));
        std::fs::write(&path, b"not a socket").unwrap();
        assert!(ReplayServer::bind(tiny_service(), &path, 0).is_err());
        std::fs::remove_file(&path).unwrap();

        // A stale socket (no listener behind it) is replaced.
        {
            let first = ReplayServer::bind(tiny_service(), &path, 0).unwrap();
            drop(first); // listener gone, socket file left behind
        }
        assert!(path.exists(), "dropping the server leaves the socket file");
        let second = ReplayServer::bind(tiny_service(), &path, 0).unwrap();
        assert_eq!(second.socket_path(), path.as_path());
        drop(second);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dispatch_rejects_hostile_priority_updates() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        // Out-of-range index.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::UpdatePriorities {
                table: "replay".into(),
                indices: vec![1 << 50],
                td_abs: vec![1.0],
                seq: 0,
            },
        );
        match resp {
            Response::Error { message } => assert!(message.contains("out of range"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        // Non-finite priority.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::UpdatePriorities {
                table: "replay".into(),
                indices: vec![0],
                td_abs: vec![f32::NAN],
                seq: 0,
            },
        );
        assert!(matches!(resp, Response::Error { .. }));
        // Unknown table.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Sample { table: "nope".into(), batch: 4, seq: 0 },
        );
        assert!(matches!(resp, Response::Error { .. }));
    }

    fn step_with_dims(obs: usize, act: usize) -> crate::service::WriterStep {
        crate::service::WriterStep {
            obs: vec![0.5; obs],
            action: vec![0.1; act],
            next_obs: vec![0.6; obs],
            reward: 1.0,
            done: false,
            truncated: false,
        }
    }

    #[test]
    fn dispatch_rejects_mismatched_step_dims_atomically() {
        let service = tiny_service(); // tables are obs_dim 2, act_dim 1
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        // Declared dims: a wrong-width step is rejected and NOTHING of
        // the batch (even its valid steps) is applied.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            Some((2, 1)),
            Request::Append {
                actor_id: 0,
                seq: 0,
                dropped: 0,
                steps: vec![step_with_dims(2, 1), step_with_dims(8, 1)],
            },
        );
        match resp {
            Response::Error { message } => assert!(message.contains("expects"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        assert_eq!(service.table("replay").unwrap().len(), 0);
        // Without declared dims, self-inconsistent steps still fail.
        let mut bad = step_with_dims(2, 1);
        bad.next_obs = vec![0.0; 5];
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Append { actor_id: 0, seq: 0, dropped: 0, steps: vec![bad] },
        );
        assert!(matches!(resp, Response::Error { .. }));
        assert_eq!(service.table("replay").unwrap().len(), 0);
        // A well-formed batch passes.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            Some((2, 1)),
            Request::Append {
                actor_id: 0,
                seq: 0,
                dropped: 0,
                steps: vec![step_with_dims(2, 1)],
            },
        );
        assert!(matches!(resp, Response::Appended { consumed: 1, .. }));
        assert_eq!(service.table("replay").unwrap().len(), 1);
    }

    fn append_req(seq: u64, n: usize) -> Request {
        Request::Append {
            actor_id: 0,
            seq,
            dropped: 0,
            steps: (0..n).map(|_| step_with_dims(2, 1)).collect(),
        }
    }

    #[test]
    fn replayed_seq_answers_from_cache_without_reexecuting() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        let first = dispatch(&service, &mut session, &mut scratch, None, append_req(1, 3));
        assert!(matches!(first, Response::Appended { consumed: 3, .. }));
        assert_eq!(service.table("replay").unwrap().len(), 3);
        // The exact request re-sent (link died before the ack): the
        // cached reply comes back verbatim and nothing is re-inserted.
        let replay = dispatch(&service, &mut session, &mut scratch, None, append_req(1, 3));
        assert!(matches!(replay, Response::Appended { consumed: 3, .. }));
        assert_eq!(
            service.table("replay").unwrap().len(),
            3,
            "a replayed append must not double-insert"
        );
        assert_eq!(session.next_seq, 2);
    }

    #[test]
    fn seq_gap_and_stale_seq_are_descriptive_errors() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        // A gap (requests lost): descriptive error, nothing applied.
        let resp = dispatch(&service, &mut session, &mut scratch, None, append_req(5, 1));
        match resp {
            Response::Error { message } => assert!(message.contains("seq gap"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        assert_eq!(service.table("replay").unwrap().len(), 0);
        // Push the window past the reply cache, then replay seq 1: the
        // cache no longer holds it — stale error, not a re-execution.
        for seq in 1..=(REPLY_CACHE_DEPTH as u64 + 2) {
            let resp = dispatch(&service, &mut session, &mut scratch, None, append_req(seq, 1));
            assert!(matches!(resp, Response::Appended { .. }));
        }
        let before = service.table("replay").unwrap().len();
        let resp = dispatch(&service, &mut session, &mut scratch, None, append_req(1, 1));
        match resp {
            Response::Error { message } => assert!(message.contains("stale"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        assert_eq!(service.table("replay").unwrap().len(), before);
    }

    #[test]
    fn unsequenced_requests_bypass_the_gate() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        for _ in 0..3 {
            let resp = dispatch(&service, &mut session, &mut scratch, None, append_req(0, 1));
            assert!(matches!(resp, Response::Appended { consumed: 1, .. }));
        }
        assert_eq!(service.table("replay").unwrap().len(), 3);
        assert_eq!(session.next_seq, 1, "seq 0 must not advance the session");
        assert!(session.replies.is_empty(), "seq 0 must not populate the reply cache");
    }

    #[test]
    fn append_dropped_delta_feeds_table_stats() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Append {
                actor_id: 0,
                seq: 1,
                dropped: 7,
                steps: vec![step_with_dims(2, 1)],
            },
        );
        assert!(matches!(resp, Response::Appended { consumed: 1, .. }));
        let stats = service.table("replay").unwrap().stats_snapshot();
        assert_eq!(stats.steps_dropped, 7);
        // Replaying the same request must not double-count the delta.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Append {
                actor_id: 0,
                seq: 1,
                dropped: 7,
                steps: vec![step_with_dims(2, 1)],
            },
        );
        assert!(matches!(resp, Response::Appended { consumed: 1, .. }));
        let stats = service.table("replay").unwrap().stats_snapshot();
        assert_eq!(stats.steps_dropped, 7, "replayed dropped delta must dedupe");
    }

    #[test]
    fn mass_reports_len_and_total_priority() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Mass { table: "replay".into() },
        );
        assert_eq!(resp, Response::Mass { len: 0, mass: 0.0 });
        let mut w = service.writer(0);
        for _ in 0..3 {
            w.append(step_with_dims(2, 1));
        }
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Mass { table: "replay".into() },
        );
        // A uniform buffer's mass is its length (every item weight 1).
        assert_eq!(resp, Response::Mass { len: 3, mass: 3.0 });
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Mass { table: "nope".into() },
        );
        assert!(matches!(resp, Response::Error { .. }));
    }

    /// A donor service with `n` inserted steps, plus its encoded state.
    fn donor_state(n: usize) -> Vec<u8> {
        let donor = tiny_service();
        let mut w = donor.writer(0);
        for _ in 0..n {
            w.append(step_with_dims(2, 1));
        }
        donor.checkpoint().expect("capture donor state").encode()
    }

    /// The full request sequence of one chunked upload of `state`.
    fn upload_requests(state: &[u8], chunk_len: u32) -> Vec<Request> {
        let mut reqs = vec![Request::ChunkBegin {
            total_len: state.len() as u64,
            chunk_len,
            chunk_count: (state.len() as u64).div_ceil(chunk_len as u64) as u32,
        }];
        for (seq, piece) in state.chunks(chunk_len as usize).enumerate() {
            reqs.push(Request::Chunk { seq: seq as u32, crc: crc32(piece), data: piece.to_vec() });
        }
        reqs.push(Request::ChunkEnd { total_crc: crc32(state) });
        reqs
    }

    #[test]
    fn chunked_upload_restores_state_exactly() {
        let state = donor_state(9);
        let service = tiny_service();
        let mut upload = None;
        // 7-byte chunks: many chunks plus a short tail.
        for req in upload_requests(&state, 7) {
            match handle_chunk_upload(&service, &mut upload, req) {
                Response::Ok => {}
                other => panic!("upload step failed: {other:?}"),
            }
        }
        assert!(upload.is_none(), "a finished upload must leave no staging behind");
        assert_eq!(service.table("replay").unwrap().len(), 9);
        assert_eq!(
            service.checkpoint().unwrap().encode(),
            state,
            "the restored service must checkpoint byte-identically to the donor"
        );
    }

    /// Run `reqs` through the upload state machine until the first
    /// error; returns its message.
    fn upload_error(service: &Arc<ReplayService>, reqs: Vec<Request>) -> String {
        let mut upload = None;
        for req in reqs {
            if let Response::Error { message } = handle_chunk_upload(service, &mut upload, req) {
                assert!(upload.is_none(), "an upload error must discard the staging");
                return message;
            }
        }
        panic!("upload unexpectedly succeeded");
    }

    #[test]
    fn chunked_upload_violations_abort_with_tables_untouched() {
        let state = donor_state(9);
        let service = tiny_service();
        let reqs = upload_requests(&state, 7);

        // A chunk with no ChunkBegin.
        let msg = upload_error(&service, vec![reqs[1].clone()]);
        assert!(msg.contains("no ChunkBegin"), "{msg}");

        // Out-of-order sequence: chunk 1 where 0 is expected.
        let msg = upload_error(&service, vec![reqs[0].clone(), reqs[2].clone()]);
        assert!(msg.contains("out of order"), "{msg}");

        // A flipped payload bit fails the per-chunk CRC.
        let mut bad = reqs.clone();
        if let Request::Chunk { data, .. } = &mut bad[1] {
            data[0] ^= 0x01;
        }
        let msg = upload_error(&service, bad);
        assert!(msg.contains("CRC mismatch"), "{msg}");

        // An oversized single chunk (more bytes than the header
        // declared per chunk) is rejected the moment it arrives.
        let oversized = vec![
            reqs[0].clone(),
            Request::Chunk { seq: 0, crc: crc32(&state[..8]), data: state[..8].to_vec() },
        ];
        let msg = upload_error(&service, oversized);
        assert!(msg.contains("upload declared"), "{msg}");

        // ChunkEnd before every chunk arrived.
        let early = vec![reqs[0].clone(), reqs[1].clone(), reqs.last().unwrap().clone()];
        let msg = upload_error(&service, early);
        assert!(msg.contains("closed after"), "{msg}");

        // No violation may leave anything in the tables.
        assert_eq!(service.table("replay").unwrap().len(), 0);
    }

    #[test]
    fn stream_checkpoint_emits_bounded_verifiable_frames() {
        let service = tiny_service();
        let mut w = service.writer(0);
        for _ in 0..12 {
            w.append(step_with_dims(2, 1));
        }
        let (a, b) = std::os::unix::net::UnixStream::pair().expect("socketpair");
        let mut out = RpcStream::Unix(a);
        let mut enc = ByteWriter::new();
        // A 5-byte chunk bound forces a long multi-frame stream.
        stream_checkpoint(&service, &mut out, &mut enc, 5).expect("stream");
        drop(out);
        let mut rd = b;
        let mut payload = Vec::new();
        assert!(read_frame_into(&mut rd, &mut payload).unwrap());
        let (total_len, chunk_count) = match Response::decode(&payload).unwrap() {
            Response::ChunkBegin { total_len, chunk_len, chunk_count } => {
                assert_eq!(chunk_len, 5);
                (total_len, chunk_count)
            }
            other => panic!("expected ChunkBegin, got {other:?}"),
        };
        assert!(chunk_count > 1, "the state must not fit one 5-byte chunk");
        let mut got = Vec::new();
        for want_seq in 0..chunk_count {
            assert!(read_frame_into(&mut rd, &mut payload).unwrap());
            match Response::decode(&payload).unwrap() {
                Response::Chunk { seq, crc, data } => {
                    assert_eq!(seq, want_seq);
                    assert_eq!(crc, crc32(&data), "chunk {seq} ships a wrong CRC");
                    assert!(data.len() <= 5, "chunk {seq} exceeds the declared bound");
                    got.extend_from_slice(&data);
                }
                other => panic!("expected Chunk {want_seq}, got {other:?}"),
            }
        }
        assert_eq!(got.len() as u64, total_len);
        assert!(read_frame_into(&mut rd, &mut payload).unwrap());
        match Response::decode(&payload).unwrap() {
            Response::ChunkEnd { total_crc } => assert_eq!(total_crc, crc32(&got)),
            other => panic!("expected ChunkEnd, got {other:?}"),
        }
        assert_eq!(
            got,
            service.checkpoint().unwrap().encode(),
            "reassembled stream must equal the checkpoint bytes"
        );
    }

    fn two_table_service() -> Arc<ReplayService> {
        let table = |name: &str| {
            Table::new(
                name,
                ItemKind::OneStep,
                Arc::new(UniformReplay::new(32, 2, 1)),
                RateLimiter::Unlimited { min_size_to_sample: 1 },
            )
        };
        Arc::new(ReplayService::new(vec![table("hot"), table("cold")]).unwrap())
    }

    #[test]
    fn insert_budget_caps_appends_then_would_stall() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        session.budget = Some(5);
        let mut scratch = SampleBatch::default();
        // 8 steps against a budget of 5: partial consume, like a
        // limiter stall — the client retries the tail.
        let resp = dispatch(&service, &mut session, &mut scratch, None, append_req(0, 8));
        assert!(matches!(resp, Response::Appended { consumed: 5, .. }), "{resp:?}");
        assert_eq!(service.table("replay").unwrap().len(), 5);
        assert_eq!(session.budget, Some(0));
        // Budget spent and nothing consumable: a retriable quota
        // stall, never an error or a dropped connection.
        let resp = dispatch(&service, &mut session, &mut scratch, None, append_req(0, 2));
        assert_eq!(resp, Response::WouldStall { reason: StallReason::QuotaExhausted });
        assert_eq!(service.table("replay").unwrap().len(), 5);
    }

    #[test]
    fn acl_scopes_writer_fan_out_and_rejects_foreign_samples() {
        let service = two_table_service();
        let mut session = Session::new(0, 1);
        session.set_acl(&["hot".to_string()]);
        let mut scratch = SampleBatch::default();
        // Appends fan out only to the ACL tables.
        let resp = dispatch(&service, &mut session, &mut scratch, None, append_req(0, 3));
        assert!(matches!(resp, Response::Appended { consumed: 3, .. }), "{resp:?}");
        assert_eq!(service.table("hot").unwrap().len(), 3);
        assert_eq!(service.table("cold").unwrap().len(), 0);
        // Sampling inside the ACL works; outside it is a hard error
        // (a config bug, not a capacity condition).
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Sample { table: "hot".into(), batch: 2, seq: 0 },
        );
        assert!(matches!(resp, Response::Sampled(_)), "{resp:?}");
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Sample { table: "cold".into(), batch: 2, seq: 0 },
        );
        match resp {
            Response::Error { message } => assert!(message.contains("ACL"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::UpdatePriorities {
                table: "cold".into(),
                indices: vec![0],
                td_abs: vec![1.0],
                seq: 0,
            },
        );
        assert!(matches!(resp, Response::Error { .. }), "{resp:?}");
    }

    #[test]
    fn writer_ledger_caps_writers_per_table() {
        let service = two_table_service();
        let ledger = Arc::new(WriterLedger::new(1));
        let mut scratch = SampleBatch::default();
        let mut a = Session::new(0, 1);
        a.ledger = Some(Arc::clone(&ledger));
        a.set_acl(&["hot".to_string()]);
        let resp = dispatch(&service, &mut a, &mut scratch, None, append_req(0, 1));
        assert!(matches!(resp, Response::Appended { consumed: 1, .. }), "{resp:?}");
        // A second session wanting "hot" hits the cap — retriable.
        let mut b = Session::new(1, 2);
        b.ledger = Some(Arc::clone(&ledger));
        b.set_acl(&["hot".to_string()]);
        let resp = dispatch(&service, &mut b, &mut scratch, None, append_req(0, 1));
        assert_eq!(resp, Response::WouldStall { reason: StallReason::QuotaExhausted });
        assert_eq!(service.table("hot").unwrap().len(), 1);
        // A session scoped to the other table is unaffected.
        let mut c = Session::new(2, 3);
        c.ledger = Some(Arc::clone(&ledger));
        c.set_acl(&["cold".to_string()]);
        let resp = dispatch(&service, &mut c, &mut scratch, None, append_req(0, 1));
        assert!(matches!(resp, Response::Appended { consumed: 1, .. }), "{resp:?}");
        // Dropping the holder releases its claim; the retry succeeds.
        drop(a);
        let resp = dispatch(&service, &mut b, &mut scratch, None, append_req(0, 1));
        assert!(matches!(resp, Response::Appended { consumed: 1, .. }), "{resp:?}");
    }

    #[test]
    fn ping_echoes_the_nonce() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Ping { nonce: 0xDECA_FBAD },
        );
        assert_eq!(resp, Response::Pong { nonce: 0xDECA_FBAD });
        // A draining server still answers: the probe distinguishes
        // draining/restarting from dead.
        let resp = dispatch_draining(
            &service,
            &mut session,
            &mut scratch,
            Request::Ping { nonce: 7 },
        );
        assert_eq!(resp, Response::Pong { nonce: 7 });
    }

    #[test]
    fn draining_stalls_appends_and_advertises_zero_mass() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        let mut w = service.writer(7);
        for _ in 0..3 {
            w.append(step_with_dims(2, 1));
        }
        // Appends stall retriably; the dropped delta is still folded in
        // (the reply is the ack), so drops land exactly once.
        let resp = dispatch_draining(
            &service,
            &mut session,
            &mut scratch,
            Request::Append {
                actor_id: 0,
                seq: 1,
                dropped: 3,
                steps: vec![step_with_dims(2, 1)],
            },
        );
        assert_eq!(resp, Response::WouldStall { reason: StallReason::QuotaExhausted });
        let stats = service.table("replay").unwrap().stats_snapshot();
        assert_eq!(stats.steps_dropped, 3);
        assert_eq!(service.table("replay").unwrap().len(), 3, "no step may be admitted");
        // Mass advertises zero so mesh samplers renormalize away...
        let resp = dispatch_draining(
            &service,
            &mut session,
            &mut scratch,
            Request::Mass { table: "replay".into() },
        );
        assert_eq!(resp, Response::Mass { len: 0, mass: 0.0 });
        // ...but sampling still works — the rows stay here until the
        // handoff lands on a peer.
        let resp = dispatch_draining(
            &service,
            &mut session,
            &mut scratch,
            Request::Sample { table: "replay".into(), batch: 2, seq: 0 },
        );
        assert!(matches!(resp, Response::Sampled(_)), "{resp:?}");
    }

    #[test]
    fn chunked_handoff_merges_instead_of_replacing() {
        let state = donor_state(5);
        let service = tiny_service();
        // The receiver already holds rows of its own.
        let mut w = service.writer(1);
        for _ in 0..4 {
            w.append(step_with_dims(2, 1));
        }
        let mut upload = None;
        let mut reqs = upload_requests(&state, 64);
        let Some(Request::ChunkEnd { total_crc }) = reqs.pop() else {
            panic!("upload must close with ChunkEnd");
        };
        reqs.push(Request::HandoffEnd { total_crc });
        for req in reqs {
            match handle_chunk_upload(&service, &mut upload, req) {
                Response::Ok => {}
                other => panic!("handoff step failed: {other:?}"),
            }
        }
        assert!(upload.is_none(), "a finished handoff must leave no staging behind");
        assert_eq!(
            service.table("replay").unwrap().len(),
            9,
            "the merge must add the donor's 5 rows on top of the receiver's 4"
        );
    }

    #[test]
    fn drain_without_reachable_peers_fails_and_resumes_service() {
        let service = tiny_service();
        let drain = DrainCtl { flag: Arc::new(AtomicBool::new(false)), peers: Vec::new() };
        // No peers anywhere: refused up front.
        let err = handle_drain(&service, &drain, 0, &[]).unwrap_err();
        assert!(format!("{err:#}").contains("no drain peers"), "{err:#}");
        assert!(!drain.flag.load(Ordering::SeqCst), "a failed drain must clear the flag");
        // An unreachable peer: the handoff fails naming it, and the
        // flag clears so the server resumes normal service.
        let missing = std::env::temp_dir().join("pal_drain_no_such_server.sock");
        let err = handle_drain(
            &service,
            &drain,
            0,
            &[missing.display().to_string()],
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("refused the handoff"), "{err:#}");
        assert!(!drain.flag.load(Ordering::SeqCst));
        // A drain racing an in-progress one is refused without
        // clearing the winner's flag.
        drain.flag.store(true, Ordering::SeqCst);
        let err = handle_drain(&service, &drain, 0, &[]).unwrap_err();
        assert!(format!("{err:#}").contains("already draining"), "{err:#}");
        assert!(drain.flag.load(Ordering::SeqCst));
    }

    #[test]
    fn session_registry_resumes_and_expires() {
        let reg = SessionRegistry::new();
        let (slot, resumed) = reg.hello(0, 11);
        assert!(!resumed);
        let id = slot.lock().unwrap().id;
        assert_ne!(id, 0, "minted ids must be nonzero (0 means fresh on the wire)");
        slot.lock().unwrap().next_seq = 42;
        drop(slot); // detach
        // Resuming the same id reattaches the same state.
        let (slot, resumed) = reg.hello(id, 999);
        assert!(resumed);
        assert_eq!(slot.lock().unwrap().next_seq, 42);
        drop(slot);
        // An unknown id (e.g. minted by a previous server boot) binds a
        // fresh session instead of failing.
        let (slot, resumed) = reg.hello(id ^ 0xDEAD_BEEF, 5);
        assert!(!resumed);
        assert_eq!(slot.lock().unwrap().next_seq, 1);
    }
}
