//! The remote replay server: a Unix-domain-socket front-end over one
//! [`ReplayService`] (Reverb's `reverb.Server` shape, std-only).
//!
//! One accept loop, one detached thread per connection. Each
//! connection binds a server-side *session*: a sampling RNG (seeded by
//! the client's `Hello`, or from the connection id), one
//! [`TrajectoryWriter`] per actor id — so remote actors get the same
//! item assembly (N-step folding, sequence windows, boundary rules) as
//! local ones and sharded tables keep their actor-affinity routing —
//! plus the session's request-sequence state and reply cache.
//!
//! # Sessions and exactly-once requests
//!
//! A `Hello` with `session == 0` registers a fresh session and returns
//! its id; a reconnecting client quotes that id and, if the session is
//! still registered (it survives a dropped connection, with a TTL),
//! reattaches to ALL of its state: the sampling RNG stream continues,
//! per-actor `TrajectoryWriter` assembly windows reattach instead of
//! resetting, and the reply cache dedupes replayed requests. The
//! mutating RPCs carry a session-scoped sequence number: the server
//! executes each number once, caches the encoded reply, and answers a
//! replay (a request the client re-sent because the link died before
//! the ack arrived) from the cache verbatim — an append can therefore
//! never double-insert across reconnects. An unknown or expired
//! session id simply binds a fresh session (`resumed == false` in the
//! response) — the server-restart path, where clients re-send all
//! unacked work under new sequence numbers.
//!
//! # Failure semantics
//!
//! * A malformed *frame* (truncated, bit-flipped, oversized length,
//!   wrong magic) gets a best-effort [`Response::Error`] and the
//!   connection is dropped — the stream can no longer be trusted to be
//!   on a frame boundary. Nothing was applied: a request is decoded in
//!   full before any table is touched.
//! * A malformed *payload* inside a checksummed frame (bad opcode,
//!   inconsistent lengths) gets a [`Response::Error`] and the
//!   connection stays up (the frame boundary is intact).
//! * Application errors (unknown table, out-of-range indices,
//!   non-finite priorities, failed restore) get a [`Response::Error`]
//!   carrying the server-side error chain; the connection stays up.
//! * A stalled sample is a retriable [`Response::WouldStall`]; a
//!   partially admitted insert batch is a short
//!   [`Response::Appended`]. The server never blocks a connection on a
//!   rate limiter.

use super::frame::{read_frame_into, write_frame};
use super::proto::{self, Request, Response, StallReason, TableInfo};
use crate::replay::SampleBatch;
use crate::service::{ReplayService, SampleOutcome, ServiceState, TrajectoryWriter};
use crate::util::blob::ByteWriter;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Decrements the server's live-connection count when a connection
/// thread exits by any path (EOF, protocol error, shutdown, panic).
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Release);
    }
}

/// Most distinct actor ids one session may write for. Every other
/// hostile count in the protocol is bounded; this bounds the
/// server-side writer map (a buggy client passing a step counter as
/// its actor id would otherwise grow it without limit).
pub const MAX_WRITERS_PER_CONN: usize = 1_024;

/// Most registered sessions the server keeps; past this, the oldest
/// detached session is evicted to make room.
pub const MAX_SESSIONS: usize = 4_096;

/// How long a detached session's state survives before it may be
/// evicted (a reconnect after this binds a fresh session).
pub const SESSION_TTL: Duration = Duration::from_secs(900);

/// Encoded replies kept per session for request dedupe. Deeper than
/// any client's in-flight pipeline (the sampler keeps at most 2
/// requests outstanding, the writer 1).
pub const REPLY_CACHE_DEPTH: usize = 8;

/// The default bound on the post-stop connection drain (override with
/// [`ReplayServer::with_drain_deadline`] / `pal serve --drain-deadline`).
pub const DEFAULT_DRAIN_DEADLINE: Duration = Duration::from_secs(5);

/// One session's server-side state. Owned by the registry (detached
/// sessions keep it alive for [`SESSION_TTL`]); a connection locks it
/// per request.
struct Session {
    id: u64,
    rng: Rng,
    writers: HashMap<u64, TrajectoryWriter>,
    /// Next expected sequenced-request number (sequenced requests start
    /// at 1; `seq == 0` opts out of sequencing).
    next_seq: u64,
    /// Encoded replies of the most recent sequenced requests, for
    /// replay dedupe.
    replies: VecDeque<(u64, Vec<u8>)>,
}

impl Session {
    fn new(id: u64, seed: u64) -> Self {
        Self {
            id,
            rng: Rng::new(seed),
            writers: HashMap::new(),
            next_seq: 1,
            replies: VecDeque::new(),
        }
    }
}

struct SessionEntry {
    slot: Arc<Mutex<Session>>,
    last_seen: Instant,
}

/// Registry of resumable sessions. Ids mix a per-boot nonce with a
/// counter so a restarted server can never wrongly resume a session id
/// minted by a previous incarnation.
struct SessionRegistry {
    inner: Mutex<HashMap<u64, SessionEntry>>,
    next: AtomicU64,
}

impl SessionRegistry {
    fn new() -> Self {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap_or_default()
            .as_nanos() as u64;
        let nonce = nanos ^ ((std::process::id() as u64) << 32);
        // Odd base + even strides keeps every id odd, hence nonzero
        // (0 means "fresh" on the wire).
        Self { inner: Mutex::new(HashMap::new()), next: AtomicU64::new(nonce | 1) }
    }

    /// Bind a `Hello`: resume `requested` if it is still registered,
    /// else mint a fresh session seeded with `seed`. Returns the slot
    /// and whether prior state was resumed.
    fn hello(&self, requested: u64, seed: u64) -> (Arc<Mutex<Session>>, bool) {
        let mut map = self.inner.lock().expect("session registry poisoned");
        let now = Instant::now();
        // Evict expired detached sessions (attached slots have a second
        // Arc holder: the connection).
        map.retain(|_, e| {
            Arc::strong_count(&e.slot) > 1 || now.duration_since(e.last_seen) < SESSION_TTL
        });
        if requested != 0 {
            if let Some(e) = map.get_mut(&requested) {
                e.last_seen = now;
                return (Arc::clone(&e.slot), true);
            }
        }
        if map.len() >= MAX_SESSIONS {
            let oldest = map
                .iter()
                .filter(|(_, e)| Arc::strong_count(&e.slot) == 1)
                .min_by_key(|(_, e)| e.last_seen)
                .map(|(&id, _)| id);
            if let Some(id) = oldest {
                map.remove(&id);
            }
        }
        let id = self.next.fetch_add(2, Ordering::Relaxed);
        let slot = Arc::new(Mutex::new(Session::new(id, seed)));
        map.insert(id, SessionEntry { slot: Arc::clone(&slot), last_seen: now });
        (slot, false)
    }

    /// Record detach time so the TTL measures time since last use.
    fn touch(&self, id: u64) {
        if let Some(e) = self.inner.lock().expect("session registry poisoned").get_mut(&id) {
            e.last_seen = Instant::now();
        }
    }
}

/// A bound replay server. [`Self::serve`] runs the accept loop until a
/// client sends `Shutdown` (or [`Self::stop_handle`] is flipped).
pub struct ReplayServer {
    service: Arc<ReplayService>,
    listener: UnixListener,
    path: PathBuf,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    seed: u64,
    /// Expected base step dims (obs, action), when known: `Append`
    /// steps are rejected with a descriptive error on mismatch instead
    /// of silently truncating/padding rows in storage.
    dims: Option<(usize, usize)>,
    sessions: Arc<SessionRegistry>,
    drain_deadline: Duration,
}

impl ReplayServer {
    /// Bind a Unix-domain socket at `path`. A stale socket file left by
    /// a dead server is replaced; a socket another server still answers
    /// on, or any other kind of file, is refused. `seed` derives the
    /// default per-connection sampling RNGs.
    pub fn bind(service: Arc<ReplayService>, path: impl AsRef<Path>, seed: u64) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Ok(meta) = std::fs::symlink_metadata(&path) {
            if !std::os::unix::fs::FileTypeExt::is_socket(&meta.file_type()) {
                bail!(
                    "{} exists and is not a socket — refusing to replace it",
                    path.display()
                );
            }
            // Liveness probe: only a DEAD server's socket may be
            // replaced. Stealing a live server's path would split the
            // experience stream between two servers with no error.
            if UnixStream::connect(&path).is_ok() {
                bail!(
                    "a replay server is already listening on {} — refusing to replace it",
                    path.display()
                );
            }
            std::fs::remove_file(&path)
                .with_context(|| format!("removing stale socket {}", path.display()))?;
        }
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("binding replay server socket {}", path.display()))?;
        // Non-blocking accept so the loop can notice a stop request.
        listener
            .set_nonblocking(true)
            .context("setting the listener non-blocking")?;
        Ok(Self {
            service,
            listener,
            path,
            stop: Arc::new(AtomicBool::new(false)),
            active: Arc::new(AtomicUsize::new(0)),
            seed,
            dims: None,
            sessions: Arc::new(SessionRegistry::new()),
            drain_deadline: DEFAULT_DRAIN_DEADLINE,
        })
    }

    /// Bound the post-stop wait for open connections to drain (`pal
    /// serve --drain-deadline`).
    pub fn with_drain_deadline(mut self, deadline: Duration) -> Self {
        self.drain_deadline = deadline;
        self
    }

    /// Enforce base step dims on every `Append` (what `pal serve`'s
    /// `--obs-dim`/`--act-dim` declare): mismatched clients get a
    /// descriptive error on their first frame instead of silently
    /// corrupted rows.
    pub fn expect_dims(mut self, obs_dim: usize, act_dim: usize) -> Self {
        self.dims = Some((obs_dim, act_dim));
        self
    }

    /// Flag that ends the accept loop (also set by a `Shutdown` RPC).
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    pub fn socket_path(&self) -> &Path {
        &self.path
    }

    /// Accept loop. Returns after `Shutdown` (or an external stop);
    /// connection threads are detached and exit when their client hangs
    /// up. On the way out the loop drains in-flight connections
    /// (bounded wait) so a post-`serve` state capture cannot race a
    /// request the server already acknowledged, then removes the
    /// socket file.
    pub fn serve(&self) -> Result<()> {
        let mut conn_id = 0u64;
        while !self.stop.load(Ordering::Relaxed) {
            match self.listener.accept() {
                Ok((stream, _addr)) => {
                    conn_id += 1;
                    let service = Arc::clone(&self.service);
                    let stop = Arc::clone(&self.stop);
                    let guard = ConnGuard(Arc::clone(&self.active));
                    self.active.fetch_add(1, Ordering::Acquire);
                    let dims = self.dims;
                    let sessions = Arc::clone(&self.sessions);
                    let seed = self
                        .seed
                        .wrapping_add(conn_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    std::thread::spawn(move || {
                        let _guard = guard;
                        handle_connection(service, stream, seed, stop, dims, sessions);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!("accepting on replay server socket {}", self.path.display())
                    });
                }
            }
        }
        // Drain: clients that quiesced before Shutdown disconnect
        // promptly; an idle client parked in a blocking read cannot be
        // joined, so the wait is bounded and reported.
        let deadline = Instant::now() + self.drain_deadline;
        while self.active.load(Ordering::Acquire) > 0 {
            if Instant::now() >= deadline {
                eprintln!(
                    "[pal] WARNING: {} connection(s) still open at shutdown; \
                     a concurrent state capture may miss their in-flight requests",
                    self.active.load(Ordering::Acquire)
                );
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        std::fs::remove_file(&self.path).ok();
        Ok(())
    }
}

/// Per-connection loop: read frame → decode → dispatch → respond. One
/// read buffer and one response encoder per connection, reused for
/// every frame, so framing and response encoding allocate nothing per
/// RPC (request *decoding* still materializes owned payloads — an
/// `Append`'s steps become storage rows).
fn handle_connection(
    service: Arc<ReplayService>,
    mut stream: UnixStream,
    seed: u64,
    stop: Arc<AtomicBool>,
    dims: Option<(usize, usize)>,
    sessions: Arc<SessionRegistry>,
) {
    // Accepted sockets may inherit the listener's non-blocking mode;
    // connection I/O is plain blocking reads.
    let _ = stream.set_nonblocking(false);
    // Until (unless) the client says Hello, the connection runs on an
    // implicit session: same state shape, but unregistered — it dies
    // with the connection, exactly the pre-session behavior.
    let mut session: Arc<Mutex<Session>> = Arc::new(Mutex::new(Session::new(0, seed)));
    let mut registered = 0u64;
    let mut scratch = SampleBatch::default();
    let mut rbuf: Vec<u8> = Vec::new();
    let mut enc = ByteWriter::new();
    loop {
        match read_frame_into(&mut stream, &mut rbuf) {
            Ok(true) => {}
            // Client hung up between frames.
            Ok(false) => break,
            Err(e) => {
                // The stream may be mid-frame; answer and drop it.
                let resp = Response::Error { message: format!("protocol error: {e}") };
                let _ = write_frame(&mut stream, &resp.encode());
                break;
            }
        }
        enc.reset();
        let mut shutdown = false;
        match Request::decode(&rbuf) {
            // Frame boundaries are intact (the frame checksum passed);
            // a bad payload is answerable without closing.
            Err(e) => {
                Response::Error { message: format!("bad request: {e}") }.encode_into(&mut enc)
            }
            Ok(Request::Shutdown) => {
                Response::Ok.encode_into(&mut enc);
                shutdown = true;
            }
            Ok(Request::Hello { rng_seed, session: requested }) => {
                let (slot, resumed) = sessions.hello(requested, rng_seed);
                let (id, next_seq) = {
                    let s = slot.lock().expect("session poisoned");
                    (s.id, s.next_seq)
                };
                session = slot;
                registered = id;
                Response::Hello {
                    default_table: service.default_table().name().to_string(),
                    session: id,
                    resumed,
                    next_seq,
                }
                .encode_into(&mut enc);
            }
            Ok(req) => {
                let mut s = session.lock().expect("session poisoned");
                dispatch_into(&service, &mut s, &mut scratch, dims, req, &mut enc)
            }
        }
        if shutdown {
            // Set the stop flag BEFORE attempting the Ok response: a
            // client that hangs up right after sending Shutdown must
            // still stop the server (the reply is best-effort).
            stop.store(true, Ordering::Relaxed);
            let _ = write_frame(&mut stream, enc.as_slice());
            break;
        }
        if write_frame(&mut stream, enc.as_slice()).is_err() {
            break;
        }
    }
    if registered != 0 {
        // Stamp detach time so the session TTL measures idleness, not
        // age.
        sessions.touch(registered);
    }
}

/// Apply one decoded request against the service, encoding the
/// response into `enc`. Infallible by construction: every failure is
/// an encoded [`Response::Error`], so a hostile request can never take
/// the connection thread down. The `Sampled` hot path encodes the
/// scratch batch directly (no clone, no `Response` value).
///
/// Sequenced requests (`seq > 0`) pass the session's exactly-once
/// gate first: in-order requests execute and their encoded reply is
/// cached; a replayed number answers from the cache verbatim (no
/// re-execution); a number older than the cache window or ahead of the
/// expected one is a descriptive error.
fn dispatch_into(
    service: &Arc<ReplayService>,
    session: &mut Session,
    scratch: &mut SampleBatch,
    dims: Option<(usize, usize)>,
    req: Request,
    enc: &mut ByteWriter,
) {
    let seq = match &req {
        Request::Append { seq, .. }
        | Request::Sample { seq, .. }
        | Request::UpdatePriorities { seq, .. }
            if *seq > 0 =>
        {
            Some(*seq)
        }
        _ => None,
    };
    if let Some(seq) = seq {
        if seq < session.next_seq {
            if let Some((_, bytes)) = session.replies.iter().find(|(s, _)| *s == seq) {
                enc.raw(bytes);
            } else {
                Response::Error {
                    message: format!(
                        "stale request seq {seq}: session expects {} and the reply \
                         cache no longer holds it",
                        session.next_seq
                    ),
                }
                .encode_into(enc);
            }
            return;
        }
        if seq > session.next_seq {
            Response::Error {
                message: format!(
                    "request seq gap: got {seq}, session expects {} (requests lost \
                     or reordered)",
                    session.next_seq
                ),
            }
            .encode_into(enc);
            return;
        }
    }
    if let Request::Sample { table, batch, .. } = &req {
        match service.sampler(table) {
            None => {
                Response::Error { message: format!("unknown table `{table}`") }.encode_into(enc)
            }
            Some(sampler) => {
                match sampler.try_sample(*batch as usize, &mut session.rng, scratch) {
                    SampleOutcome::Sampled => proto::encode_sampled(enc, scratch),
                    SampleOutcome::Throttled => {
                        Response::WouldStall { reason: StallReason::Throttled }.encode_into(enc)
                    }
                    SampleOutcome::NotEnoughData => {
                        Response::WouldStall { reason: StallReason::NotEnoughData }
                            .encode_into(enc)
                    }
                }
            }
        }
    } else {
        dispatch_cold(service, session, dims, req).encode_into(enc);
    }
    if let Some(seq) = seq {
        session.next_seq = seq + 1;
        session.replies.push_back((seq, enc.as_slice().to_vec()));
        while session.replies.len() > REPLY_CACHE_DEPTH {
            session.replies.pop_front();
        }
    }
}

/// The non-`Sample` requests, as plain response values (their payloads
/// are either tiny or intrinsically owned, so value construction costs
/// nothing that matters).
fn dispatch_cold(
    service: &Arc<ReplayService>,
    session: &mut Session,
    dims: Option<(usize, usize)>,
    req: Request,
) -> Response {
    match req {
        // Session binding happens in the connection loop (it swaps the
        // session slot itself); reaching here means a decoder bug.
        Request::Hello { .. } => Response::Error {
            message: "internal: Hello reached the dispatch path".to_string(),
        },
        Request::Append { actor_id, seq: _, dropped, steps } => {
            // A client reporting spill-queue drops folds the delta into
            // server-side stats even when the limiter admits nothing:
            // the reply (cached under this request's seq) is the ack, so
            // the count is applied exactly once.
            if dropped > 0 {
                for t in service.tables() {
                    t.add_steps_dropped(dropped as usize);
                }
            }
            // Validate the WHOLE batch before applying any of it, so a
            // malformed batch never half-applies. Without declared dims
            // only self-consistency is checkable; with them a
            // mismatched client fails on its first frame instead of
            // silently truncating/padding rows in storage.
            for (i, s) in steps.iter().enumerate() {
                let self_consistent =
                    !s.obs.is_empty() && !s.action.is_empty() && s.obs.len() == s.next_obs.len();
                let dims_ok = dims
                    .map_or(true, |(od, ad)| s.obs.len() == od && s.action.len() == ad);
                if !self_consistent || !dims_ok {
                    let expected = match dims {
                        Some((od, ad)) => format!("obs_dim {od}, act_dim {ad}"),
                        None => "non-empty obs/action with obs_dim == next_obs dim".to_string(),
                    };
                    return Response::Error {
                        message: format!(
                            "append step {i} has dims obs={}/next_obs={}/action={}, server \
                             expects {expected}",
                            s.obs.len(),
                            s.next_obs.len(),
                            s.action.len(),
                        ),
                    };
                }
            }
            if !session.writers.contains_key(&actor_id)
                && session.writers.len() >= MAX_WRITERS_PER_CONN
            {
                return Response::Error {
                    message: format!(
                        "session already writes for {MAX_WRITERS_PER_CONN} distinct \
                         actor ids — actor id {actor_id} rejected (buggy id generation?)"
                    ),
                };
            }
            let writer = session
                .writers
                .entry(actor_id)
                .or_insert_with(|| service.writer(actor_id as usize));
            let mut consumed = 0u32;
            let mut emitted = 0u32;
            for step in steps {
                // Stop at the first limiter stall; the client retries
                // the tail. An admitted step is fully fanned out, so an
                // insert is never half-applied.
                if writer.throttled() {
                    break;
                }
                emitted += writer.append(step) as u32;
                consumed += 1;
            }
            Response::Appended { consumed, emitted }
        }
        // Handled by the hot path in `dispatch_into`.
        Request::Sample { .. } => unreachable!("Sample is dispatched before the cold path"),
        Request::UpdatePriorities { table, indices, td_abs, seq: _ } => match service.table(&table)
        {
            None => Response::Error { message: format!("unknown table `{table}`") },
            Some(t) => {
                let cap = t.capacity() as u64;
                if let Some(bad) = indices.iter().find(|&&i| i >= cap) {
                    return Response::Error {
                        message: format!(
                            "priority index {bad} out of range for table `{table}` \
                             (capacity {cap})"
                        ),
                    };
                }
                if let Some(bad) = td_abs.iter().find(|v| !v.is_finite()) {
                    return Response::Error {
                        message: format!("non-finite priority value {bad} rejected"),
                    };
                }
                let idx: Vec<usize> = indices.iter().map(|&i| i as usize).collect();
                t.update_priorities(&idx, &td_abs);
                Response::Ok
            }
        },
        Request::Stats => Response::Stats {
            tables: service
                .tables()
                .iter()
                .map(|t| TableInfo {
                    name: t.name().to_string(),
                    len: t.len() as u64,
                    capacity: t.capacity() as u64,
                    stats: t.stats_snapshot(),
                })
                .collect(),
        },
        Request::Checkpoint => match service.checkpoint() {
            Ok(state) => {
                let state = state.encode();
                // A state payload the framing layer cannot carry must be
                // a clear error frame, not a dropped connection.
                if state.len() + 64 > super::frame::MAX_FRAME_LEN {
                    Response::Error {
                        message: format!(
                            "checkpoint is {} bytes, larger than the {}-byte frame cap — \
                             checkpoint the serving process directly (`pal serve --save-state`)",
                            state.len(),
                            super::frame::MAX_FRAME_LEN
                        ),
                    }
                } else {
                    Response::State { state }
                }
            }
            Err(e) => Response::Error { message: format!("checkpoint failed: {e}") },
        },
        Request::Restore { state } => {
            match ServiceState::decode(&state).and_then(|s| service.restore(&s)) {
                Ok(()) => Response::Ok,
                Err(e) => Response::Error { message: format!("restore failed: {e}") },
            }
        }
        // Handled (and answered) by the connection loop before dispatch.
        Request::Shutdown => Response::Ok,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::replay::UniformReplay;
    use crate::service::{ItemKind, RateLimiter, Table};

    /// Round one request through the encoding dispatch path back to a
    /// decoded `Response` (what tests assert on).
    fn dispatch(
        service: &Arc<ReplayService>,
        session: &mut Session,
        scratch: &mut SampleBatch,
        dims: Option<(usize, usize)>,
        req: Request,
    ) -> Response {
        let mut enc = ByteWriter::new();
        dispatch_into(service, session, scratch, dims, req, &mut enc);
        Response::decode(enc.as_slice()).expect("dispatch must encode a decodable response")
    }

    fn tiny_service() -> Arc<ReplayService> {
        Arc::new(
            ReplayService::new(vec![Table::new(
                "replay",
                ItemKind::OneStep,
                Arc::new(UniformReplay::new(32, 2, 1)),
                RateLimiter::Unlimited { min_size_to_sample: 1 },
            )])
            .unwrap(),
        )
    }

    #[test]
    fn bind_refuses_non_socket_files_and_replaces_stale_sockets() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("pal_srv_bind_{}.sock", std::process::id()));
        std::fs::write(&path, b"not a socket").unwrap();
        assert!(ReplayServer::bind(tiny_service(), &path, 0).is_err());
        std::fs::remove_file(&path).unwrap();

        // A stale socket (no listener behind it) is replaced.
        {
            let first = ReplayServer::bind(tiny_service(), &path, 0).unwrap();
            drop(first); // listener gone, socket file left behind
        }
        assert!(path.exists(), "dropping the server leaves the socket file");
        let second = ReplayServer::bind(tiny_service(), &path, 0).unwrap();
        assert_eq!(second.socket_path(), path.as_path());
        drop(second);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn dispatch_rejects_hostile_priority_updates() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        // Out-of-range index.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::UpdatePriorities {
                table: "replay".into(),
                indices: vec![1 << 50],
                td_abs: vec![1.0],
                seq: 0,
            },
        );
        match resp {
            Response::Error { message } => assert!(message.contains("out of range"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        // Non-finite priority.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::UpdatePriorities {
                table: "replay".into(),
                indices: vec![0],
                td_abs: vec![f32::NAN],
                seq: 0,
            },
        );
        assert!(matches!(resp, Response::Error { .. }));
        // Unknown table.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Sample { table: "nope".into(), batch: 4, seq: 0 },
        );
        assert!(matches!(resp, Response::Error { .. }));
    }

    fn step_with_dims(obs: usize, act: usize) -> crate::service::WriterStep {
        crate::service::WriterStep {
            obs: vec![0.5; obs],
            action: vec![0.1; act],
            next_obs: vec![0.6; obs],
            reward: 1.0,
            done: false,
            truncated: false,
        }
    }

    #[test]
    fn dispatch_rejects_mismatched_step_dims_atomically() {
        let service = tiny_service(); // tables are obs_dim 2, act_dim 1
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        // Declared dims: a wrong-width step is rejected and NOTHING of
        // the batch (even its valid steps) is applied.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            Some((2, 1)),
            Request::Append {
                actor_id: 0,
                seq: 0,
                dropped: 0,
                steps: vec![step_with_dims(2, 1), step_with_dims(8, 1)],
            },
        );
        match resp {
            Response::Error { message } => assert!(message.contains("expects"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        assert_eq!(service.table("replay").unwrap().len(), 0);
        // Without declared dims, self-inconsistent steps still fail.
        let mut bad = step_with_dims(2, 1);
        bad.next_obs = vec![0.0; 5];
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Append { actor_id: 0, seq: 0, dropped: 0, steps: vec![bad] },
        );
        assert!(matches!(resp, Response::Error { .. }));
        assert_eq!(service.table("replay").unwrap().len(), 0);
        // A well-formed batch passes.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            Some((2, 1)),
            Request::Append {
                actor_id: 0,
                seq: 0,
                dropped: 0,
                steps: vec![step_with_dims(2, 1)],
            },
        );
        assert!(matches!(resp, Response::Appended { consumed: 1, .. }));
        assert_eq!(service.table("replay").unwrap().len(), 1);
    }

    fn append_req(seq: u64, n: usize) -> Request {
        Request::Append {
            actor_id: 0,
            seq,
            dropped: 0,
            steps: (0..n).map(|_| step_with_dims(2, 1)).collect(),
        }
    }

    #[test]
    fn replayed_seq_answers_from_cache_without_reexecuting() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        let first = dispatch(&service, &mut session, &mut scratch, None, append_req(1, 3));
        assert!(matches!(first, Response::Appended { consumed: 3, .. }));
        assert_eq!(service.table("replay").unwrap().len(), 3);
        // The exact request re-sent (link died before the ack): the
        // cached reply comes back verbatim and nothing is re-inserted.
        let replay = dispatch(&service, &mut session, &mut scratch, None, append_req(1, 3));
        assert!(matches!(replay, Response::Appended { consumed: 3, .. }));
        assert_eq!(
            service.table("replay").unwrap().len(),
            3,
            "a replayed append must not double-insert"
        );
        assert_eq!(session.next_seq, 2);
    }

    #[test]
    fn seq_gap_and_stale_seq_are_descriptive_errors() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        // A gap (requests lost): descriptive error, nothing applied.
        let resp = dispatch(&service, &mut session, &mut scratch, None, append_req(5, 1));
        match resp {
            Response::Error { message } => assert!(message.contains("seq gap"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        assert_eq!(service.table("replay").unwrap().len(), 0);
        // Push the window past the reply cache, then replay seq 1: the
        // cache no longer holds it — stale error, not a re-execution.
        for seq in 1..=(REPLY_CACHE_DEPTH as u64 + 2) {
            let resp = dispatch(&service, &mut session, &mut scratch, None, append_req(seq, 1));
            assert!(matches!(resp, Response::Appended { .. }));
        }
        let before = service.table("replay").unwrap().len();
        let resp = dispatch(&service, &mut session, &mut scratch, None, append_req(1, 1));
        match resp {
            Response::Error { message } => assert!(message.contains("stale"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        assert_eq!(service.table("replay").unwrap().len(), before);
    }

    #[test]
    fn unsequenced_requests_bypass_the_gate() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        for _ in 0..3 {
            let resp = dispatch(&service, &mut session, &mut scratch, None, append_req(0, 1));
            assert!(matches!(resp, Response::Appended { consumed: 1, .. }));
        }
        assert_eq!(service.table("replay").unwrap().len(), 3);
        assert_eq!(session.next_seq, 1, "seq 0 must not advance the session");
        assert!(session.replies.is_empty(), "seq 0 must not populate the reply cache");
    }

    #[test]
    fn append_dropped_delta_feeds_table_stats() {
        let service = tiny_service();
        let mut session = Session::new(0, 1);
        let mut scratch = SampleBatch::default();
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Append {
                actor_id: 0,
                seq: 1,
                dropped: 7,
                steps: vec![step_with_dims(2, 1)],
            },
        );
        assert!(matches!(resp, Response::Appended { consumed: 1, .. }));
        let stats = service.table("replay").unwrap().stats_snapshot();
        assert_eq!(stats.steps_dropped, 7);
        // Replaying the same request must not double-count the delta.
        let resp = dispatch(
            &service,
            &mut session,
            &mut scratch,
            None,
            Request::Append {
                actor_id: 0,
                seq: 1,
                dropped: 7,
                steps: vec![step_with_dims(2, 1)],
            },
        );
        assert!(matches!(resp, Response::Appended { consumed: 1, .. }));
        let stats = service.table("replay").unwrap().stats_snapshot();
        assert_eq!(stats.steps_dropped, 7, "replayed dropped delta must dedupe");
    }

    #[test]
    fn session_registry_resumes_and_expires() {
        let reg = SessionRegistry::new();
        let (slot, resumed) = reg.hello(0, 11);
        assert!(!resumed);
        let id = slot.lock().unwrap().id;
        assert_ne!(id, 0, "minted ids must be nonzero (0 means fresh on the wire)");
        slot.lock().unwrap().next_seq = 42;
        drop(slot); // detach
        // Resuming the same id reattaches the same state.
        let (slot, resumed) = reg.hello(id, 999);
        assert!(resumed);
        assert_eq!(slot.lock().unwrap().next_seq, 42);
        drop(slot);
        // An unknown id (e.g. minted by a previous server boot) binds a
        // fresh session instead of failing.
        let (slot, resumed) = reg.hello(id ^ 0xDEAD_BEEF, 5);
        assert!(!resumed);
        assert_eq!(slot.lock().unwrap().next_seq, 1);
    }
}
