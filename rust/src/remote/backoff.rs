//! Shared reconnect backoff policy for the supervised remote clients.
//!
//! One policy, four users: [`super::RemoteClient`] (blocking reconnect
//! loops), [`super::RemoteWriter`] (non-blocking attempt pacing while
//! the spill queue absorbs steps), [`super::RemoteSampler`], and the
//! coordinator's monitor front. The schedule is exponential with
//! full-decorrelation jitter — each delay is drawn uniformly from
//! `[base/2, base]` where `base = initial · multiplier^attempt`
//! (clamped to `max`) — plus one overall `deadline` after which
//! [`Backoff::next_delay`] returns `None` and the caller surfaces a
//! descriptive "reconnect deadline exceeded" error instead of retrying
//! forever.
//!
//! Jitter is drawn from the crate's seeded [`Rng`], so a test (or the
//! chaos harness) that fixes the seed gets a reproducible retry
//! schedule.

use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// Configuration of one reconnect schedule. `Default` is tuned for a
/// local Unix-socket server: fast first retry, capped at 1 s, giving up
/// after 30 s (override via `--reconnect-deadline`).
#[derive(Clone, Debug)]
pub struct BackoffPolicy {
    /// Base delay of the first retry.
    pub initial: Duration,
    /// Upper clamp on any single delay.
    pub max: Duration,
    /// Growth factor per attempt.
    pub multiplier: f64,
    /// Overall give-up deadline measured from the first failure.
    pub deadline: Duration,
    /// Seed for the jitter stream.
    pub jitter_seed: u64,
}

impl Default for BackoffPolicy {
    fn default() -> Self {
        Self {
            initial: Duration::from_millis(10),
            max: Duration::from_secs(1),
            multiplier: 2.0,
            deadline: Duration::from_secs(30),
            jitter_seed: 0x0BAC_0FF5,
        }
    }
}

impl BackoffPolicy {
    /// The policy with a different overall deadline (the
    /// `--reconnect-deadline` hook).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = deadline;
        self
    }

    /// Start one outage's schedule.
    pub fn start(&self) -> Backoff {
        Backoff {
            policy: self.clone(),
            attempt: 0,
            started: Instant::now(),
            rng: Rng::new(self.jitter_seed),
        }
    }
}

/// One outage's live schedule; create via [`BackoffPolicy::start`],
/// drop (or [`Backoff::reset`]) once reconnected.
pub struct Backoff {
    policy: BackoffPolicy,
    attempt: u32,
    started: Instant,
    rng: Rng,
}

impl Backoff {
    /// The delay to sleep before the next attempt, or `None` once the
    /// overall deadline has passed (give up and report).
    pub fn next_delay(&mut self) -> Option<Duration> {
        let elapsed = self.started.elapsed();
        if elapsed >= self.policy.deadline {
            return None;
        }
        let base = self
            .policy
            .initial
            .as_secs_f64()
            .max(1e-9)
            * self.policy.multiplier.max(1.0).powi(self.attempt as i32);
        let base = base.min(self.policy.max.as_secs_f64());
        // Uniform in [base/2, base]: decorrelates a fleet of clients
        // reconnecting to one restarted server.
        let jittered = base * (0.5 + 0.5 * self.rng.f32() as f64);
        self.attempt = self.attempt.saturating_add(1);
        let remaining = self.policy.deadline - elapsed;
        Some(Duration::from_secs_f64(jittered).min(remaining))
    }

    /// Attempts scheduled so far (for error messages).
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Time since the schedule started.
    pub fn elapsed(&self) -> Duration {
        self.started.elapsed()
    }

    /// The overall deadline this schedule enforces.
    pub fn deadline(&self) -> Duration {
        self.policy.deadline
    }

    /// Restart the schedule (connection healed, then failed again
    /// later: the new outage gets a fresh deadline).
    pub fn reset(&mut self) {
        self.attempt = 0;
        self.started = Instant::now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy_ms(initial: u64, max: u64, deadline: u64) -> BackoffPolicy {
        BackoffPolicy {
            initial: Duration::from_millis(initial),
            max: Duration::from_millis(max),
            multiplier: 2.0,
            deadline: Duration::from_millis(deadline),
            jitter_seed: 42,
        }
    }

    #[test]
    fn delays_grow_exponentially_and_clamp() {
        let mut b = policy_ms(10, 80, 60_000).start();
        let delays: Vec<f64> = (0..8)
            .map(|_| b.next_delay().unwrap().as_secs_f64() * 1_000.0)
            .collect();
        // Each delay lies in [base/2, base] for base = 10·2^k clamped to 80.
        for (k, d) in delays.iter().enumerate() {
            let base = (10.0 * 2f64.powi(k as i32)).min(80.0);
            assert!(
                *d >= base / 2.0 - 1e-6 && *d <= base + 1e-6,
                "attempt {k}: delay {d} ms outside [{}, {base}]",
                base / 2.0
            );
        }
        assert_eq!(b.attempts(), 8);
    }

    #[test]
    fn same_seed_same_schedule() {
        let a: Vec<_> = {
            let mut b = policy_ms(10, 1_000, 60_000).start();
            (0..6).map(|_| b.next_delay().unwrap()).collect()
        };
        let c: Vec<_> = {
            let mut b = policy_ms(10, 1_000, 60_000).start();
            (0..6).map(|_| b.next_delay().unwrap()).collect()
        };
        assert_eq!(a, c);
    }

    #[test]
    fn deadline_exhausts_to_none() {
        let mut b = policy_ms(1, 2, 30).start();
        let mut total = Duration::ZERO;
        let mut gave_up = false;
        for _ in 0..10_000 {
            match b.next_delay() {
                Some(d) => {
                    total += d;
                    std::thread::sleep(d);
                }
                None => {
                    gave_up = true;
                    break;
                }
            }
        }
        assert!(gave_up, "deadline must eventually exhaust");
        assert!(b.elapsed() >= Duration::from_millis(30));
        // No single sleep may overshoot the deadline by more than one
        // clamped delay.
        assert!(total <= Duration::from_millis(40), "slept {total:?}");
    }

    #[test]
    fn reset_restarts_the_deadline() {
        let mut b = policy_ms(1, 1, 25).start();
        while b.next_delay().is_some() {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(b.next_delay().is_none());
        b.reset();
        assert!(b.next_delay().is_some(), "reset must re-arm the schedule");
    }
}
