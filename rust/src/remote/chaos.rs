//! A deterministic chaos proxy for the remote replay protocol: an
//! endpoint-to-endpoint forwarder — Unix socket or TCP on either side —
//! that injects faults — delays, partial writes, connection resets,
//! hard connection kills, and a black-hole mode — between clients and a
//! [`super::ReplayServer`], without either side knowing it is there.
//!
//! This is test infrastructure (the `remote_chaos` soaks and the
//! `pal chaos-smoke` CI restart drill), shipped in the library so the
//! binary's drill and the integration tests share one implementation.
//!
//! # Determinism contract
//!
//! All fault *decisions* are drawn from seeded [`Rng`] streams, never
//! from ambient entropy, and the streams are transport-independent — a
//! TCP proxy with the same seed draws the same verdict sequence as a
//! UDS one:
//!
//! * Connection `i` (1-based accept order) gets two decision streams,
//!   forked from [`ChaosConfig::seed`] as `fork(2·i)` for the
//!   client→server direction and `fork(2·i + 1)` for server→client.
//!   Streams are independent of thread interleaving across
//!   connections.
//! * Within one direction, the `k`-th forwarded chunk always consults
//!   the stream in the same order (reset? → delay? → shred?), so a
//!   fixed seed yields a fixed verdict sequence per (connection,
//!   direction).
//!
//! What the seed does **not** pin down is chunk *boundaries*: the
//! proxy forwards whatever each `read` returns, and the OS may split
//! a stream differently across runs, shifting which byte a given
//! verdict lands on. The guarantee is therefore reproducibility of the
//! fault *mix* (same rates, same per-chunk schedule), not a
//! byte-exact fault script. End-state determinism in the chaos tests
//! comes from the protocol — sessions, sequenced requests, and the
//! server's reply cache make the *outcome* (table contents, stats
//! accounting) independent of where faults land, which is precisely
//! what the tests assert.
//!
//! Faults injected:
//!
//! * **Delay** — with [`ChaosConfig::delay_chance`], sleep a seeded
//!   duration up to [`ChaosConfig::max_delay`] before forwarding a
//!   chunk (exercises RPC timeouts and slow-link pacing).
//! * **Shred (partial writes)** — with [`ChaosConfig::shred_chance`],
//!   forward a chunk in 1–7-byte slices with tiny sleeps in between
//!   (exercises the framing layer's short-read/short-write handling).
//! * **Reset** — with [`ChaosConfig::reset_chance`], drop the
//!   connection mid-stream (both directions shut down; at most
//!   [`ChaosConfig::max_resets`] total so a soak always finishes).
//! * **Kill** — [`ChaosProxy::kill_connections`] hard-drops every
//!   live connection now (the `kill -9` stand-in for a link).
//! * **Black hole** — [`ChaosProxy::set_blackhole`] makes the proxy
//!   accept-and-immediately-close new connections (the
//!   server-unreachable outage; clients see connect-then-dead, their
//!   backoff schedules pace the retries).
//! * **Stall (silent partition)** — [`ChaosProxy::set_stall`] makes
//!   every pump read-and-discard instead of forwarding: connections
//!   stay open and writes succeed, but nothing ever arrives. This is
//!   the nastiest failure for a client — no error, no EOF — and what
//!   forces it to rely on its RPC read timeout (exactly what the mesh
//!   health ladder's Suspect/Down marking is tested against).

use super::transport::{Endpoint, RpcListener, RpcStream};
use crate::util::rng::Rng;
use anyhow::Result;
use std::io::{Read, Write};
use std::net::Shutdown;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Fault rates for one [`ChaosProxy`]. `Default` injects nothing —
/// enable faults explicitly so each test states what it exercises.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Root seed of every decision stream (see the module docs).
    pub seed: u64,
    /// Per-chunk chance of an injected forwarding delay.
    pub delay_chance: f64,
    /// Upper bound on one injected delay (the actual delay is seeded,
    /// uniform in `[0, max_delay]`).
    pub max_delay: Duration,
    /// Per-chunk chance of forwarding in 1–7-byte slices.
    pub shred_chance: f64,
    /// Per-chunk chance of dropping the connection mid-stream.
    pub reset_chance: f64,
    /// Global cap on injected resets (so a soak cannot reset forever).
    pub max_resets: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            seed: 0xC4A0_5EED,
            delay_chance: 0.0,
            max_delay: Duration::from_millis(5),
            shred_chance: 0.0,
            reset_chance: 0.0,
            max_resets: u64::MAX,
        }
    }
}

/// One live proxied connection: both stream halves (kept so a kill can
/// shut them down from outside the pump threads) plus its kill flag.
struct Conn {
    client: RpcStream,
    server: RpcStream,
    dead: Arc<AtomicBool>,
}

impl Conn {
    fn kill(&self) {
        self.dead.store(true, Ordering::Relaxed);
        let _ = self.client.shutdown(Shutdown::Both);
        let _ = self.server.shutdown(Shutdown::Both);
    }
}

/// State shared between the accept loop, the pump threads, and the
/// test-facing handle.
struct Shared {
    cfg: ChaosConfig,
    stop: AtomicBool,
    blackhole: AtomicBool,
    stall: AtomicBool,
    resets: AtomicU64,
    conns: Mutex<Vec<Conn>>,
}

/// A running chaos proxy; construct with [`ChaosProxy::start`] (UDS
/// paths) or [`ChaosProxy::start_endpoints`] (either transport on
/// either side), point clients at [`ChaosProxy::listen_endpoint`].
/// Dropping the handle stops the accept loop, kills live connections,
/// and removes a UDS listen socket.
pub struct ChaosProxy {
    shared: Arc<Shared>,
    listen: Endpoint,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ChaosProxy {
    /// Bind the Unix socket `listen_path` and forward each accepted
    /// connection to the replay server at `upstream`, injecting faults
    /// per `cfg` (the original all-UDS form; see
    /// [`Self::start_endpoints`] for TCP).
    pub fn start(
        upstream: impl AsRef<Path>,
        listen_path: impl AsRef<Path>,
        cfg: ChaosConfig,
    ) -> Result<Self> {
        Self::start_endpoints(
            &Endpoint::from(upstream.as_ref()),
            &Endpoint::from(listen_path.as_ref()),
            cfg,
        )
    }

    /// Bind `listen` and forward each accepted connection to the replay
    /// server at `upstream`, injecting faults per `cfg`. Either side
    /// may be UDS or TCP (they need not match — the proxy is also a
    /// transport bridge); a TCP `:0` listen reports its resolved port
    /// via [`Self::listen_endpoint`].
    pub fn start_endpoints(
        upstream: &Endpoint,
        listen: &Endpoint,
        cfg: ChaosConfig,
    ) -> Result<Self> {
        let listener = RpcListener::bind(listen)?;
        let listen = listener.endpoint();
        let shared = Arc::new(Shared {
            cfg,
            stop: AtomicBool::new(false),
            blackhole: AtomicBool::new(false),
            stall: AtomicBool::new(false),
            resets: AtomicU64::new(0),
            conns: Mutex::new(Vec::new()),
        });
        let accept_shared = Arc::clone(&shared);
        let upstream = upstream.clone();
        let accept_thread = std::thread::spawn(move || {
            accept_loop(listener, upstream, accept_shared);
        });
        Ok(Self { shared, listen, accept_thread: Some(accept_thread) })
    }

    /// The endpoint clients should dial instead of the real server's
    /// (for a TCP `:0` bind, the resolved address).
    pub fn listen_endpoint(&self) -> &Endpoint {
        &self.listen
    }

    /// The socket path clients should dial, for the UDS form.
    ///
    /// # Panics
    /// On a TCP proxy — use [`Self::listen_endpoint`] there.
    pub fn listen_path(&self) -> &Path {
        match &self.listen {
            Endpoint::Uds(p) => p,
            Endpoint::Tcp(a) => panic!("chaos proxy listens on tcp://{a}, not a socket path"),
        }
    }

    /// Total connection resets injected so far (seeded resets plus
    /// [`Self::kill_connections`] victims).
    pub fn resets_injected(&self) -> u64 {
        self.shared.resets.load(Ordering::Relaxed)
    }

    /// Switch the server-unreachable mode: while on, new connections
    /// are accepted and immediately closed. Existing connections are
    /// untouched — pair with [`Self::kill_connections`] for a full
    /// outage.
    pub fn set_blackhole(&self, on: bool) {
        self.shared.blackhole.store(on, Ordering::Relaxed);
    }

    /// Switch the silent-partition mode: while on, every pump reads and
    /// discards instead of forwarding, in both directions. Connections
    /// stay open and writes succeed, but no byte ever crosses — the
    /// failure only an RPC read timeout can detect. Existing and new
    /// connections are both affected; switching it off resumes
    /// forwarding (bytes swallowed while stalled are gone, like any
    /// partition).
    pub fn set_stall(&self, on: bool) {
        self.shared.stall.store(on, Ordering::Relaxed);
    }

    /// Hard-drop every live proxied connection right now; returns how
    /// many were killed.
    pub fn kill_connections(&self) -> usize {
        let mut conns = self.shared.conns.lock().expect("chaos connection list poisoned");
        let mut killed = 0;
        for c in conns.iter() {
            if !c.dead.load(Ordering::Relaxed) {
                c.kill();
                killed += 1;
            }
        }
        self.shared.resets.fetch_add(killed as u64, Ordering::Relaxed);
        conns.retain(|c| !c.dead.load(Ordering::Relaxed));
        killed
    }

    /// Stop the accept loop, kill live connections, remove a UDS listen
    /// socket. Also what `Drop` does; explicit form for tests that want
    /// to simulate the proxy process dying.
    pub fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.kill_connections();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Endpoint::Uds(path) = &self.listen {
            let _ = std::fs::remove_file(path);
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: RpcListener, upstream: Endpoint, shared: Arc<Shared>) {
    let mut conn_id = 0u64;
    // One root stream per proxy; each connection forks its two
    // direction streams from it by id, so decision streams are fixed
    // by (seed, accept order) alone — on either transport.
    let mut root = Rng::new(shared.cfg.seed);
    while !shared.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok(client) => {
                if shared.blackhole.load(Ordering::Relaxed) {
                    drop(client); // accept-then-vanish: the outage mode
                    continue;
                }
                conn_id += 1;
                let _ = client.set_nonblocking(false);
                let server = match upstream.dial() {
                    Ok(s) => s,
                    Err(_) => {
                        drop(client); // upstream gone: behave like it
                        continue;
                    }
                };
                let dead = Arc::new(AtomicBool::new(false));
                let c2s = Rng::new(root.next_u64()).fork(2 * conn_id);
                let s2c = Rng::new(root.next_u64()).fork(2 * conn_id + 1);
                spawn_pumps(&shared, &client, &server, &dead, c2s, s2c);
                let mut conns = shared.conns.lock().expect("chaos connection list poisoned");
                // Opportunistic sweep so a long soak's list stays small.
                conns.retain(|c| !c.dead.load(Ordering::Relaxed));
                conns.push(Conn { client, server, dead });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(_) => break,
        }
    }
    listener.cleanup();
}

fn spawn_pumps(
    shared: &Arc<Shared>,
    client: &RpcStream,
    server: &RpcStream,
    dead: &Arc<AtomicBool>,
    c2s_rng: Rng,
    s2c_rng: Rng,
) {
    for (rng, from, to) in [
        (c2s_rng, client.try_clone(), server.try_clone()),
        (s2c_rng, server.try_clone(), client.try_clone()),
    ] {
        let (from, to) = match (from, to) {
            (Ok(f), Ok(t)) => (f, t),
            _ => {
                dead.store(true, Ordering::Relaxed);
                return;
            }
        };
        let shared = Arc::clone(shared);
        let dead = Arc::clone(dead);
        std::thread::spawn(move || pump(shared, from, to, dead, rng));
    }
}

/// Forward one direction chunk by chunk, consulting the seeded stream
/// in a fixed order per chunk: reset? → delay? → shred?.
fn pump(
    shared: Arc<Shared>,
    mut from: RpcStream,
    mut to: RpcStream,
    dead: Arc<AtomicBool>,
    mut rng: Rng,
) {
    // A short read timeout so the pump notices kill/stop flags even
    // when the link is idle.
    let _ = from.set_read_timeout(Some(Duration::from_millis(25)));
    let mut buf = [0u8; 4096];
    loop {
        if dead.load(Ordering::Relaxed) || shared.stop.load(Ordering::Relaxed) {
            break;
        }
        let n = match from.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        };
        // Silent partition: swallow the chunk before any seeded
        // verdict, so toggling stall never shifts the decision
        // streams of chunks that do get forwarded later.
        if shared.stall.load(Ordering::Relaxed) {
            continue;
        }
        // Decision order per chunk is part of the determinism contract.
        let reset = rng.chance(shared.cfg.reset_chance);
        let delay = rng.chance(shared.cfg.delay_chance);
        let shred = rng.chance(shared.cfg.shred_chance);
        if reset && try_claim_reset(&shared) {
            dead.store(true, Ordering::Relaxed);
            let _ = from.shutdown(Shutdown::Both);
            let _ = to.shutdown(Shutdown::Both);
            break;
        }
        if delay {
            let frac = rng.f64();
            std::thread::sleep(shared.cfg.max_delay.mul_f64(frac));
        }
        let write = if shred {
            write_shredded(&mut to, &buf[..n], &mut rng)
        } else {
            to.write_all(&buf[..n]).and_then(|()| to.flush())
        };
        if write.is_err() {
            break;
        }
    }
    let _ = from.shutdown(Shutdown::Both);
    let _ = to.shutdown(Shutdown::Both);
}

/// Claim one of the bounded reset slots; false once the cap is spent.
fn try_claim_reset(shared: &Shared) -> bool {
    let mut cur = shared.resets.load(Ordering::Relaxed);
    loop {
        if cur >= shared.cfg.max_resets {
            return false;
        }
        match shared.resets.compare_exchange(cur, cur + 1, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
}

/// Forward a chunk in seeded 1–7-byte slices with microsleeps between
/// them — the torn-write torture for the framing layer.
fn write_shredded(to: &mut RpcStream, chunk: &[u8], rng: &mut Rng) -> std::io::Result<()> {
    let mut off = 0;
    while off < chunk.len() {
        let piece = 1 + rng.below(7) as usize;
        let end = (off + piece).min(chunk.len());
        to.write_all(&chunk[off..end])?;
        to.flush()?;
        off = end;
        std::thread::sleep(Duration::from_micros(200));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A trivial upstream echo server on either transport: reads
    /// chunks, writes them back. Returns the resolved endpoint.
    fn spawn_echo(
        endpoint: &Endpoint,
        stop: Arc<AtomicBool>,
    ) -> (Endpoint, std::thread::JoinHandle<()>) {
        let listener = RpcListener::bind(endpoint).expect("bind echo");
        let resolved = listener.endpoint();
        let handle = std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok(mut s) => {
                        let _ = s.set_nonblocking(false);
                        let _ = s.set_read_timeout(Some(Duration::from_millis(25)));
                        let stop = Arc::clone(&stop);
                        std::thread::spawn(move || {
                            let mut buf = [0u8; 1024];
                            loop {
                                if stop.load(Ordering::Relaxed) {
                                    break;
                                }
                                match s.read(&mut buf) {
                                    Ok(0) => break,
                                    Ok(n) => {
                                        if s.write_all(&buf[..n]).is_err() {
                                            break;
                                        }
                                    }
                                    Err(e)
                                        if e.kind() == std::io::ErrorKind::WouldBlock
                                            || e.kind() == std::io::ErrorKind::TimedOut =>
                                    {
                                        continue
                                    }
                                    Err(_) => break,
                                }
                            }
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
            listener.cleanup();
        });
        (resolved, handle)
    }

    fn echo_roundtrip_through(proxy: &ChaosProxy) {
        let mut c = proxy.listen_endpoint().dial().expect("connect");
        let msg = b"the chaos proxy must not corrupt payload bytes";
        c.write_all(msg).expect("write");
        let mut got = vec![0u8; msg.len()];
        c.read_exact(&mut got).expect("read back");
        assert_eq!(&got, msg);
    }

    #[test]
    fn forwards_bytes_transparently_even_when_shredding() {
        let dir = std::env::temp_dir().join(format!("pal_chaos_fwd_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let stop = Arc::new(AtomicBool::new(false));
        let (up, echo) =
            spawn_echo(&Endpoint::Uds(dir.join("up.sock")), Arc::clone(&stop));
        let proxy = ChaosProxy::start_endpoints(
            &up,
            &Endpoint::Uds(dir.join("proxy.sock")),
            ChaosConfig { shred_chance: 1.0, ..ChaosConfig::default() },
        )
        .expect("start proxy");
        echo_roundtrip_through(&proxy);
        drop(proxy);
        stop.store(true, Ordering::Relaxed);
        echo.join().expect("echo thread");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tcp_proxy_forwards_and_reports_resolved_port() {
        // TCP on both sides, both bound to ephemeral ports: the proxy
        // must report where it actually listens, and the same shredding
        // contract must hold byte-for-byte.
        let stop = Arc::new(AtomicBool::new(false));
        let (up, echo) =
            spawn_echo(&Endpoint::Tcp("127.0.0.1:0".into()), Arc::clone(&stop));
        let proxy = ChaosProxy::start_endpoints(
            &up,
            &Endpoint::Tcp("127.0.0.1:0".into()),
            ChaosConfig { shred_chance: 1.0, ..ChaosConfig::default() },
        )
        .expect("start proxy");
        match proxy.listen_endpoint() {
            Endpoint::Tcp(a) => assert!(!a.ends_with(":0"), "unresolved listen address {a}"),
            other => panic!("tcp proxy reported {other:?}"),
        }
        echo_roundtrip_through(&proxy);
        drop(proxy);
        stop.store(true, Ordering::Relaxed);
        echo.join().expect("echo thread");
    }

    #[test]
    fn blackhole_and_kill_sever_clients() {
        let dir = std::env::temp_dir().join(format!("pal_chaos_kill_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let stop = Arc::new(AtomicBool::new(false));
        let (up, echo) =
            spawn_echo(&Endpoint::Uds(dir.join("up.sock")), Arc::clone(&stop));
        let proxy = ChaosProxy::start_endpoints(
            &up,
            &Endpoint::Uds(dir.join("proxy.sock")),
            ChaosConfig::default(),
        )
        .expect("start proxy");

        // A live connection echoes...
        let mut c = proxy.listen_endpoint().dial().expect("connect");
        c.write_all(b"ping").expect("write");
        let mut got = [0u8; 4];
        c.read_exact(&mut got).expect("read");
        // ...until killed: the next read sees EOF or an error.
        assert_eq!(proxy.kill_connections(), 1);
        let mut buf = [0u8; 1];
        match c.read(&mut buf) {
            Ok(0) | Err(_) => {}
            Ok(n) => panic!("killed connection still delivered {n} byte(s)"),
        }
        assert_eq!(proxy.resets_injected(), 1);

        // Black hole: connects succeed, then the socket is dead.
        proxy.set_blackhole(true);
        let mut c2 = proxy.listen_endpoint().dial().expect("connect during blackhole");
        let _ = c2.set_read_timeout(Some(Duration::from_millis(500)));
        let _ = c2.write_all(b"hello?");
        match c2.read(&mut buf) {
            Ok(0) | Err(_) => {}
            Ok(n) => panic!("blackholed connection delivered {n} byte(s)"),
        }

        drop(proxy);
        stop.store(true, Ordering::Relaxed);
        echo.join().expect("echo thread");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stall_swallows_traffic_until_cleared() {
        let dir = std::env::temp_dir().join(format!("pal_chaos_stall_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let stop = Arc::new(AtomicBool::new(false));
        let (up, echo) =
            spawn_echo(&Endpoint::Uds(dir.join("up.sock")), Arc::clone(&stop));
        let proxy = ChaosProxy::start_endpoints(
            &up,
            &Endpoint::Uds(dir.join("proxy.sock")),
            ChaosConfig::default(),
        )
        .expect("start proxy");

        // Silent partition: the write succeeds, nothing ever comes back
        // — only the read timeout notices.
        proxy.set_stall(true);
        let mut c = proxy.listen_endpoint().dial().expect("connect");
        let _ = c.set_read_timeout(Some(Duration::from_millis(200)));
        c.write_all(b"lost").expect("write into the partition succeeds");
        let mut buf = [0u8; 4];
        match c.read(&mut buf) {
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut => {}
            other => panic!("stalled read should time out, got {other:?}"),
        }

        // Clearing the stall resumes forwarding on the SAME connection;
        // the swallowed bytes are gone for good.
        proxy.set_stall(false);
        let _ = c.set_read_timeout(Some(Duration::from_secs(5)));
        c.write_all(b"ping").expect("write");
        let mut got = [0u8; 4];
        c.read_exact(&mut got).expect("read after clearing the stall");
        assert_eq!(&got, b"ping");

        drop(proxy);
        stop.store(true, Ordering::Relaxed);
        echo.join().expect("echo thread");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn same_seed_same_decision_stream() {
        // The contract is about the decision stream, not socket timing:
        // replay the per-chunk verdict sequence directly.
        let verdicts = |seed: u64| -> Vec<(bool, bool, bool)> {
            let mut root = Rng::new(seed);
            let mut rng = Rng::new(root.next_u64()).fork(2);
            (0..64)
                .map(|_| (rng.chance(0.1), rng.chance(0.3), rng.chance(0.5)))
                .collect()
        };
        assert_eq!(verdicts(7), verdicts(7));
        assert_ne!(verdicts(7), verdicts(8), "different seeds must differ somewhere");
    }

    #[test]
    fn uds_listen_path_still_exposed() {
        let dir = std::env::temp_dir().join(format!("pal_chaos_path_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let up = dir.join("up.sock"); // never dialed: no traffic flows
        let proxy = ChaosProxy::start(&up, dir.join("proxy.sock"), ChaosConfig::default())
            .expect("start proxy");
        assert_eq!(proxy.listen_path(), dir.join("proxy.sock"));
        drop(proxy);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[should_panic(expected = "not a socket path")]
    fn listen_path_panics_on_tcp() {
        let proxy = ChaosProxy::start_endpoints(
            &Endpoint::Tcp("127.0.0.1:1".into()),
            &Endpoint::Tcp("127.0.0.1:0".into()),
            ChaosConfig::default(),
        )
        .expect("start proxy");
        let _ = proxy.listen_path();
    }
}
