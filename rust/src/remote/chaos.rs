//! A deterministic chaos proxy for the remote replay protocol: a
//! Unix-socket-to-Unix-socket forwarder that injects faults — delays,
//! partial writes, connection resets, hard connection kills, and a
//! black-hole mode — between clients and a [`super::ReplayServer`],
//! without either side knowing it is there.
//!
//! This is test infrastructure (the `remote_chaos` soaks and the
//! `pal chaos-smoke` CI restart drill), shipped in the library so the
//! binary's drill and the integration tests share one implementation.
//!
//! # Determinism contract
//!
//! All fault *decisions* are drawn from seeded [`Rng`] streams, never
//! from ambient entropy:
//!
//! * Connection `i` (1-based accept order) gets two decision streams,
//!   forked from [`ChaosConfig::seed`] as `fork(2·i)` for the
//!   client→server direction and `fork(2·i + 1)` for server→client.
//!   Streams are independent of thread interleaving across
//!   connections.
//! * Within one direction, the `k`-th forwarded chunk always consults
//!   the stream in the same order (reset? → delay? → shred?), so a
//!   fixed seed yields a fixed verdict sequence per (connection,
//!   direction).
//!
//! What the seed does **not** pin down is chunk *boundaries*: the
//! proxy forwards whatever each `read` returns, and the OS may split
//! a stream differently across runs, shifting which byte a given
//! verdict lands on. The guarantee is therefore reproducibility of the
//! fault *mix* (same rates, same per-chunk schedule), not a
//! byte-exact fault script. End-state determinism in the chaos tests
//! comes from the protocol — sessions, sequenced requests, and the
//! server's reply cache make the *outcome* (table contents, stats
//! accounting) independent of where faults land, which is precisely
//! what the tests assert.
//!
//! Faults injected:
//!
//! * **Delay** — with [`ChaosConfig::delay_chance`], sleep a seeded
//!   duration up to [`ChaosConfig::max_delay`] before forwarding a
//!   chunk (exercises RPC timeouts and slow-link pacing).
//! * **Shred (partial writes)** — with [`ChaosConfig::shred_chance`],
//!   forward a chunk in 1–7-byte slices with tiny sleeps in between
//!   (exercises the framing layer's short-read/short-write handling).
//! * **Reset** — with [`ChaosConfig::reset_chance`], drop the
//!   connection mid-stream (both directions shut down; at most
//!   [`ChaosConfig::max_resets`] total so a soak always finishes).
//! * **Kill** — [`ChaosProxy::kill_connections`] hard-drops every
//!   live connection now (the `kill -9` stand-in for a link).
//! * **Black hole** — [`ChaosProxy::set_blackhole`] makes the proxy
//!   accept-and-immediately-close new connections (the
//!   server-unreachable outage; clients see connect-then-dead, their
//!   backoff schedules pace the retries).

use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Fault rates for one [`ChaosProxy`]. `Default` injects nothing —
/// enable faults explicitly so each test states what it exercises.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Root seed of every decision stream (see the module docs).
    pub seed: u64,
    /// Per-chunk chance of an injected forwarding delay.
    pub delay_chance: f64,
    /// Upper bound on one injected delay (the actual delay is seeded,
    /// uniform in `[0, max_delay]`).
    pub max_delay: Duration,
    /// Per-chunk chance of forwarding in 1–7-byte slices.
    pub shred_chance: f64,
    /// Per-chunk chance of dropping the connection mid-stream.
    pub reset_chance: f64,
    /// Global cap on injected resets (so a soak cannot reset forever).
    pub max_resets: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            seed: 0xC4A0_5EED,
            delay_chance: 0.0,
            max_delay: Duration::from_millis(5),
            shred_chance: 0.0,
            reset_chance: 0.0,
            max_resets: u64::MAX,
        }
    }
}

/// One live proxied connection: both stream halves (kept so a kill can
/// shut them down from outside the pump threads) plus its kill flag.
struct Conn {
    client: UnixStream,
    server: UnixStream,
    dead: Arc<AtomicBool>,
}

impl Conn {
    fn kill(&self) {
        self.dead.store(true, Ordering::Relaxed);
        let _ = self.client.shutdown(std::net::Shutdown::Both);
        let _ = self.server.shutdown(std::net::Shutdown::Both);
    }
}

/// State shared between the accept loop, the pump threads, and the
/// test-facing handle.
struct Shared {
    cfg: ChaosConfig,
    stop: AtomicBool,
    blackhole: AtomicBool,
    resets: AtomicU64,
    conns: Mutex<Vec<Conn>>,
}

/// A running chaos proxy; construct with [`ChaosProxy::start`], point
/// clients at [`ChaosProxy::listen_path`]. Dropping the handle stops
/// the accept loop, kills live connections, and removes the socket.
pub struct ChaosProxy {
    shared: Arc<Shared>,
    listen_path: PathBuf,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ChaosProxy {
    /// Bind `listen_path` and forward each accepted connection to the
    /// replay server at `upstream`, injecting faults per `cfg`.
    pub fn start(
        upstream: impl AsRef<Path>,
        listen_path: impl AsRef<Path>,
        cfg: ChaosConfig,
    ) -> Result<Self> {
        let upstream = upstream.as_ref().to_path_buf();
        let listen_path = listen_path.as_ref().to_path_buf();
        if listen_path.exists() {
            std::fs::remove_file(&listen_path).with_context(|| {
                format!("removing stale chaos socket {}", listen_path.display())
            })?;
        }
        let listener = UnixListener::bind(&listen_path)
            .with_context(|| format!("binding chaos proxy socket {}", listen_path.display()))?;
        listener
            .set_nonblocking(true)
            .context("setting the chaos listener non-blocking")?;
        let shared = Arc::new(Shared {
            cfg,
            stop: AtomicBool::new(false),
            blackhole: AtomicBool::new(false),
            resets: AtomicU64::new(0),
            conns: Mutex::new(Vec::new()),
        });
        let accept_shared = Arc::clone(&shared);
        let accept_thread = std::thread::spawn(move || {
            accept_loop(listener, upstream, accept_shared);
        });
        Ok(Self { shared, listen_path, accept_thread: Some(accept_thread) })
    }

    /// The socket clients should dial instead of the real server's.
    pub fn listen_path(&self) -> &Path {
        &self.listen_path
    }

    /// Total connection resets injected so far (seeded resets plus
    /// [`Self::kill_connections`] victims).
    pub fn resets_injected(&self) -> u64 {
        self.shared.resets.load(Ordering::Relaxed)
    }

    /// Switch the server-unreachable mode: while on, new connections
    /// are accepted and immediately closed. Existing connections are
    /// untouched — pair with [`Self::kill_connections`] for a full
    /// outage.
    pub fn set_blackhole(&self, on: bool) {
        self.shared.blackhole.store(on, Ordering::Relaxed);
    }

    /// Hard-drop every live proxied connection right now; returns how
    /// many were killed.
    pub fn kill_connections(&self) -> usize {
        let mut conns = self.shared.conns.lock().expect("chaos connection list poisoned");
        let mut killed = 0;
        for c in conns.iter() {
            if !c.dead.load(Ordering::Relaxed) {
                c.kill();
                killed += 1;
            }
        }
        self.shared.resets.fetch_add(killed as u64, Ordering::Relaxed);
        conns.retain(|c| !c.dead.load(Ordering::Relaxed));
        killed
    }

    /// Stop the accept loop, kill live connections, remove the socket.
    /// Also what `Drop` does; explicit form for tests that want to
    /// simulate the proxy process dying.
    pub fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        self.kill_connections();
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        let _ = std::fs::remove_file(&self.listen_path);
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: UnixListener, upstream: PathBuf, shared: Arc<Shared>) {
    let mut conn_id = 0u64;
    // One root stream per proxy; each connection forks its two
    // direction streams from it by id, so decision streams are fixed
    // by (seed, accept order) alone.
    let mut root = Rng::new(shared.cfg.seed);
    while !shared.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((client, _addr)) => {
                if shared.blackhole.load(Ordering::Relaxed) {
                    drop(client); // accept-then-vanish: the outage mode
                    continue;
                }
                conn_id += 1;
                let _ = client.set_nonblocking(false);
                let server = match UnixStream::connect(&upstream) {
                    Ok(s) => s,
                    Err(_) => {
                        drop(client); // upstream gone: behave like it
                        continue;
                    }
                };
                let dead = Arc::new(AtomicBool::new(false));
                let c2s = Rng::new(root.next_u64()).fork(2 * conn_id);
                let s2c = Rng::new(root.next_u64()).fork(2 * conn_id + 1);
                spawn_pumps(&shared, &client, &server, &dead, c2s, s2c);
                let mut conns = shared.conns.lock().expect("chaos connection list poisoned");
                // Opportunistic sweep so a long soak's list stays small.
                conns.retain(|c| !c.dead.load(Ordering::Relaxed));
                conns.push(Conn { client, server, dead });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(_) => break,
        }
    }
}

fn spawn_pumps(
    shared: &Arc<Shared>,
    client: &UnixStream,
    server: &UnixStream,
    dead: &Arc<AtomicBool>,
    c2s_rng: Rng,
    s2c_rng: Rng,
) {
    for (rng, from, to) in [
        (c2s_rng, client.try_clone(), server.try_clone()),
        (s2c_rng, server.try_clone(), client.try_clone()),
    ] {
        let (from, to) = match (from, to) {
            (Ok(f), Ok(t)) => (f, t),
            _ => {
                dead.store(true, Ordering::Relaxed);
                return;
            }
        };
        let shared = Arc::clone(shared);
        let dead = Arc::clone(dead);
        std::thread::spawn(move || pump(shared, from, to, dead, rng));
    }
}

/// Forward one direction chunk by chunk, consulting the seeded stream
/// in a fixed order per chunk: reset? → delay? → shred?.
fn pump(
    shared: Arc<Shared>,
    mut from: UnixStream,
    mut to: UnixStream,
    dead: Arc<AtomicBool>,
    mut rng: Rng,
) {
    // A short read timeout so the pump notices kill/stop flags even
    // when the link is idle.
    let _ = from.set_read_timeout(Some(Duration::from_millis(25)));
    let mut buf = [0u8; 4096];
    loop {
        if dead.load(Ordering::Relaxed) || shared.stop.load(Ordering::Relaxed) {
            break;
        }
        let n = match from.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        };
        // Decision order per chunk is part of the determinism contract.
        let reset = rng.chance(shared.cfg.reset_chance);
        let delay = rng.chance(shared.cfg.delay_chance);
        let shred = rng.chance(shared.cfg.shred_chance);
        if reset && try_claim_reset(&shared) {
            dead.store(true, Ordering::Relaxed);
            let _ = from.shutdown(std::net::Shutdown::Both);
            let _ = to.shutdown(std::net::Shutdown::Both);
            break;
        }
        if delay {
            let frac = rng.f64();
            std::thread::sleep(shared.cfg.max_delay.mul_f64(frac));
        }
        let write = if shred {
            write_shredded(&mut to, &buf[..n], &mut rng)
        } else {
            to.write_all(&buf[..n]).and_then(|()| to.flush())
        };
        if write.is_err() {
            break;
        }
    }
    let _ = from.shutdown(std::net::Shutdown::Both);
    let _ = to.shutdown(std::net::Shutdown::Both);
}

/// Claim one of the bounded reset slots; false once the cap is spent.
fn try_claim_reset(shared: &Shared) -> bool {
    let mut cur = shared.resets.load(Ordering::Relaxed);
    loop {
        if cur >= shared.cfg.max_resets {
            return false;
        }
        match shared.resets.compare_exchange(cur, cur + 1, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(now) => cur = now,
        }
    }
}

/// Forward a chunk in seeded 1–7-byte slices with microsleeps between
/// them — the torn-write torture for the framing layer.
fn write_shredded(to: &mut UnixStream, chunk: &[u8], rng: &mut Rng) -> std::io::Result<()> {
    let mut off = 0;
    while off < chunk.len() {
        let piece = 1 + rng.below(7) as usize;
        let end = (off + piece).min(chunk.len());
        to.write_all(&chunk[off..end])?;
        to.flush()?;
        off = end;
        std::thread::sleep(Duration::from_micros(200));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    fn sock(dir: &std::path::Path, name: &str) -> PathBuf {
        dir.join(name)
    }

    /// A trivial upstream echo server: reads chunks, writes them back.
    fn spawn_echo(path: PathBuf, stop: Arc<AtomicBool>) -> std::thread::JoinHandle<()> {
        let listener = UnixListener::bind(&path).expect("bind echo");
        listener.set_nonblocking(true).expect("nonblocking echo");
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((mut s, _)) => {
                        let _ = s.set_nonblocking(false);
                        let _ = s.set_read_timeout(Some(Duration::from_millis(25)));
                        let stop = Arc::clone(&stop);
                        std::thread::spawn(move || {
                            let mut buf = [0u8; 1024];
                            loop {
                                if stop.load(Ordering::Relaxed) {
                                    break;
                                }
                                match s.read(&mut buf) {
                                    Ok(0) => break,
                                    Ok(n) => {
                                        if s.write_all(&buf[..n]).is_err() {
                                            break;
                                        }
                                    }
                                    Err(e)
                                        if e.kind() == std::io::ErrorKind::WouldBlock
                                            || e.kind() == std::io::ErrorKind::TimedOut =>
                                    {
                                        continue
                                    }
                                    Err(_) => break,
                                }
                            }
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            }
            let _ = std::fs::remove_file(&path);
        })
    }

    #[test]
    fn forwards_bytes_transparently_even_when_shredding() {
        let dir = std::env::temp_dir().join(format!("pal_chaos_fwd_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let up = sock(&dir, "up.sock");
        let stop = Arc::new(AtomicBool::new(false));
        let echo = spawn_echo(up.clone(), Arc::clone(&stop));
        let proxy = ChaosProxy::start(
            &up,
            sock(&dir, "proxy.sock"),
            ChaosConfig { shred_chance: 1.0, ..ChaosConfig::default() },
        )
        .expect("start proxy");

        let mut c = UnixStream::connect(proxy.listen_path()).expect("connect");
        let msg = b"the chaos proxy must not corrupt payload bytes";
        c.write_all(msg).expect("write");
        let mut got = vec![0u8; msg.len()];
        c.read_exact(&mut got).expect("read back");
        assert_eq!(&got, msg);

        drop(c);
        drop(proxy);
        stop.store(true, Ordering::Relaxed);
        echo.join().expect("echo thread");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn blackhole_and_kill_sever_clients() {
        let dir = std::env::temp_dir().join(format!("pal_chaos_kill_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("mkdir");
        let up = sock(&dir, "up.sock");
        let stop = Arc::new(AtomicBool::new(false));
        let echo = spawn_echo(up.clone(), Arc::clone(&stop));
        let proxy = ChaosProxy::start(&up, sock(&dir, "proxy.sock"), ChaosConfig::default())
            .expect("start proxy");

        // A live connection echoes...
        let mut c = UnixStream::connect(proxy.listen_path()).expect("connect");
        c.write_all(b"ping").expect("write");
        let mut got = [0u8; 4];
        c.read_exact(&mut got).expect("read");
        // ...until killed: the next read sees EOF or an error.
        assert_eq!(proxy.kill_connections(), 1);
        let mut buf = [0u8; 1];
        match c.read(&mut buf) {
            Ok(0) | Err(_) => {}
            Ok(n) => panic!("killed connection still delivered {n} byte(s)"),
        }
        assert_eq!(proxy.resets_injected(), 1);

        // Black hole: connects succeed, then the socket is dead.
        proxy.set_blackhole(true);
        let mut c2 = UnixStream::connect(proxy.listen_path()).expect("connect during blackhole");
        let _ = c2.set_read_timeout(Some(Duration::from_millis(500)));
        let _ = c2.write_all(b"hello?");
        match c2.read(&mut buf) {
            Ok(0) | Err(_) => {}
            Ok(n) => panic!("blackholed connection delivered {n} byte(s)"),
        }

        drop(proxy);
        stop.store(true, Ordering::Relaxed);
        echo.join().expect("echo thread");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn same_seed_same_decision_stream() {
        // The contract is about the decision stream, not socket timing:
        // replay the per-chunk verdict sequence directly.
        let verdicts = |seed: u64| -> Vec<(bool, bool, bool)> {
            let mut root = Rng::new(seed);
            let mut rng = Rng::new(root.next_u64()).fork(2);
            (0..64)
                .map(|_| (rng.chance(0.1), rng.chance(0.3), rng.chance(0.5)))
                .collect()
        };
        assert_eq!(verdicts(7), verdicts(7));
        assert_ne!(verdicts(7), verdicts(8), "different seeds must differ somewhere");
    }
}
