//! Client side of the remote replay protocol: a low-level
//! [`RemoteClient`] (one frame in, one frame out) plus the
//! [`RemoteWriter`] / [`RemoteSampler`] handles that mirror the
//! in-process [`TrajectoryWriter`] / [`SamplerHandle`] APIs through
//! the [`ExperienceWriter`] / [`ExperienceSampler`] traits — the
//! actor and learner loops cannot tell which side of the socket their
//! tables live on.
//!
//! Rate-limiter semantics are preserved across the wire without ever
//! blocking the connection: a stalled insert comes back as a short
//! `Appended` frame (the un-admitted steps stay queued client-side and
//! are retried by the actor's normal `throttled()` poll), a stalled
//! sample as a retriable `WouldStall` frame the learner sleep-polls,
//! exactly like the in-process outcomes.

use super::frame::{read_frame, write_frame};
use super::proto::{Request, Response, StallReason, TableInfo};
use crate::replay::SampleBatch;
use crate::service::{
    ExperienceSampler, ExperienceWriter, SampleOutcome, ServiceState, WriterStep,
};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::os::unix::net::UnixStream;
use std::path::Path;
use std::time::Duration;

/// How long one RPC may stay silent before the client gives up. The
/// server never blocks on a rate limiter (stalls come back as
/// immediate `WouldStall`/short-`Appended` frames), so a long silence
/// means a wedged or dead server — erroring lets the worker loops
/// stop the run instead of hanging past `ctl.request_stop`. Sized for
/// the slowest legitimate RPC (a multi-hundred-MiB `Checkpoint`).
const RPC_TIMEOUT: Duration = Duration::from_secs(120);

/// One connection to a [`super::ReplayServer`]; a thin call/response
/// wrapper plus typed helpers for every RPC.
pub struct RemoteClient {
    stream: UnixStream,
}

impl RemoteClient {
    pub fn connect(path: impl AsRef<Path>) -> Result<Self> {
        let stream = UnixStream::connect(path.as_ref()).with_context(|| {
            format!("connecting to replay server at {}", path.as_ref().display())
        })?;
        stream
            .set_read_timeout(Some(RPC_TIMEOUT))
            .context("setting the RPC read timeout")?;
        stream
            .set_write_timeout(Some(RPC_TIMEOUT))
            .context("setting the RPC write timeout")?;
        Ok(Self { stream })
    }

    /// One request, one response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.stream, &req.encode())?;
        match read_frame(&mut self.stream)? {
            None => bail!("replay server closed the connection mid-call"),
            Some(payload) => Response::decode(&payload),
        }
    }

    /// As [`Self::call`], but a `Response::Error` becomes an `Err`.
    fn call_checked(&mut self, req: &Request) -> Result<Response> {
        match self.call(req)? {
            Response::Error { message } => bail!("replay server error: {message}"),
            resp => Ok(resp),
        }
    }

    /// Seed this connection's server-side sampling RNG.
    pub fn hello(&mut self, rng_seed: u64) -> Result<()> {
        match self.call_checked(&Request::Hello { rng_seed })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response to Hello: {other:?}"),
        }
    }

    /// Append steps for one actor; returns `(consumed, emitted)`. A
    /// `consumed` short of `steps.len()` means the limiter stalled —
    /// retry the tail later.
    pub fn append(&mut self, actor_id: u64, steps: Vec<WriterStep>) -> Result<(u32, u32)> {
        match self.call_checked(&Request::Append { actor_id, steps })? {
            Response::Appended { consumed, emitted } => Ok((consumed, emitted)),
            other => bail!("unexpected response to Append: {other:?}"),
        }
    }

    /// Sample one batch from a named table into `out`.
    pub fn sample(
        &mut self,
        table: &str,
        batch: usize,
        out: &mut SampleBatch,
    ) -> Result<SampleOutcome> {
        let req = Request::Sample { table: table.to_string(), batch: batch as u32 };
        match self.call_checked(&req)? {
            Response::Sampled(b) => {
                *out = b;
                Ok(SampleOutcome::Sampled)
            }
            Response::WouldStall { reason } => Ok(match reason {
                StallReason::Throttled => SampleOutcome::Throttled,
                StallReason::NotEnoughData => SampleOutcome::NotEnoughData,
            }),
            other => bail!("unexpected response to Sample: {other:?}"),
        }
    }

    /// Feed |TD| errors back for sampled indices of a named table.
    pub fn update_priorities(
        &mut self,
        table: &str,
        indices: &[usize],
        td_abs: &[f32],
    ) -> Result<()> {
        let req = Request::UpdatePriorities {
            table: table.to_string(),
            indices: indices.iter().map(|&i| i as u64).collect(),
            td_abs: td_abs.to_vec(),
        };
        match self.call_checked(&req)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response to UpdatePriorities: {other:?}"),
        }
    }

    /// Per-table sizes and counters.
    pub fn stats(&mut self) -> Result<Vec<TableInfo>> {
        match self.call_checked(&Request::Stats)? {
            Response::Stats { tables } => Ok(tables),
            other => bail!("unexpected response to Stats: {other:?}"),
        }
    }

    /// The server's whole serialized state, as raw `ServiceState`
    /// payload bytes (what [`ServiceState::encode`] produced).
    pub fn checkpoint_bytes(&mut self) -> Result<Vec<u8>> {
        match self.call_checked(&Request::Checkpoint)? {
            Response::State { state } => Ok(state),
            other => bail!("unexpected response to Checkpoint: {other:?}"),
        }
    }

    /// The server's whole state, decoded.
    pub fn checkpoint_state(&mut self) -> Result<ServiceState> {
        ServiceState::decode(&self.checkpoint_bytes()?)
            .context("decoding the replay server's checkpoint payload")
    }

    /// Restore a previously captured state into the served tables.
    pub fn restore_state(&mut self, state: &ServiceState) -> Result<()> {
        match self.call_checked(&Request::Restore { state: state.encode() })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response to Restore: {other:?}"),
        }
    }

    /// Ask the server to stop accepting connections and exit.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call_checked(&Request::Shutdown)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response to Shutdown: {other:?}"),
        }
    }
}

/// Remote counterpart of [`crate::service::TrajectoryWriter`]: ships
/// raw env steps to the server, which runs the real writer (item
/// assembly server-side keeps remote and local items byte-identical).
/// Steps the limiter has not yet admitted wait in a small client-side
/// queue that [`ExperienceWriter::throttled`] retries — mirroring the
/// local writer, where a throttled actor holds its next step in the
/// loop instead.
pub struct RemoteWriter {
    client: RemoteClient,
    actor_id: u64,
    pending: VecDeque<WriterStep>,
    items_emitted: u64,
}

impl RemoteWriter {
    pub fn connect(path: impl AsRef<Path>, actor_id: u64) -> Result<Self> {
        Ok(Self {
            client: RemoteClient::connect(path)?,
            actor_id,
            pending: VecDeque::new(),
            items_emitted: 0,
        })
    }

    /// Items the server reported emitting for this writer so far.
    pub fn items_emitted(&self) -> u64 {
        self.items_emitted
    }

    /// Try to ship every pending step; stops early when the server
    /// reports a limiter stall (the tail stays queued for the next
    /// poll).
    fn flush(&mut self) -> Result<()> {
        while !self.pending.is_empty() {
            let steps: Vec<WriterStep> = self.pending.iter().cloned().collect();
            let sent = steps.len();
            let (consumed, emitted) = self.client.append(self.actor_id, steps)?;
            for _ in 0..consumed {
                self.pending.pop_front();
            }
            self.items_emitted += emitted as u64;
            if (consumed as usize) < sent {
                break; // limiter stall — retriable, not an error
            }
        }
        Ok(())
    }
}

impl ExperienceWriter for RemoteWriter {
    fn throttled(&mut self) -> Result<bool> {
        self.flush()?;
        Ok(!self.pending.is_empty())
    }

    fn append(&mut self, step: WriterStep) -> Result<usize> {
        let before = self.items_emitted;
        self.pending.push_back(step);
        self.flush()?;
        Ok((self.items_emitted - before) as usize)
    }
}

impl Drop for RemoteWriter {
    fn drop(&mut self) {
        // Best-effort: one last try at delivering a step the limiter
        // stalled right before shutdown.
        let _ = self.flush();
    }
}

/// Remote counterpart of [`crate::service::SamplerHandle`] on one named
/// table. Sampling randomness lives server-side (seeded at connect),
/// so a fixed seed makes a remote sample/update loop bit-reproducible
/// against an in-process one.
pub struct RemoteSampler {
    client: RemoteClient,
    table: String,
}

impl RemoteSampler {
    /// Connect and seed the connection's sampling RNG.
    pub fn connect(
        path: impl AsRef<Path>,
        table: impl Into<String>,
        rng_seed: u64,
    ) -> Result<Self> {
        let mut client = RemoteClient::connect(path)?;
        client.hello(rng_seed)?;
        Ok(Self { client, table: table.into() })
    }

    /// Connect to the server's default (first) table.
    pub fn connect_default(path: impl AsRef<Path>, rng_seed: u64) -> Result<Self> {
        let path = path.as_ref();
        let mut client = RemoteClient::connect(path)?;
        let tables = client.stats()?;
        let first = tables
            .first()
            .map(|t| t.name.clone())
            .context("replay server reports no tables")?;
        client.hello(rng_seed)?;
        Ok(Self { client, table: first })
    }

    pub fn table(&self) -> &str {
        &self.table
    }
}

impl ExperienceSampler for RemoteSampler {
    fn try_sample(
        &mut self,
        batch: usize,
        _rng: &mut Rng,
        out: &mut SampleBatch,
    ) -> Result<SampleOutcome> {
        self.client.sample(&self.table, batch, out)
    }

    fn update_priorities(&mut self, indices: &[usize], td_abs: &[f32]) -> Result<()> {
        self.client.update_priorities(&self.table, indices, td_abs)
    }
}
