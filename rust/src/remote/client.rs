//! Client side of the remote replay protocol: a low-level
//! [`RemoteClient`] (framed call/response with reusable encode/decode
//! buffers) plus the [`RemoteWriter`] / [`RemoteSampler`] handles that
//! mirror the in-process [`TrajectoryWriter`] / [`SamplerHandle`] APIs
//! through the [`ExperienceWriter`] / [`ExperienceSampler`] traits —
//! the actor and learner loops cannot tell which side of the socket
//! their tables live on.
//!
//! # Throughput machinery
//!
//! * **Batched appends** — [`RemoteWriter`] accumulates steps and
//!   ships them `batch` at a time (one `Append` RPC per chunk instead
//!   of one per step). A limiter stall comes back as a short
//!   `Appended` frame; the un-admitted tail stays queued and is
//!   retried by the actor's normal `throttled()` poll, re-encoding at
//!   most one chunk per retry (never the whole backlog).
//! * **Pipelined sampling** — [`RemoteSampler`] writes the next
//!   `Sample` request immediately after each `UpdatePriorities` (same
//!   connection, strictly after the update so the server applies
//!   priorities before drawing), leaving the response in flight while
//!   the learner runs its gradient step. The next `try_sample` only
//!   reads the already-travelling response, collapsing the two serial
//!   round-trips per learn iteration into roughly one.
//! * **Allocation-free framing** — every RPC encodes into the
//!   connection's reused [`ByteWriter`] and decodes out of its reused
//!   payload buffer; sampled batches land directly in the learner's
//!   [`SampleBatch`] scratch. Steady-state append/sample does no
//!   per-RPC heap allocation on the client, and none for framing or
//!   response encoding on the server (the server's `Append` decode
//!   still materializes owned `WriterStep`s — they become storage
//!   rows).
//!
//! Rate-limiter semantics are preserved across the wire without ever
//! blocking the connection: a stalled insert comes back as a short
//! `Appended` frame, a stalled sample as a retriable `WouldStall`
//! frame the learner sleep-polls, exactly like the in-process
//! outcomes.

use super::frame::{read_frame_into, write_frame};
use super::proto::{
    self, Request, Response, SampleOutcomeWire, StallReason, TableInfo, MAX_APPEND_STEPS,
};
use crate::replay::SampleBatch;
use crate::service::{
    ExperienceSampler, ExperienceWriter, SampleOutcome, ServiceState, WriterStep,
};
use crate::util::blob::ByteWriter;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::os::unix::net::UnixStream;
use std::path::Path;
use std::time::Duration;

/// How long one RPC may stay silent before the client gives up. The
/// server never blocks on a rate limiter (stalls come back as
/// immediate `WouldStall`/short-`Appended` frames), so a long silence
/// means a wedged or dead server — erroring lets the worker loops
/// stop the run instead of hanging past `ctl.request_stop`. Sized for
/// the slowest legitimate RPC (a multi-hundred-MiB `Checkpoint`).
const RPC_TIMEOUT: Duration = Duration::from_secs(120);

/// Default [`RemoteWriter`] flush threshold of a training run
/// (`--remote-batch`); `RemoteWriter::connect` itself starts at 1
/// (exact legacy one-step-per-RPC semantics) until
/// [`RemoteWriter::with_batch`] raises it.
pub const DEFAULT_REMOTE_BATCH: usize = 16;

/// One connection to a [`super::ReplayServer`]; a thin call/response
/// wrapper plus typed helpers for every RPC. Requests encode into a
/// per-connection [`ByteWriter`] and responses decode out of a
/// per-connection payload buffer, both reused across calls.
pub struct RemoteClient {
    stream: UnixStream,
    enc: ByteWriter,
    rbuf: Vec<u8>,
}

impl RemoteClient {
    pub fn connect(path: impl AsRef<Path>) -> Result<Self> {
        let stream = UnixStream::connect(path.as_ref()).with_context(|| {
            format!("connecting to replay server at {}", path.as_ref().display())
        })?;
        stream
            .set_read_timeout(Some(RPC_TIMEOUT))
            .context("setting the RPC read timeout")?;
        stream
            .set_write_timeout(Some(RPC_TIMEOUT))
            .context("setting the RPC write timeout")?;
        Ok(Self { stream, enc: ByteWriter::new(), rbuf: Vec::new() })
    }

    /// Ship whatever the last `self.enc.reset()` + encode produced.
    fn send_encoded(&mut self) -> Result<()> {
        write_frame(&mut self.stream, self.enc.as_slice())
    }

    /// Write one request frame without reading its response (the
    /// pipelining half; pair with a receive helper).
    pub fn send(&mut self, req: &Request) -> Result<()> {
        self.enc.reset();
        req.encode_into(&mut self.enc);
        self.send_encoded()
    }

    /// Read one response frame into the reused payload buffer.
    fn recv_payload(&mut self) -> Result<()> {
        if !read_frame_into(&mut self.stream, &mut self.rbuf)? {
            bail!("replay server closed the connection mid-call");
        }
        Ok(())
    }

    /// Read one response and decode it (allocates for payload-carrying
    /// variants; hot paths use the typed receive helpers instead).
    pub fn recv(&mut self) -> Result<Response> {
        self.recv_payload()?;
        Response::decode(&self.rbuf)
    }

    /// One request, one response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        self.send(req)?;
        self.recv()
    }

    /// As [`Self::call`], but a `Response::Error` becomes an `Err`.
    fn call_checked(&mut self, req: &Request) -> Result<Response> {
        match self.call(req)? {
            Response::Error { message } => bail!("replay server error: {message}"),
            resp => Ok(resp),
        }
    }

    /// Read one response that must be a bare `Ok`.
    fn recv_ok(&mut self, what: &str) -> Result<()> {
        match self.recv()? {
            Response::Ok => Ok(()),
            Response::Error { message } => bail!("replay server error: {message}"),
            other => bail!("unexpected response to {what}: {other:?}"),
        }
    }

    /// Seed this connection's server-side sampling RNG; returns the
    /// server's default (first) table name, so a sampler binds without
    /// a separate `Stats` round-trip.
    pub fn hello(&mut self, rng_seed: u64) -> Result<String> {
        match self.call_checked(&Request::Hello { rng_seed })? {
            Response::Hello { default_table } => Ok(default_table),
            other => bail!("unexpected response to Hello: {other:?}"),
        }
    }

    /// Append steps for one actor; returns `(consumed, emitted)`. A
    /// `consumed` short of `steps.len()` means the limiter stalled —
    /// retry the tail later.
    pub fn append(&mut self, actor_id: u64, steps: &[WriterStep]) -> Result<(u32, u32)> {
        self.append_steps(actor_id, steps.iter())
    }

    /// As [`Self::append`], but straight from borrowed steps (e.g. a
    /// slice of a pending queue) — no clone, no intermediate `Request`.
    pub fn append_steps<'a>(
        &mut self,
        actor_id: u64,
        steps: impl ExactSizeIterator<Item = &'a WriterStep>,
    ) -> Result<(u32, u32)> {
        self.enc.reset();
        proto::encode_append(&mut self.enc, actor_id, steps);
        self.send_encoded()?;
        match self.recv()? {
            Response::Appended { consumed, emitted } => Ok((consumed, emitted)),
            Response::Error { message } => bail!("replay server error: {message}"),
            other => bail!("unexpected response to Append: {other:?}"),
        }
    }

    /// Write a `Sample` request without reading the response (the
    /// prefetch half; pair with [`Self::recv_sample`]).
    pub fn send_sample(&mut self, table: &str, batch: usize) -> Result<()> {
        self.enc.reset();
        proto::encode_sample(&mut self.enc, table, batch as u32);
        self.send_encoded()
    }

    /// Read one `Sample` response, decoding a granted batch into `out`
    /// without allocating.
    pub fn recv_sample(&mut self, out: &mut SampleBatch) -> Result<SampleOutcome> {
        self.recv_payload()?;
        Ok(match proto::decode_sample_response(&self.rbuf, out)? {
            SampleOutcomeWire::Sampled => SampleOutcome::Sampled,
            SampleOutcomeWire::WouldStall(StallReason::Throttled) => SampleOutcome::Throttled,
            SampleOutcomeWire::WouldStall(StallReason::NotEnoughData) => {
                SampleOutcome::NotEnoughData
            }
        })
    }

    /// Sample one batch from a named table into `out`.
    pub fn sample(
        &mut self,
        table: &str,
        batch: usize,
        out: &mut SampleBatch,
    ) -> Result<SampleOutcome> {
        self.send_sample(table, batch)?;
        self.recv_sample(out)
    }

    /// Write an `UpdatePriorities` request without reading the
    /// response (the pipelining half; pair with a `recv_ok`).
    fn send_update(&mut self, table: &str, indices: &[usize], td_abs: &[f32]) -> Result<()> {
        self.enc.reset();
        proto::encode_update_priorities(&mut self.enc, table, indices, td_abs);
        self.send_encoded()
    }

    /// Feed |TD| errors back for sampled indices of a named table.
    pub fn update_priorities(
        &mut self,
        table: &str,
        indices: &[usize],
        td_abs: &[f32],
    ) -> Result<()> {
        self.send_update(table, indices, td_abs)?;
        self.recv_ok("UpdatePriorities")
    }

    /// Per-table sizes and counters.
    pub fn stats(&mut self) -> Result<Vec<TableInfo>> {
        match self.call_checked(&Request::Stats)? {
            Response::Stats { tables } => Ok(tables),
            other => bail!("unexpected response to Stats: {other:?}"),
        }
    }

    /// The server's whole serialized state, as raw `ServiceState`
    /// payload bytes (what [`ServiceState::encode`] produced).
    pub fn checkpoint_bytes(&mut self) -> Result<Vec<u8>> {
        match self.call_checked(&Request::Checkpoint)? {
            Response::State { state } => Ok(state),
            other => bail!("unexpected response to Checkpoint: {other:?}"),
        }
    }

    /// The server's whole state, decoded.
    pub fn checkpoint_state(&mut self) -> Result<ServiceState> {
        ServiceState::decode(&self.checkpoint_bytes()?)
            .context("decoding the replay server's checkpoint payload")
    }

    /// Restore a previously captured state into the served tables.
    pub fn restore_state(&mut self, state: &ServiceState) -> Result<()> {
        match self.call_checked(&Request::Restore { state: state.encode() })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response to Restore: {other:?}"),
        }
    }

    /// Ask the server to stop accepting connections and exit.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call_checked(&Request::Shutdown)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response to Shutdown: {other:?}"),
        }
    }
}

/// Remote counterpart of [`crate::service::TrajectoryWriter`]: ships
/// raw env steps to the server, which runs the real writer (item
/// assembly server-side keeps remote and local items byte-identical).
///
/// Steps accumulate client-side and go out `batch` at a time — one
/// `Append` RPC per chunk. With `batch` = 1 ([`Self::connect`]'s
/// default) every step is its own RPC, byte-for-byte the pre-batching
/// behaviour. Steps the limiter has not yet admitted wait in the
/// pending queue, retried by [`ExperienceWriter::throttled`] polls one
/// chunk per RPC, so a long stall re-encodes at most `batch` steps per
/// retry — never the whole backlog.
pub struct RemoteWriter {
    client: RemoteClient,
    actor_id: u64,
    pending: VecDeque<WriterStep>,
    /// Flush threshold AND per-RPC chunk size (≥ 1).
    batch: usize,
    /// The last `Append` was cut short by a limiter stall; cleared
    /// when a flush drains the queue.
    stalled: bool,
    items_emitted: u64,
    wire_steps_sent: u64,
}

impl RemoteWriter {
    /// Connect with the legacy one-step-per-RPC behaviour (`batch` 1);
    /// chain [`Self::with_batch`] to enable client-side batching.
    pub fn connect(path: impl AsRef<Path>, actor_id: u64) -> Result<Self> {
        Ok(Self {
            client: RemoteClient::connect(path)?,
            actor_id,
            pending: VecDeque::new(),
            batch: 1,
            stalled: false,
            items_emitted: 0,
            wire_steps_sent: 0,
        })
    }

    /// Set the flush threshold: steps accumulate until `batch` are
    /// pending, then ship as one `Append` RPC.
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch.clamp(1, MAX_APPEND_STEPS);
        self
    }

    /// Items the server reported emitting for this writer so far.
    pub fn items_emitted(&self) -> u64 {
        self.items_emitted
    }

    /// Steps queued client-side (not yet acknowledged by the server).
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Total steps encoded onto the wire, retries included — the
    /// regression probe for the flush path: a stall must re-send at
    /// most one chunk per retry, so this stays O(steps + retries ·
    /// batch), never O(steps²).
    pub fn wire_steps_sent(&self) -> u64 {
        self.wire_steps_sent
    }

    /// Ship pending steps one chunk per RPC; stops early when the
    /// server reports a limiter stall (the tail stays queued for the
    /// next poll). Returns the number of steps still pending.
    fn flush_pending(&mut self) -> Result<usize> {
        while !self.pending.is_empty() {
            let chunk = self.pending.len().min(self.batch);
            let (consumed, emitted) =
                self.client.append_steps(self.actor_id, self.pending.iter().take(chunk))?;
            self.wire_steps_sent += chunk as u64;
            for _ in 0..consumed {
                self.pending.pop_front();
            }
            self.items_emitted += emitted as u64;
            if (consumed as usize) < chunk {
                self.stalled = true; // limiter stall — retriable, not an error
                return Ok(self.pending.len());
            }
        }
        self.stalled = false;
        Ok(0)
    }
}

impl ExperienceWriter for RemoteWriter {
    fn throttled(&mut self) -> Result<bool> {
        if self.stalled || self.pending.len() >= self.batch {
            self.flush_pending()?;
        }
        Ok(self.stalled)
    }

    fn append(&mut self, step: WriterStep) -> Result<usize> {
        let before = self.items_emitted;
        self.pending.push_back(step);
        // While stalled, retries belong to the `throttled()` poll (the
        // actor's sleep loop), not to every queued step — that keeps a
        // long stall at one chunk-sized RPC per poll instead of one
        // per append.
        if !self.stalled && self.pending.len() >= self.batch {
            self.flush_pending()?;
        }
        Ok((self.items_emitted - before) as usize)
    }

    fn flush(&mut self) -> Result<usize> {
        self.flush_pending()
    }
}

impl Drop for RemoteWriter {
    fn drop(&mut self) {
        // Best-effort: one last try at delivering steps still queued
        // (a sub-batch tail, or steps the limiter stalled) at shutdown.
        let _ = self.flush_pending();
    }
}

/// Remote counterpart of [`crate::service::SamplerHandle`] on one named
/// table. Sampling randomness lives server-side (seeded at connect),
/// so a fixed seed makes a remote sample/update loop bit-reproducible
/// against an in-process one.
///
/// With [`Self::with_prefetch`] the sampler keeps one decoded batch in
/// flight: each `update_priorities` writes the update *and* the next
/// `Sample` request back-to-back on the connection (the server applies
/// the priorities before drawing, preserving in-process ordering), so
/// the following `try_sample` only reads a response that travelled
/// during the learner's gradient step. A `WouldStall` read out of the
/// pipeline ends it cleanly — the next `try_sample` issues a fresh
/// request, and no granted batch is ever lost or duplicated.
pub struct RemoteSampler {
    client: RemoteClient,
    table: String,
    prefetch: bool,
    /// Batch size of the `Sample` request currently in flight.
    inflight: Option<usize>,
    /// Batch size of the last granted batch (what a prefetch requests).
    last_batch: Option<usize>,
    /// Responses drained out of order (an in-flight sample consumed by
    /// a second consecutive update), oldest first, each tagged with its
    /// requested batch size; handed back by `try_sample` in order so no
    /// granted batch is ever lost.
    stashed: VecDeque<(usize, SampleOutcome, SampleBatch)>,
}

impl RemoteSampler {
    /// Connect to a named table and seed the connection's sampling RNG.
    pub fn connect(
        path: impl AsRef<Path>,
        table: impl Into<String>,
        rng_seed: u64,
    ) -> Result<Self> {
        let mut client = RemoteClient::connect(path)?;
        client.hello(rng_seed)?;
        Ok(Self::new(client, table.into()))
    }

    /// Connect to the server's default (first) table: one dial, one
    /// round-trip — the `Hello` response names the table.
    pub fn connect_default(path: impl AsRef<Path>, rng_seed: u64) -> Result<Self> {
        let mut client = RemoteClient::connect(path)?;
        let table = client.hello(rng_seed)?;
        if table.is_empty() {
            bail!("replay server reports no default table");
        }
        Ok(Self::new(client, table))
    }

    fn new(client: RemoteClient, table: String) -> Self {
        Self {
            client,
            table,
            prefetch: false,
            inflight: None,
            last_batch: None,
            stashed: VecDeque::new(),
        }
    }

    /// Enable pipelined sampling (one batch kept in flight).
    pub fn with_prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    pub fn table(&self) -> &str {
        &self.table
    }

    /// Consume the in-flight prefetch response, if any, and report its
    /// outcome. A `Sampled` outcome here is a batch the server granted
    /// (and counted) that this client will never use — callers that
    /// audit exact accounting must tally it.
    pub fn drain(&mut self) -> Result<Option<SampleOutcome>> {
        match self.inflight.take() {
            None => Ok(None),
            Some(_) => {
                let mut scratch = SampleBatch::default();
                Ok(Some(self.client.recv_sample(&mut scratch)?))
            }
        }
    }
}

impl ExperienceSampler for RemoteSampler {
    fn try_sample(
        &mut self,
        batch: usize,
        _rng: &mut Rng,
        out: &mut SampleBatch,
    ) -> Result<SampleOutcome> {
        if let Some((n, outcome, mut stashed)) = self.stashed.pop_front() {
            if n != batch {
                bail!(
                    "stashed sample batch size does not match the request ({n} stashed, \
                     {batch} requested)"
                );
            }
            std::mem::swap(out, &mut stashed);
            if outcome == SampleOutcome::Sampled {
                self.last_batch = Some(batch);
            }
            return Ok(outcome);
        }
        let outcome = match self.inflight.take() {
            Some(n) => {
                if n != batch {
                    bail!(
                        "pipelined sample batch size changed mid-flight ({n} in flight, \
                         {batch} requested)"
                    );
                }
                self.client.recv_sample(out)?
            }
            None => {
                self.client.send_sample(&self.table, batch)?;
                self.client.recv_sample(out)?
            }
        };
        if outcome == SampleOutcome::Sampled {
            self.last_batch = Some(batch);
        }
        Ok(outcome)
    }

    fn update_priorities(&mut self, indices: &[usize], td_abs: &[f32]) -> Result<()> {
        if let Some(n) = self.inflight.take() {
            // Two updates without a try_sample in between: drain the
            // in-flight response into the stash queue so the granted
            // batch is neither lost nor read out of frame order (even
            // across several consecutive updates).
            let mut scratch = SampleBatch::default();
            let outcome = self.client.recv_sample(&mut scratch)?;
            self.stashed.push_back((n, outcome, scratch));
        }
        self.client.send_update(&self.table, indices, td_abs)?;
        if self.prefetch {
            if let Some(n) = self.last_batch {
                // Written strictly after the update on the same stream:
                // the server applies the new priorities, then draws.
                self.client.send_sample(&self.table, n)?;
                self.inflight = Some(n);
            }
        }
        self.client.recv_ok("UpdatePriorities")
    }

    fn finish(&mut self) -> Result<()> {
        self.drain().map(|_| ())
    }
}
