//! Client side of the remote replay protocol: a low-level
//! [`RemoteClient`] (framed call/response with reusable encode/decode
//! buffers) plus the [`RemoteWriter`] / [`RemoteSampler`] handles that
//! mirror the in-process [`TrajectoryWriter`] / [`SamplerHandle`] APIs
//! through the [`ExperienceWriter`] / [`ExperienceSampler`] traits —
//! the actor and learner loops cannot tell which side of the socket
//! their tables live on.
//!
//! # Throughput machinery
//!
//! * **Batched appends** — [`RemoteWriter`] accumulates steps and
//!   ships them `batch` at a time (one `Append` RPC per chunk instead
//!   of one per step). A limiter stall comes back as a short
//!   `Appended` frame; the un-admitted tail stays queued and is
//!   retried by the actor's normal `throttled()` poll, re-encoding at
//!   most one chunk per retry (never the whole backlog).
//! * **Pipelined sampling** — [`RemoteSampler`] writes the next
//!   `Sample` request immediately after each `UpdatePriorities` (same
//!   connection, strictly after the update so the server applies
//!   priorities before drawing), leaving the response in flight while
//!   the learner runs its gradient step. The next `try_sample` only
//!   reads the already-travelling response, collapsing the two serial
//!   round-trips per learn iteration into roughly one.
//! * **Allocation-free framing** — every RPC encodes into the
//!   connection's reused [`ByteWriter`] and decodes out of its reused
//!   payload buffer; sampled batches land directly in the learner's
//!   [`SampleBatch`] scratch.
//!
//! # Fault tolerance
//!
//! All three handles are *supervised*: a dead or wedged connection is
//! redialed under the shared [`BackoffPolicy`] schedule (exponential,
//! jittered, bounded by an overall reconnect deadline), and each
//! redial re-sends `Hello` quoting the old session id. When the server
//! still holds the session, every request re-sent after the reconnect
//! is deduplicated by the server's reply cache — appends are
//! exactly-once across reconnects. When it does not (server restart,
//! session expiry), unacked work is re-sent under fresh sequence
//! numbers.
//!
//! [`RemoteWriter`] additionally degrades gracefully through an
//! outage: its pending queue doubles as a bounded spill buffer, so the
//! actor keeps stepping while the server is away. Past the spill cap
//! the oldest queued steps are dropped (newest experience is the most
//! valuable); every drop is counted and reported to the server on the
//! next successful append, where it lands in the `steps_dropped` stat.
//! Note that a dropped step breaks trajectory continuity for N-step
//! and sequence tables — the server-side writer folds across the gap —
//! which is the documented price of not blocking the actor.
//!
//! Rate-limiter semantics are preserved across the wire without ever
//! blocking the connection: a stalled insert comes back as a short
//! `Appended` frame, a stalled sample as a retriable `WouldStall`
//! frame the learner sleep-polls, exactly like the in-process
//! outcomes.

use super::backoff::{Backoff, BackoffPolicy};
use super::frame::{read_frame_into, write_frame};
use super::proto::{
    self, Request, Response, SampleOutcomeWire, StallReason, TableInfo, DEFAULT_CHUNK_LEN,
    MAX_APPEND_STEPS,
};
use super::transport::{Endpoint, RpcStream};
use crate::replay::SampleBatch;
use crate::service::{
    ExperienceSampler, ExperienceWriter, SampleOutcome, ServiceState, WriterStep,
};
use crate::util::blob::{crc32, ByteWriter};
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::collections::VecDeque;
use std::path::Path;
use std::time::{Duration, Instant};

/// Default bound on one RPC's silence before the client gives up on
/// the connection (`--rpc-timeout`). The server never blocks on a rate
/// limiter (stalls come back as immediate `WouldStall`/short-`Appended`
/// frames), so a long silence means a wedged or dead server — treating
/// it as a transport failure hands the connection to the reconnect
/// supervisor instead of hanging the worker loop. Sized for the
/// slowest legitimate RPC (a multi-hundred-MiB `Checkpoint`).
pub const DEFAULT_RPC_TIMEOUT: Duration = Duration::from_secs(120);

/// Default [`RemoteWriter`] flush threshold of a training run
/// (`--remote-batch`); `RemoteWriter::connect` itself starts at 1
/// (exact legacy one-step-per-RPC semantics) until
/// [`RemoteWriter::with_batch`] raises it.
pub const DEFAULT_REMOTE_BATCH: usize = 16;

/// Default [`RemoteWriter`] spill-queue bound (`--spill-cap`): steps
/// queued past this during an outage drop oldest-first.
pub const DEFAULT_SPILL_CAP: usize = 65_536;

/// Reconnect rounds one [`RemoteSampler`] operation may burn before
/// reporting the link unstabilizable (each round is a full
/// [`BackoffPolicy`]-bounded reconnect, so this only bounds a link
/// that keeps dying immediately after healing).
const MAX_RECOVER_ROUNDS: u32 = 16;

/// Marker context attached to every raw-I/O failure inside
/// [`RemoteClient`], so supervision code can tell a dead *connection*
/// (redial and retry) from a server-reported *application* error
/// (surface to the caller). The vendored `anyhow` shim carries string
/// chains only, so the classification is a context-message prefix.
const TRANSPORT_MARK: &str = "replay transport";

/// True when `e` is a connection-level failure (socket died, stream
/// corrupted, RPC timed out) rather than an application error the
/// server answered with.
pub(crate) fn is_transport_error(e: &anyhow::Error) -> bool {
    e.chain().any(|m| m.starts_with(TRANSPORT_MARK))
}

/// How one supervised connection dials, times out, and retries. The
/// training CLI maps `--rpc-timeout` and `--reconnect-deadline` here.
#[derive(Clone, Debug)]
pub struct ConnectionPolicy {
    /// Per-RPC read/write timeout on the socket.
    pub rpc_timeout: Duration,
    /// Redial schedule after a transport failure.
    pub backoff: BackoffPolicy,
}

impl Default for ConnectionPolicy {
    fn default() -> Self {
        Self { rpc_timeout: DEFAULT_RPC_TIMEOUT, backoff: BackoffPolicy::default() }
    }
}

/// One connection to a [`super::ReplayServer`]; a thin call/response
/// wrapper plus typed helpers for every RPC. Requests encode into a
/// per-connection [`ByteWriter`] and responses decode out of a
/// per-connection payload buffer, both reused across calls. The client
/// remembers its dial endpoint (UDS path or TCP address), session id,
/// and request sequence counter, so a supervisor can redial and resume
/// the server-side session.
pub struct RemoteClient {
    stream: RpcStream,
    enc: ByteWriter,
    rbuf: Vec<u8>,
    endpoint: Endpoint,
    policy: ConnectionPolicy,
    /// Seed re-quoted on every redial's `Hello`, once [`Self::hello`]
    /// has run (a client that never said hello redials sessionless).
    hello_seed: Option<u64>,
    /// Table ACL quoted on every `Hello` (empty = all tables); redials
    /// re-send it so the server rebinds the same scope.
    acl: Vec<String>,
    /// Server-side session id (0 until the first `Hello` reply).
    session: u64,
    /// Next sequence number [`Self::alloc_seq`] hands out.
    next_seq: u64,
    reconnects: u64,
    /// Whether the last `Hello` reattached existing server-side state.
    last_hello_resumed: bool,
}

impl RemoteClient {
    pub fn connect(path: impl AsRef<Path>) -> Result<Self> {
        Self::connect_with(path, ConnectionPolicy::default())
    }

    /// Connect to a Unix-socket path under an explicit timeout/backoff
    /// policy (the pre-mesh constructor; endpoint-blind callers use
    /// [`Self::connect_endpoint_with`]).
    pub fn connect_with(path: impl AsRef<Path>, policy: ConnectionPolicy) -> Result<Self> {
        Self::connect_endpoint_with(&Endpoint::from(path.as_ref()), policy)
    }

    /// Connect to a UDS or TCP endpoint with the default policy.
    pub fn connect_endpoint(endpoint: &Endpoint) -> Result<Self> {
        Self::connect_endpoint_with(endpoint, ConnectionPolicy::default())
    }

    /// Connect to a UDS or TCP endpoint under an explicit
    /// timeout/backoff policy.
    pub fn connect_endpoint_with(endpoint: &Endpoint, policy: ConnectionPolicy) -> Result<Self> {
        let stream = Self::dial(endpoint, &policy)?;
        Ok(Self {
            stream,
            enc: ByteWriter::new(),
            rbuf: Vec::new(),
            endpoint: endpoint.clone(),
            policy,
            hello_seed: None,
            acl: Vec::new(),
            session: 0,
            next_seq: 1,
            reconnects: 0,
            last_hello_resumed: false,
        })
    }

    fn dial(endpoint: &Endpoint, policy: &ConnectionPolicy) -> Result<RpcStream> {
        let stream = endpoint
            .dial()
            .with_context(|| format!("connecting to replay server at {endpoint}"))?;
        stream
            .set_read_timeout(Some(policy.rpc_timeout))
            .context("setting the RPC read timeout")?;
        stream
            .set_write_timeout(Some(policy.rpc_timeout))
            .context("setting the RPC write timeout")?;
        Ok(stream)
    }

    pub fn policy(&self) -> &ConnectionPolicy {
        &self.policy
    }

    /// The endpoint this client dials (and redials).
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    /// The server-side session id this connection is bound to (0 before
    /// the first `Hello`).
    pub fn session(&self) -> u64 {
        self.session
    }

    /// Successful redials so far (the monitor surfaces this per tick).
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// Whether the most recent `Hello` resumed existing server-side
    /// session state (false after a server restart or session expiry).
    pub fn last_hello_resumed(&self) -> bool {
        self.last_hello_resumed
    }

    /// Hand out the next request sequence number (sequenced requests
    /// start at 1; 0 on the wire means "unsequenced").
    pub fn alloc_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    /// One redial attempt: dial, then re-`Hello` quoting the old
    /// session id (when [`Self::hello`] ever ran). On success the
    /// connection is usable; check [`Self::last_hello_resumed`] to
    /// learn whether server-side state survived.
    pub fn try_redial(&mut self) -> Result<()> {
        self.stream = Self::dial(&self.endpoint, &self.policy)?;
        if let Some(seed) = self.hello_seed {
            self.hello(seed)?;
        }
        self.reconnects += 1;
        Ok(())
    }

    /// Blocking reconnect under the policy's backoff schedule; gives up
    /// with a descriptive error once the reconnect deadline passes.
    pub fn reconnect(&mut self) -> Result<()> {
        let mut backoff = self.policy.backoff.start();
        loop {
            match self.try_redial() {
                Ok(()) => return Ok(()),
                Err(e) => match backoff.next_delay() {
                    Some(d) => std::thread::sleep(d),
                    None => {
                        return Err(e).with_context(|| {
                            format!(
                                "reconnect to replay server at {} gave up: deadline {:?} \
                                 exceeded after {} attempts",
                                self.endpoint,
                                backoff.deadline(),
                                backoff.attempts()
                            )
                        });
                    }
                },
            }
        }
    }

    /// Ship whatever the last `self.enc.reset()` + encode produced.
    fn send_encoded(&mut self) -> Result<()> {
        write_frame(&mut self.stream, self.enc.as_slice()).context(TRANSPORT_MARK)
    }

    /// Ship one pre-encoded request payload (the supervision resend
    /// path: outstanding requests are re-sent byte-identical so the
    /// server's reply cache can match them).
    pub fn send_payload(&mut self, payload: &[u8]) -> Result<()> {
        write_frame(&mut self.stream, payload).context(TRANSPORT_MARK)
    }

    /// Write one request frame without reading its response (the
    /// pipelining half; pair with a receive helper).
    pub fn send(&mut self, req: &Request) -> Result<()> {
        self.enc.reset();
        req.encode_into(&mut self.enc);
        self.send_encoded()
    }

    /// Read one response frame into the reused payload buffer.
    fn recv_payload(&mut self) -> Result<()> {
        match read_frame_into(&mut self.stream, &mut self.rbuf) {
            Ok(true) => Ok(()),
            Ok(false) => bail!("{TRANSPORT_MARK}: replay server closed the connection mid-call"),
            Err(e) => Err(e.context(TRANSPORT_MARK)),
        }
    }

    /// Read one response and decode it (allocates for payload-carrying
    /// variants; hot paths use the typed receive helpers instead).
    pub fn recv(&mut self) -> Result<Response> {
        self.recv_payload()?;
        Response::decode(&self.rbuf)
    }

    /// One request, one response.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        self.send(req)?;
        self.recv()
    }

    /// As [`Self::call`], but a transport failure triggers one
    /// supervised reconnect (backoff, deadline) and a single retry.
    /// Only safe for idempotent or unsequenced requests (`Stats`,
    /// `Checkpoint`) — the monitor's poll path.
    pub fn call_resilient(&mut self, req: &Request) -> Result<Response> {
        match self.call(req) {
            Err(e) if is_transport_error(&e) => {
                self.reconnect()?;
                self.call(req)
            }
            other => other,
        }
    }

    /// As [`Self::call`], but a `Response::Error` becomes an `Err`.
    fn call_checked(&mut self, req: &Request) -> Result<Response> {
        match self.call(req)? {
            Response::Error { message } => bail!("replay server error: {message}"),
            resp => Ok(resp),
        }
    }

    /// Read one response that must be a bare `Ok`.
    fn recv_ok(&mut self, what: &str) -> Result<()> {
        match self.recv()? {
            Response::Ok => Ok(()),
            Response::Error { message } => bail!("replay server error: {message}"),
            other => bail!("unexpected response to {what}: {other:?}"),
        }
    }

    /// Scope the connection to a set of table names (the tenant ACL the
    /// next `Hello` binds; empty = all tables). Call before
    /// [`Self::hello`] — redials re-send the list automatically, so the
    /// scope survives reconnects.
    pub fn set_acl(&mut self, tables: Vec<String>) {
        self.acl = tables;
    }

    /// Bind (or, after a redial, resume) a server-side session and seed
    /// its sampling RNG; returns the server's default (first) table
    /// name, so a sampler binds without a separate `Stats` round-trip.
    pub fn hello(&mut self, rng_seed: u64) -> Result<String> {
        self.hello_seed = Some(rng_seed);
        let quoted = self.session;
        let req =
            Request::Hello { rng_seed, session: quoted, tables: self.acl.clone() };
        match self.call_checked(&req)? {
            Response::Hello { default_table, session, resumed, next_seq } => {
                self.session = session;
                self.last_hello_resumed = resumed;
                if resumed {
                    // The local counter is already at or past the
                    // server's expectation (it allocated every number
                    // the server has seen); never move it backwards.
                    self.next_seq = self.next_seq.max(next_seq);
                } else {
                    self.next_seq = next_seq;
                }
                Ok(default_table)
            }
            other => bail!("unexpected response to Hello: {other:?}"),
        }
    }

    /// Append steps for one actor; returns `(consumed, emitted)`. A
    /// `consumed` short of `steps.len()` means the limiter stalled —
    /// retry the tail later.
    pub fn append(&mut self, actor_id: u64, steps: &[WriterStep]) -> Result<(u32, u32)> {
        self.append_steps(actor_id, steps.iter())
    }

    /// As [`Self::append`], but straight from borrowed steps (e.g. a
    /// slice of a pending queue) — no clone, no intermediate `Request`.
    pub fn append_steps<'a>(
        &mut self,
        actor_id: u64,
        steps: impl ExactSizeIterator<Item = &'a WriterStep>,
    ) -> Result<(u32, u32)> {
        self.append_steps_seq(actor_id, 0, 0, steps)
    }

    /// The sequenced append used by [`RemoteWriter`]: `seq` rides the
    /// session's exactly-once gate and `dropped` reports client-side
    /// spill drops since the last acked append.
    pub fn append_steps_seq<'a>(
        &mut self,
        actor_id: u64,
        seq: u64,
        dropped: u64,
        steps: impl ExactSizeIterator<Item = &'a WriterStep>,
    ) -> Result<(u32, u32)> {
        self.enc.reset();
        proto::encode_append(&mut self.enc, actor_id, seq, dropped, steps);
        self.send_encoded()?;
        match self.recv()? {
            Response::Appended { consumed, emitted } => Ok((consumed, emitted)),
            // A tenant-quota rejection is retriable, exactly like a
            // limiter stall: nothing was consumed, the tail stays
            // queued, the caller's throttle poll retries it.
            Response::WouldStall { reason: StallReason::QuotaExhausted } => Ok((0, 0)),
            Response::WouldStall { reason } => {
                bail!("unexpected stall reason {reason:?} to Append")
            }
            Response::Error { message } => bail!("replay server error: {message}"),
            other => bail!("unexpected response to Append: {other:?}"),
        }
    }

    /// Write a `Sample` request without reading the response (the
    /// prefetch half; pair with [`Self::recv_sample`]).
    pub fn send_sample(&mut self, table: &str, batch: usize) -> Result<()> {
        self.enc.reset();
        proto::encode_sample(&mut self.enc, table, batch as u32, 0);
        self.send_encoded()
    }

    /// Read one `Sample` response, decoding a granted batch into `out`
    /// without allocating.
    pub fn recv_sample(&mut self, out: &mut SampleBatch) -> Result<SampleOutcome> {
        self.recv_payload()?;
        Ok(match proto::decode_sample_response(&self.rbuf, out)? {
            SampleOutcomeWire::Sampled => SampleOutcome::Sampled,
            SampleOutcomeWire::WouldStall(StallReason::Throttled) => SampleOutcome::Throttled,
            SampleOutcomeWire::WouldStall(StallReason::NotEnoughData) => {
                SampleOutcome::NotEnoughData
            }
            // Quota rejections are retriable by design; to a sampling
            // loop they look like a throttle (sleep-poll and retry).
            SampleOutcomeWire::WouldStall(StallReason::QuotaExhausted) => {
                SampleOutcome::Throttled
            }
        })
    }

    /// Sample one batch from a named table into `out`.
    pub fn sample(
        &mut self,
        table: &str,
        batch: usize,
        out: &mut SampleBatch,
    ) -> Result<SampleOutcome> {
        self.send_sample(table, batch)?;
        self.recv_sample(out)
    }

    /// Feed |TD| errors back for sampled indices of a named table.
    pub fn update_priorities(
        &mut self,
        table: &str,
        indices: &[usize],
        td_abs: &[f32],
    ) -> Result<()> {
        self.enc.reset();
        proto::encode_update_priorities(&mut self.enc, table, indices, td_abs, 0);
        self.send_encoded()?;
        self.recv_ok("UpdatePriorities")
    }

    /// Per-table sizes and counters.
    pub fn stats(&mut self) -> Result<Vec<TableInfo>> {
        match self.call_checked(&Request::Stats)? {
            Response::Stats { tables } => Ok(tables),
            other => bail!("unexpected response to Stats: {other:?}"),
        }
    }

    /// One table's item count and total priority mass — the lightweight
    /// probe [`super::MeshSampler`] polls to pick a server before each
    /// batch (level 1 of the two-level draw).
    pub fn mass(&mut self, table: &str) -> Result<(u64, f32)> {
        match self.call_checked(&Request::Mass { table: table.to_string() })? {
            Response::Mass { len, mass } => Ok((len, mass)),
            other => bail!("unexpected response to Mass: {other:?}"),
        }
    }

    /// Table-agnostic liveness probe: the server echoes `nonce` without
    /// touching any table or session state. The membership layer's
    /// health check — answered even by a draining server.
    pub fn ping(&mut self, nonce: u64) -> Result<()> {
        match self.call_checked(&Request::Ping { nonce })? {
            Response::Pong { nonce: echoed } => {
                if echoed != nonce {
                    bail!("ping answered with nonce {echoed}, expected {nonce}");
                }
                Ok(())
            }
            other => bail!("unexpected response to Ping: {other:?}"),
        }
    }

    /// Operator command: put the server into drain mode. The server
    /// refuses new sessions and appends, hands its tables to the first
    /// reachable of `peers` through the chunked handoff stream, then
    /// stops its accept loop — the `Ok` here means the handoff landed
    /// and the server is exiting. `max_chunk` of 0 uses the server's
    /// default chunk size.
    pub fn drain(&mut self, peers: &[String], max_chunk: u32) -> Result<()> {
        match self.call_checked(&Request::Drain { max_chunk, peers: peers.to_vec() })? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response to Drain: {other:?}"),
        }
    }

    /// The server's whole serialized state, as raw `ServiceState`
    /// payload bytes (what [`ServiceState::encode`] produced). Streams
    /// over the chunked transfer protocol — `CheckpointChunked`
    /// answered by a `ChunkBegin`/`Chunk…`/`ChunkEnd` train of bounded
    /// frames — so a table bigger than one frame's 256 MiB cap still
    /// moves; every chunk is CRC- and sequence-checked on arrival and
    /// the reassembled payload is checked against the end-of-stream
    /// digest.
    pub fn checkpoint_bytes(&mut self) -> Result<Vec<u8>> {
        self.checkpoint_bytes_chunked(DEFAULT_CHUNK_LEN)
    }

    /// As [`Self::checkpoint_bytes`], with an explicit chunk size (the
    /// tests pin tiny chunks to force many frames).
    pub fn checkpoint_bytes_chunked(&mut self, max_chunk: usize) -> Result<Vec<u8>> {
        let max_chunk = max_chunk.clamp(1, proto::MAX_CHUNK_LEN);
        self.send(&Request::CheckpointChunked { max_chunk: max_chunk as u32 })?;
        let (total_len, chunk_len, chunk_count) = match self.recv()? {
            Response::ChunkBegin { total_len, chunk_len, chunk_count } => {
                (total_len, chunk_len, chunk_count)
            }
            Response::Error { message } => bail!("replay server error: {message}"),
            other => bail!("unexpected response to CheckpointChunked: {other:?}"),
        };
        let mut state = Vec::new();
        for want in 0..chunk_count {
            match self.recv()? {
                Response::Chunk { seq, crc, data } => {
                    if seq != want {
                        bail!(
                            "checkpoint stream out of order: got chunk {seq}, expected {want}"
                        );
                    }
                    let expected = if want + 1 == chunk_count {
                        total_len - u64::from(chunk_count - 1) * u64::from(chunk_len)
                    } else {
                        u64::from(chunk_len)
                    };
                    if data.len() as u64 != expected {
                        bail!(
                            "checkpoint chunk {seq} is {} bytes, stream declared {expected}",
                            data.len()
                        );
                    }
                    if crc32(&data) != crc {
                        bail!("checkpoint chunk {seq} CRC mismatch (corrupted in flight)");
                    }
                    state.extend_from_slice(&data);
                }
                Response::Error { message } => bail!("replay server error: {message}"),
                other => bail!("unexpected frame in a checkpoint stream: {other:?}"),
            }
        }
        match self.recv()? {
            Response::ChunkEnd { total_crc } => {
                if crc32(&state) != total_crc {
                    bail!("reassembled checkpoint CRC mismatch");
                }
            }
            Response::Error { message } => bail!("replay server error: {message}"),
            other => bail!("unexpected end of a checkpoint stream: {other:?}"),
        }
        Ok(state)
    }

    /// The server's whole state, decoded.
    pub fn checkpoint_state(&mut self) -> Result<ServiceState> {
        ServiceState::decode(&self.checkpoint_bytes()?)
            .context("decoding the replay server's checkpoint payload")
    }

    /// Restore a previously captured state into the served tables,
    /// streamed as a `ChunkBegin`/`Chunk…`/`ChunkEnd` upload of bounded
    /// frames. The server stages the chunks connection-locally and
    /// applies the restore only after the final digest verifies — any
    /// violation (or a dropped link) leaves the tables untouched.
    pub fn restore_state(&mut self, state: &ServiceState) -> Result<()> {
        self.restore_state_chunked(state, DEFAULT_CHUNK_LEN)
    }

    /// As [`Self::restore_state`], with an explicit chunk size.
    pub fn restore_state_chunked(
        &mut self,
        state: &ServiceState,
        max_chunk: usize,
    ) -> Result<()> {
        let bytes = state.encode();
        self.upload_chunks(&bytes, max_chunk)?;
        match self.call(&Request::ChunkEnd { total_crc: crc32(&bytes) })? {
            Response::Ok => Ok(()),
            Response::Error { message } => bail!("replay server error: {message}"),
            other => bail!("unexpected response to ChunkEnd: {other:?}"),
        }
    }

    /// Hand a serialized `ServiceState` off for a **merge**: the same
    /// chunked upload as [`Self::restore_state_chunked`], but closed by
    /// `HandoffEnd`, so the receiver inserts the rows (with their exact
    /// checkpointed priorities) into its live tables instead of
    /// replacing them. The drain path of a leaving mesh member.
    pub fn handoff_state_bytes(&mut self, bytes: &[u8], max_chunk: usize) -> Result<()> {
        self.upload_chunks(bytes, max_chunk)?;
        match self.call(&Request::HandoffEnd { total_crc: crc32(bytes) })? {
            Response::Ok => Ok(()),
            Response::Error { message } => bail!("replay server error: {message}"),
            other => bail!("unexpected response to HandoffEnd: {other:?}"),
        }
    }

    /// The shared upload half of a chunked restore or handoff: open
    /// with `ChunkBegin`, stream every bounded `Chunk`, leave the
    /// closing frame (which decides replace vs merge) to the caller.
    fn upload_chunks(&mut self, bytes: &[u8], max_chunk: usize) -> Result<()> {
        let chunk_len = max_chunk.clamp(1, proto::MAX_CHUNK_LEN);
        let chunk_count = bytes.len().div_ceil(chunk_len);
        match self.call(&Request::ChunkBegin {
            total_len: bytes.len() as u64,
            chunk_len: chunk_len as u32,
            chunk_count: chunk_count as u32,
        })? {
            Response::Ok => {}
            Response::Error { message } => bail!("replay server error: {message}"),
            other => bail!("unexpected response to ChunkBegin: {other:?}"),
        }
        for (seq, piece) in bytes.chunks(chunk_len).enumerate() {
            self.enc.reset();
            proto::encode_chunk_request(&mut self.enc, seq as u32, piece);
            self.send_encoded()?;
            self.recv_ok("Chunk")?;
        }
        Ok(())
    }

    /// Ask the server to stop accepting connections and exit.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call_checked(&Request::Shutdown)? {
            Response::Ok => Ok(()),
            other => bail!("unexpected response to Shutdown: {other:?}"),
        }
    }
}

/// The chunk a [`RemoteWriter`] has sent but not yet seen acked: the
/// first `len` steps of the pending queue under sequence `seq`,
/// claiming `dropped` spill drops. Pinned — the spill cap never drops
/// these steps, and a reconnect re-sends them byte-identically so the
/// server's reply cache can dedupe.
struct InflightAppend {
    seq: u64,
    len: usize,
    dropped: u64,
}

/// Remote counterpart of [`crate::service::TrajectoryWriter`]: ships
/// raw env steps to the server, which runs the real writer (item
/// assembly server-side keeps remote and local items byte-identical).
///
/// Steps accumulate client-side and go out `batch` at a time — one
/// `Append` RPC per chunk. With `batch` = 1 ([`Self::connect`]'s
/// default) every step is its own RPC, byte-for-byte the pre-batching
/// behaviour. Steps the limiter has not yet admitted wait in the
/// pending queue, retried by [`ExperienceWriter::throttled`] polls one
/// chunk per RPC, so a long stall re-encodes at most `batch` steps per
/// retry — never the whole backlog.
///
/// The writer is supervised: every append carries a session sequence
/// number, so a chunk whose ack was lost to a dead connection is
/// re-sent after the redial and deduplicated by the server — appends
/// are exactly-once across reconnects. During an outage the pending
/// queue doubles as a bounded spill buffer (see [`Self::with_spill_cap`])
/// and the actor keeps stepping; drops past the cap are counted here
/// and reported to the server as the `steps_dropped` stat.
pub struct RemoteWriter {
    client: RemoteClient,
    actor_id: u64,
    pending: VecDeque<WriterStep>,
    /// Flush threshold AND per-RPC chunk size (≥ 1).
    batch: usize,
    /// Spill bound on `pending` (effective cap is `max(spill_cap,
    /// batch)`; the in-flight chunk is never dropped).
    spill_cap: usize,
    /// The last `Append` was cut short by a limiter stall; cleared
    /// when a flush drains the queue.
    stalled: bool,
    items_emitted: u64,
    wire_steps_sent: u64,
    inflight: Option<InflightAppend>,
    /// Spill drops not yet acked by the server (`steps_dropped` minus
    /// everything already reported in an acked append).
    dropped_unacked: u64,
    steps_dropped: u64,
    connected: bool,
    /// Live outage pacing for the non-blocking paths: the backoff
    /// schedule plus the earliest next redial attempt.
    outage: Option<(Backoff, Instant)>,
}

impl RemoteWriter {
    /// Connect with the legacy one-step-per-RPC behaviour (`batch` 1);
    /// chain [`Self::with_batch`] to enable client-side batching.
    pub fn connect(path: impl AsRef<Path>, actor_id: u64) -> Result<Self> {
        Self::connect_with(path, actor_id, ConnectionPolicy::default())
    }

    /// Connect to a Unix-socket path under an explicit timeout/backoff
    /// policy.
    pub fn connect_with(
        path: impl AsRef<Path>,
        actor_id: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        Self::connect_endpoint_with(&Endpoint::from(path.as_ref()), actor_id, policy)
    }

    /// Connect to a UDS or TCP endpoint under an explicit
    /// timeout/backoff policy.
    pub fn connect_endpoint_with(
        endpoint: &Endpoint,
        actor_id: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        let mut client = RemoteClient::connect_endpoint_with(endpoint, policy)?;
        // Register a resumable session up front (the seed only matters
        // for sampling, which a writer never does).
        client.hello(actor_id)?;
        Ok(Self {
            client,
            actor_id,
            pending: VecDeque::new(),
            batch: 1,
            spill_cap: DEFAULT_SPILL_CAP,
            stalled: false,
            items_emitted: 0,
            wire_steps_sent: 0,
            inflight: None,
            dropped_unacked: 0,
            steps_dropped: 0,
            connected: true,
            outage: None,
        })
    }

    /// Set the flush threshold: steps accumulate until `batch` are
    /// pending, then ship as one `Append` RPC.
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch.clamp(1, MAX_APPEND_STEPS);
        self
    }

    /// Bound the outage spill queue (steps queued past the cap drop
    /// oldest-first, counted in [`Self::steps_dropped`]).
    pub fn with_spill_cap(mut self, cap: usize) -> Self {
        self.spill_cap = cap.max(1);
        self
    }

    /// Items the server reported emitting for this writer so far.
    pub fn items_emitted(&self) -> u64 {
        self.items_emitted
    }

    /// Steps queued client-side (not yet acknowledged by the server).
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Steps dropped out of the spill queue so far (outages longer
    /// than the cap absorbs).
    pub fn steps_dropped(&self) -> u64 {
        self.steps_dropped
    }

    /// Successful redials of the underlying connection.
    pub fn reconnects(&self) -> u64 {
        self.client.reconnects()
    }

    /// Total steps encoded onto the wire, retries included — the
    /// regression probe for the flush path: a stall must re-send at
    /// most one chunk per retry, so this stays O(steps + retries ·
    /// batch), never O(steps²).
    pub fn wire_steps_sent(&self) -> u64 {
        self.wire_steps_sent
    }

    /// Tear every unacked step out of this writer so a mesh failover
    /// can hand it to a replacement writer on another server: the whole
    /// pending queue (the in-flight chunk included — its ack never
    /// arrived, so it is unacked by definition) plus the unreported
    /// spill-drop count. The writer is left empty; the caller owns
    /// delivery from here.
    pub(crate) fn take_unacked(&mut self) -> (VecDeque<WriterStep>, u64) {
        self.inflight = None;
        let dropped = self.dropped_unacked;
        self.dropped_unacked = 0;
        self.stalled = false;
        (std::mem::take(&mut self.pending), dropped)
    }

    /// Adopt unacked work from a failed-over predecessor: its steps
    /// (original order preserved) become this writer's queue, and its
    /// unreported drop count is claimed on this writer's next acked
    /// append — so the drops land in exactly one server's
    /// `steps_dropped` stat. Cross-server failover is at-least-once:
    /// the old server may have applied an append whose ack was lost,
    /// and this writer will deliver those steps again (documented in
    /// [`super::MeshWriter`]).
    pub(crate) fn adopt_pending(&mut self, mut steps: VecDeque<WriterStep>, dropped: u64) {
        steps.extend(self.pending.drain(..));
        self.pending = steps;
        self.dropped_unacked += dropped;
        self.enforce_spill_cap();
    }

    /// Mesh-failover probe: the connection is down AND the spill queue
    /// has hit its cap, i.e. every further step queued evicts one.
    /// Waiting any longer only loses more data, so a writer with
    /// somewhere else to go should go there now.
    pub(crate) fn in_saturated_outage(&self) -> bool {
        !self.connected && self.pending.len() >= self.spill_cap.max(self.batch)
    }

    /// Keep `pending` within the spill cap by dropping the oldest
    /// steps that are not part of the in-flight chunk.
    fn enforce_spill_cap(&mut self) {
        let cap = self.spill_cap.max(self.batch);
        let pinned = self.inflight.as_ref().map_or(0, |f| f.len);
        while self.pending.len() > cap && self.pending.len() > pinned {
            self.pending.remove(pinned);
            self.steps_dropped += 1;
            self.dropped_unacked += 1;
        }
    }

    /// After a successful redial: when the session did NOT resume
    /// (server restart or expiry), the in-flight chunk's sequence
    /// number means nothing to the fresh session — void it so the
    /// steps (still at the queue front) re-ship under a fresh seq.
    fn on_reconnected(&mut self) {
        if !self.client.last_hello_resumed() {
            self.inflight = None;
        }
    }

    /// One non-blocking redial attempt, paced by the outage backoff;
    /// returns whether the connection is usable. Errors only once the
    /// reconnect deadline is exhausted.
    fn try_heal(&mut self) -> Result<bool> {
        let now = Instant::now();
        if let Some((_, next_at)) = &self.outage {
            if now < *next_at {
                return Ok(false);
            }
        }
        match self.client.try_redial() {
            Ok(()) => {
                self.outage = None;
                self.connected = true;
                self.on_reconnected();
                Ok(true)
            }
            Err(e) => {
                if self.outage.is_none() {
                    self.outage = Some((self.client.policy().backoff.start(), now));
                }
                let gave_up = {
                    let (backoff, next_at) =
                        self.outage.as_mut().expect("outage schedule just ensured");
                    match backoff.next_delay() {
                        Some(d) => {
                            *next_at = now + d;
                            None
                        }
                        None => Some((backoff.attempts(), backoff.elapsed(), backoff.deadline())),
                    }
                };
                match gave_up {
                    None => Ok(false),
                    Some((attempts, elapsed, deadline)) => {
                        self.outage = None;
                        Err(e).with_context(|| {
                            format!(
                                "writer gave up reconnecting after {attempts} attempts over \
                                 {elapsed:?} (reconnect deadline {deadline:?}); {} step(s) \
                                 pending, {} dropped",
                                self.pending.len(),
                                self.steps_dropped
                            )
                        })
                    }
                }
            }
        }
    }

    /// Blocking redial under the backoff schedule (the `flush` path:
    /// a checkpoint barrier must deliver or error, not spill).
    fn heal_blocking(&mut self) -> Result<()> {
        let mut backoff = match self.outage.take() {
            Some((b, _)) => b,
            None => self.client.policy().backoff.start(),
        };
        loop {
            match self.client.try_redial() {
                Ok(()) => {
                    self.connected = true;
                    self.on_reconnected();
                    return Ok(());
                }
                Err(e) => match backoff.next_delay() {
                    Some(d) => std::thread::sleep(d),
                    None => {
                        return Err(e).with_context(|| {
                            format!(
                                "writer flush gave up reconnecting after {} attempts over \
                                 {:?} (reconnect deadline {:?}); {} step(s) still pending",
                                backoff.attempts(),
                                backoff.elapsed(),
                                backoff.deadline(),
                                self.pending.len()
                            )
                        });
                    }
                },
            }
        }
    }

    /// The one delivery loop: heal the connection if needed, keep one
    /// chunk in flight, apply acks. Stops early on a limiter stall
    /// (the tail stays queued for the next poll) and — unless
    /// `block_on_outage` — on a dead connection (the queue spills).
    /// Returns the number of steps still pending.
    fn run_flush(&mut self, block_on_outage: bool) -> Result<usize> {
        loop {
            if !self.connected {
                if block_on_outage {
                    self.heal_blocking()?;
                } else if !self.try_heal()? {
                    return Ok(self.pending.len());
                }
            }
            if self.inflight.is_none() {
                if self.pending.is_empty() && self.dropped_unacked == 0 {
                    self.stalled = false;
                    return Ok(0);
                }
                self.inflight = Some(InflightAppend {
                    seq: self.client.alloc_seq(),
                    len: self.pending.len().min(self.batch),
                    dropped: self.dropped_unacked,
                });
            }
            let (seq, len, dropped) = {
                let f = self.inflight.as_ref().expect("in-flight chunk just ensured");
                (f.seq, f.len, f.dropped)
            };
            self.wire_steps_sent += len as u64;
            match self.client.append_steps_seq(
                self.actor_id,
                seq,
                dropped,
                self.pending.iter().take(len),
            ) {
                Ok((consumed, emitted)) => {
                    self.inflight = None;
                    self.dropped_unacked -= dropped;
                    for _ in 0..consumed {
                        self.pending.pop_front();
                    }
                    self.items_emitted += emitted as u64;
                    if (consumed as usize) < len {
                        self.stalled = true; // limiter stall — retriable, not an error
                        return Ok(self.pending.len());
                    }
                    self.stalled = false;
                }
                Err(e) if is_transport_error(&e) => {
                    // The chunk stays pinned in flight: after the next
                    // successful redial it re-ships byte-identical and
                    // the server's reply cache dedupes it. A dead link
                    // is not a limiter stall — the actor must keep
                    // stepping (and spilling), not throttle-poll.
                    self.connected = false;
                    self.stalled = false;
                    if !block_on_outage {
                        return Ok(self.pending.len());
                    }
                }
                Err(e) => {
                    self.inflight = None;
                    return Err(e);
                }
            }
        }
    }
}

impl ExperienceWriter for RemoteWriter {
    fn throttled(&mut self) -> Result<bool> {
        if self.stalled
            || !self.connected
            || self.inflight.is_some()
            || self.pending.len() >= self.batch
        {
            self.run_flush(false)?;
        }
        Ok(self.stalled)
    }

    fn append(&mut self, step: WriterStep) -> Result<usize> {
        let before = self.items_emitted;
        self.pending.push_back(step);
        self.enforce_spill_cap();
        // While stalled, retries belong to the `throttled()` poll (the
        // actor's sleep loop), not to every queued step — that keeps a
        // long stall at one chunk-sized RPC per poll instead of one
        // per append.
        if !self.stalled && self.pending.len() >= self.batch {
            self.run_flush(false)?;
        }
        Ok((self.items_emitted - before) as usize)
    }

    fn flush(&mut self) -> Result<usize> {
        self.run_flush(true)
    }
}

impl Drop for RemoteWriter {
    fn drop(&mut self) {
        // Best-effort: one last try at delivering steps still queued
        // at shutdown. Non-blocking, so a dead server cannot wedge a
        // worker thread in its destructor.
        if self.connected {
            let _ = self.run_flush(false);
        }
    }
}

/// What kind of request a [`RemoteSampler`] has in flight.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum OutstandingKind {
    /// A `Sample` for this batch size.
    Sample(usize),
    /// An `UpdatePriorities` awaiting its `Ok`.
    Update,
}

/// One request the sampler has written but not yet seen answered. The
/// encoded bytes are kept so a reconnect can re-send the request
/// byte-identical — the server's reply cache then either replays the
/// original answer or executes it fresh, exactly once.
struct Outstanding {
    kind: OutstandingKind,
    bytes: Vec<u8>,
}

/// What one [`RemoteSampler::pump_one`] call consumed off the wire.
enum Pumped {
    /// A sample response for a request of batch size `n` (decoded into
    /// the caller's buffer when one was given, stashed otherwise).
    Sample { n: usize, outcome: SampleOutcome },
    /// An `UpdatePriorities` ack.
    Update,
    /// A reconnect dropped everything outstanding (fresh session with
    /// only updates in flight) — nothing left to read.
    Dry,
}

/// Remote counterpart of [`crate::service::SamplerHandle`] on one named
/// table. Sampling randomness lives server-side (seeded at connect),
/// so a fixed seed makes a remote sample/update loop bit-reproducible
/// against an in-process one.
///
/// With [`Self::with_prefetch`] the sampler keeps one decoded batch in
/// flight: each `update_priorities` writes the update *and* the next
/// `Sample` request back-to-back on the connection (the server applies
/// the priorities before drawing, preserving in-process ordering), so
/// the following `try_sample` only reads a response that travelled
/// during the learner's gradient step.
///
/// The sampler is supervised: every request is sequenced and its
/// encoded bytes retained until answered. After a reconnect that
/// *resumed* the session, outstanding requests re-ship byte-identical —
/// the server replays already-executed ones from its reply cache (same
/// bytes, same RNG stream: the pipeline re-arms with no drawn batch
/// lost or duplicated). After a reconnect that could NOT resume
/// (server restart), in-flight priority updates are dropped (counted
/// in [`Self::updates_lost`]) and sample requests re-issue under fresh
/// sequence numbers.
pub struct RemoteSampler {
    client: RemoteClient,
    table: String,
    prefetch: bool,
    /// Batch size of the last granted batch (what a prefetch requests).
    last_batch: Option<usize>,
    /// Requests written but not yet answered, oldest first (responses
    /// arrive in this order).
    outstanding: VecDeque<Outstanding>,
    /// Responses drained out of order (an in-flight sample consumed by
    /// a second consecutive update), oldest first, each tagged with its
    /// requested batch size; handed back by `try_sample` in order so no
    /// granted batch is ever lost.
    stashed: VecDeque<(usize, SampleOutcome, SampleBatch)>,
    /// Priority updates lost to a non-resumable reconnect.
    updates_lost: u64,
}

impl RemoteSampler {
    /// Connect to a named table and seed the connection's sampling RNG.
    pub fn connect(
        path: impl AsRef<Path>,
        table: impl Into<String>,
        rng_seed: u64,
    ) -> Result<Self> {
        let mut client = RemoteClient::connect(path)?;
        client.hello(rng_seed)?;
        Ok(Self::new(client, table.into()))
    }

    /// Connect to the server's default (first) table: one dial, one
    /// round-trip — the `Hello` response names the table.
    pub fn connect_default(path: impl AsRef<Path>, rng_seed: u64) -> Result<Self> {
        Self::connect_default_with(path, rng_seed, ConnectionPolicy::default())
    }

    /// As [`Self::connect_default`], under an explicit timeout/backoff
    /// policy.
    pub fn connect_default_with(
        path: impl AsRef<Path>,
        rng_seed: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        Self::connect_default_endpoint_with(&Endpoint::from(path.as_ref()), rng_seed, policy)
    }

    /// As [`Self::connect_default`], to a UDS or TCP endpoint under an
    /// explicit timeout/backoff policy.
    pub fn connect_default_endpoint_with(
        endpoint: &Endpoint,
        rng_seed: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        let mut client = RemoteClient::connect_endpoint_with(endpoint, policy)?;
        let table = client.hello(rng_seed)?;
        if table.is_empty() {
            bail!("replay server reports no default table");
        }
        Ok(Self::new(client, table))
    }

    fn new(client: RemoteClient, table: String) -> Self {
        Self {
            client,
            table,
            prefetch: false,
            last_batch: None,
            outstanding: VecDeque::new(),
            stashed: VecDeque::new(),
            updates_lost: 0,
        }
    }

    /// Enable pipelined sampling (one batch kept in flight).
    pub fn with_prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    pub fn table(&self) -> &str {
        &self.table
    }

    /// Successful redials of the underlying connection.
    pub fn reconnects(&self) -> u64 {
        self.client.reconnects()
    }

    /// Priority updates lost because a reconnect could not resume the
    /// session (the server restarted; the items they targeted may no
    /// longer exist).
    pub fn updates_lost(&self) -> u64 {
        self.updates_lost
    }

    /// Sequence, encode, queue, and (best-effort) send one `Sample`
    /// request. A transport failure here still leaves the request
    /// queued — the pump's reconnect path re-sends it.
    fn issue_sample(&mut self, n: usize) -> Result<()> {
        let seq = self.client.alloc_seq();
        let mut w = ByteWriter::new();
        proto::encode_sample(&mut w, &self.table, n as u32, seq);
        self.outstanding
            .push_back(Outstanding { kind: OutstandingKind::Sample(n), bytes: w.finish() });
        self.client
            .send_payload(&self.outstanding.back().expect("request just queued").bytes)
    }

    /// Heal the connection and re-arm the pipeline: on a resumed
    /// session every outstanding request re-ships byte-identical (the
    /// reply cache dedupes); on a fresh session updates are dropped
    /// and samples re-issued under fresh sequence numbers.
    fn recover(&mut self, cause: &anyhow::Error) -> Result<()> {
        self.client
            .reconnect()
            .with_context(|| format!("sampler lost the replay connection ({cause})"))?;
        if self.client.last_hello_resumed() {
            for o in &self.outstanding {
                self.client.send_payload(&o.bytes)?;
            }
        } else {
            let mut reissue = Vec::new();
            for o in self.outstanding.drain(..) {
                match o.kind {
                    OutstandingKind::Update => self.updates_lost += 1,
                    OutstandingKind::Sample(n) => reissue.push(n),
                }
            }
            for n in reissue {
                self.issue_sample(n)?;
            }
        }
        Ok(())
    }

    /// Read one response off the wire and pop the request it answers.
    /// A transport failure runs the supervised reconnect (bounded
    /// rounds) and retries; an application error pops the request it
    /// answered and surfaces.
    fn pump_one(&mut self, mut out: Option<&mut SampleBatch>) -> Result<Pumped> {
        let mut rounds = 0u32;
        loop {
            let front = match self.outstanding.front() {
                Some(o) => o.kind,
                None if rounds > 0 => return Ok(Pumped::Dry),
                None => bail!("internal: sampler pump with no outstanding request"),
            };
            let result = match front {
                OutstandingKind::Update => {
                    self.client.recv_ok("UpdatePriorities").map(|()| Pumped::Update)
                }
                OutstandingKind::Sample(n) => match out.as_deref_mut() {
                    Some(buf) => self
                        .client
                        .recv_sample(buf)
                        .map(|outcome| Pumped::Sample { n, outcome }),
                    None => {
                        let mut scratch = SampleBatch::default();
                        match self.client.recv_sample(&mut scratch) {
                            Ok(outcome) => {
                                self.stashed.push_back((n, outcome, scratch));
                                Ok(Pumped::Sample { n, outcome })
                            }
                            Err(e) => Err(e),
                        }
                    }
                },
            };
            match result {
                Ok(p) => {
                    self.outstanding.pop_front();
                    return Ok(p);
                }
                Err(e) if is_transport_error(&e) => {
                    rounds += 1;
                    if rounds > MAX_RECOVER_ROUNDS {
                        return Err(e).context(format!(
                            "sampler could not stabilize the replay connection after \
                             {MAX_RECOVER_ROUNDS} reconnect rounds"
                        ));
                    }
                    if let Err(re) = self.recover(&e) {
                        if !is_transport_error(&re) {
                            return Err(re);
                        }
                        // The link flapped during recovery; the next
                        // round reconnects again.
                    }
                }
                Err(e) => {
                    self.outstanding.pop_front();
                    return Err(e);
                }
            }
        }
    }

    /// Consume every outstanding response and report the last sample
    /// outcome seen, if any. A `Sampled` outcome here is a batch the
    /// server granted (and counted) that this client will never use —
    /// callers that audit exact accounting must tally it.
    pub fn drain(&mut self) -> Result<Option<SampleOutcome>> {
        let keep = self.stashed.len();
        let mut last = None;
        while !self.outstanding.is_empty() {
            if let Pumped::Sample { outcome, .. } = self.pump_one(None)? {
                last = Some(outcome);
            }
        }
        // Batches pumped here were drained, not delivered; report
        // their outcome but do not hand them to a later `try_sample`.
        self.stashed.truncate(keep);
        Ok(last)
    }
}

impl ExperienceSampler for RemoteSampler {
    fn try_sample(
        &mut self,
        batch: usize,
        _rng: &mut Rng,
        out: &mut SampleBatch,
    ) -> Result<SampleOutcome> {
        if let Some((n, outcome, mut stashed)) = self.stashed.pop_front() {
            if n != batch {
                bail!(
                    "stashed sample batch size does not match the request ({n} stashed, \
                     {batch} requested)"
                );
            }
            std::mem::swap(out, &mut stashed);
            if outcome == SampleOutcome::Sampled {
                self.last_batch = Some(batch);
            }
            return Ok(outcome);
        }
        loop {
            if !self
                .outstanding
                .iter()
                .any(|o| matches!(o.kind, OutstandingKind::Sample(_)))
            {
                if let Err(e) = self.issue_sample(batch) {
                    if !is_transport_error(&e) {
                        return Err(e);
                    }
                }
            }
            match self.pump_one(Some(&mut *out))? {
                Pumped::Sample { n, outcome } => {
                    if n != batch {
                        bail!(
                            "pipelined sample batch size changed mid-flight ({n} in flight, \
                             {batch} requested)"
                        );
                    }
                    if outcome == SampleOutcome::Sampled {
                        self.last_batch = Some(batch);
                    }
                    return Ok(outcome);
                }
                Pumped::Update | Pumped::Dry => continue,
            }
        }
    }

    fn update_priorities(&mut self, indices: &[usize], td_abs: &[f32]) -> Result<()> {
        let seq = self.client.alloc_seq();
        let mut w = ByteWriter::new();
        proto::encode_update_priorities(&mut w, &self.table, indices, td_abs, seq);
        self.outstanding
            .push_back(Outstanding { kind: OutstandingKind::Update, bytes: w.finish() });
        if let Err(e) = self
            .client
            .send_payload(&self.outstanding.back().expect("request just queued").bytes)
        {
            if !is_transport_error(&e) {
                return Err(e);
            }
        }
        if self.prefetch {
            if let Some(n) = self.last_batch {
                // Written strictly after the update on the same stream:
                // the server applies the new priorities, then draws.
                if let Err(e) = self.issue_sample(n) {
                    if !is_transport_error(&e) {
                        return Err(e);
                    }
                }
            }
        }
        // Read until this update's ack is in; sample responses reached
        // along the way (a stale prefetch) land in the stash.
        while self.outstanding.iter().any(|o| o.kind == OutstandingKind::Update) {
            self.pump_one(None)?;
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        self.drain().map(|_| ())
    }
}
