//! Mesh membership: a per-server health state machine the mesh clients
//! drive from their own RPC outcomes, so a dead or partitioned replay
//! server degrades the mesh instead of stalling it.
//!
//! The ladder is `Up → Suspect → Down → Rejoining → Up`:
//!
//! * **Up** — healthy; receives affinity appends and mass-proportional
//!   sample draws.
//! * **Suspect** — one or more recent transport failures, below the
//!   `down_after` threshold. Still counted live (a blip should not
//!   reshuffle traffic), but the next failure brings it closer to Down.
//! * **Down** — `down_after` consecutive transport failures. Excluded
//!   from the level-1 mass draw (its mass reads as zero and the
//!   survivors renormalize) and skipped by writer failover. A Down
//!   server is re-probed on a seeded-jitter schedule rather than on
//!   every call, so a dead member costs one cheap probe per interval,
//!   not one timeout per batch.
//! * **Rejoining** — a probe is in flight against a Down server. One
//!   success promotes it straight to Up (it resumes affinity traffic
//!   and mass draws); a failure sends it back to Down and reschedules.
//!
//! # Determinism
//!
//! There are no background threads and no ambient clocks in here: the
//! mesh calls [`Membership::record_success`] / `record_failure` with
//! its own RPC outcomes and passes `Instant`s in, and probe-schedule
//! jitter is drawn from a seeded [`Rng`] stream. Two meshes with the
//! same seed and the same failure history schedule identical probes —
//! the same property the chaos proxy's decision streams have, and what
//! makes the failover tests replayable.

use crate::util::rng::Rng;
use std::time::{Duration, Instant};

/// One server's position on the health ladder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HealthState {
    /// Healthy: full traffic.
    Up,
    /// Recent failures below the Down threshold: full traffic, on
    /// notice.
    Suspect,
    /// Unreachable: excluded from draws and failover targets, probed on
    /// the seeded schedule.
    Down,
    /// A recovery probe is in flight; one success promotes to Up.
    Rejoining,
}

/// Thresholds and probe pacing for a [`Membership`].
#[derive(Clone, Debug)]
pub struct HealthPolicy {
    /// Consecutive transport failures before a server is Suspect.
    pub suspect_after: u32,
    /// Consecutive transport failures before a server is Down.
    pub down_after: u32,
    /// Base interval between recovery probes of a Down server; the
    /// actual gap is jittered to `[0.5, 1.5] ×` this, seeded.
    pub probe_interval: Duration,
    /// Seed of the jitter stream (see the module docs).
    pub jitter_seed: u64,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        Self {
            suspect_after: 1,
            down_after: 3,
            probe_interval: Duration::from_millis(250),
            jitter_seed: 0x4845_414C, // "HEAL"
        }
    }
}

struct Member {
    state: HealthState,
    fails: u32,
    next_probe_at: Option<Instant>,
}

/// Health bookkeeping for a fixed-size mesh member list (servers are
/// identified by their index in the mesh's endpoint list).
pub struct Membership {
    policy: HealthPolicy,
    members: Vec<Member>,
    rng: Rng,
    downs: u64,
    rejoins: u64,
}

impl Membership {
    /// All `n` servers start Up.
    pub fn new(n: usize, policy: HealthPolicy) -> Self {
        let rng = Rng::new(policy.jitter_seed);
        let members = (0..n)
            .map(|_| Member { state: HealthState::Up, fails: 0, next_probe_at: None })
            .collect();
        Self { policy, members, rng, downs: 0, rejoins: 0 }
    }

    pub fn server_count(&self) -> usize {
        self.members.len()
    }

    pub fn state(&self, server: usize) -> HealthState {
        self.members[server].state
    }

    /// Live = participates in draws and is a failover target (Up,
    /// Suspect, or mid-rejoin — everything but Down).
    pub fn is_live(&self, server: usize) -> bool {
        self.members[server].state != HealthState::Down
    }

    /// How many servers are currently live.
    pub fn live_count(&self) -> usize {
        self.members.iter().filter(|m| m.state != HealthState::Down).count()
    }

    /// Total Up/Suspect→Down transitions so far.
    pub fn downs(&self) -> u64 {
        self.downs
    }

    /// Total Down/Rejoining→Up recoveries so far.
    pub fn rejoins(&self) -> u64 {
        self.rejoins
    }

    /// An RPC against `server` succeeded: clear its failure streak and
    /// promote it to Up (counting a rejoin if it was Down/Rejoining).
    pub fn record_success(&mut self, server: usize) {
        let m = &mut self.members[server];
        if matches!(m.state, HealthState::Down | HealthState::Rejoining) {
            self.rejoins += 1;
        }
        m.state = HealthState::Up;
        m.fails = 0;
        m.next_probe_at = None;
    }

    /// An RPC against `server` failed at the transport: advance it down
    /// the ladder and, on reaching Down, schedule its next recovery
    /// probe relative to `now`.
    pub fn record_failure(&mut self, server: usize, now: Instant) {
        let fails = {
            let m = &mut self.members[server];
            m.fails = m.fails.saturating_add(1);
            m.fails
        };
        if fails >= self.policy.down_after {
            if self.members[server].state != HealthState::Down {
                self.downs += 1;
            }
            let gap = self.policy.probe_interval.mul_f64(0.5 + self.rng.f64());
            let m = &mut self.members[server];
            m.state = HealthState::Down;
            m.next_probe_at = Some(now + gap);
        } else if fails >= self.policy.suspect_after {
            self.members[server].state = HealthState::Suspect;
        }
    }

    /// Is a recovery probe of this Down server due at `now`?
    pub fn probe_due(&self, server: usize, now: Instant) -> bool {
        let m = &self.members[server];
        m.state == HealthState::Down && m.next_probe_at.is_some_and(|at| at <= now)
    }

    /// Mark a recovery probe as in flight (Down → Rejoining) and push
    /// the next probe slot out, so a failed probe does not retry until
    /// the schedule says so.
    pub fn begin_rejoin(&mut self, server: usize, now: Instant) {
        let gap = self.policy.probe_interval.mul_f64(0.5 + self.rng.f64());
        let m = &mut self.members[server];
        m.state = HealthState::Rejoining;
        m.next_probe_at = Some(now + gap);
    }

    /// A probe against a Rejoining server failed: straight back to Down
    /// (the streak never cleared), keeping the already-pushed-out probe
    /// slot.
    pub fn probe_failed(&mut self, server: usize) {
        let m = &mut self.members[server];
        if m.state == HealthState::Rejoining {
            m.state = HealthState::Down;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> HealthPolicy {
        HealthPolicy {
            suspect_after: 1,
            down_after: 3,
            probe_interval: Duration::from_millis(100),
            jitter_seed: 7,
        }
    }

    #[test]
    fn ladder_up_suspect_down_and_back() {
        let mut m = Membership::new(2, policy());
        let t0 = Instant::now();
        assert_eq!(m.state(0), HealthState::Up);
        assert!(m.is_live(0));

        m.record_failure(0, t0);
        assert_eq!(m.state(0), HealthState::Suspect);
        assert!(m.is_live(0), "a Suspect server still takes traffic");
        m.record_failure(0, t0);
        assert_eq!(m.state(0), HealthState::Suspect);
        m.record_failure(0, t0);
        assert_eq!(m.state(0), HealthState::Down);
        assert!(!m.is_live(0));
        assert_eq!(m.downs(), 1);
        assert_eq!(m.live_count(), 1);
        // The untouched peer is unaffected.
        assert_eq!(m.state(1), HealthState::Up);

        // Recovery: probe → success → Up, rejoin counted.
        m.begin_rejoin(0, t0);
        assert_eq!(m.state(0), HealthState::Rejoining);
        assert!(m.is_live(0));
        m.record_success(0);
        assert_eq!(m.state(0), HealthState::Up);
        assert_eq!(m.rejoins(), 1);

        // The streak reset: it takes three fresh failures to go Down
        // again.
        m.record_failure(0, t0);
        assert_eq!(m.state(0), HealthState::Suspect);
        assert_eq!(m.downs(), 1);
    }

    #[test]
    fn down_servers_probe_on_the_jittered_schedule() {
        let mut m = Membership::new(1, policy());
        let t0 = Instant::now();
        for _ in 0..3 {
            m.record_failure(0, t0);
        }
        assert_eq!(m.state(0), HealthState::Down);
        // Jitter is bounded to [0.5, 1.5] × interval: not due
        // immediately, always due after 2×.
        assert!(!m.probe_due(0, t0));
        assert!(!m.probe_due(0, t0 + Duration::from_millis(49)));
        assert!(m.probe_due(0, t0 + Duration::from_millis(200)));

        // Beginning a rejoin pushes the slot out; a failed probe goes
        // back to Down without making the next probe due early.
        m.begin_rejoin(0, t0 + Duration::from_millis(200));
        assert_eq!(m.state(0), HealthState::Rejoining);
        assert!(!m.probe_due(0, t0 + Duration::from_millis(200)), "Rejoining is not re-probed");
        m.probe_failed(0);
        assert_eq!(m.state(0), HealthState::Down);
        assert!(!m.probe_due(0, t0 + Duration::from_millis(249)));
        assert!(m.probe_due(0, t0 + Duration::from_millis(400)));
    }

    #[test]
    fn same_seed_same_probe_schedule() {
        let t0 = Instant::now();
        let schedule = |seed: u64| -> Vec<Instant> {
            let mut m = Membership::new(4, HealthPolicy { jitter_seed: seed, ..policy() });
            let mut out = Vec::new();
            for s in 0..4 {
                for _ in 0..3 {
                    m.record_failure(s, t0);
                }
                // Recover the probe deadline by bisection against
                // probe_due — the public surface is enough to pin the
                // schedule.
                let mut lo = 0u64; // µs offsets; jitter caps at 150ms
                let mut hi = 200_000u64;
                while lo < hi {
                    let mid = (lo + hi) / 2;
                    if m.probe_due(s, t0 + Duration::from_micros(mid)) {
                        hi = mid;
                    } else {
                        lo = mid + 1;
                    }
                }
                out.push(t0 + Duration::from_micros(lo));
            }
            out
        };
        assert_eq!(schedule(7), schedule(7));
        assert_ne!(schedule(7), schedule(8), "different seeds must differ somewhere");
    }

    #[test]
    fn probe_failed_outside_rejoin_is_a_no_op() {
        let mut m = Membership::new(1, policy());
        m.probe_failed(0);
        assert_eq!(m.state(0), HealthState::Up);
    }
}
