//! Remote replay front-end: a socket transport (Unix-domain or TCP) in
//! front of the in-process [`crate::service::ReplayService`], so
//! parallel actors and parallel learners can live in **separate
//! processes — or on separate hosts** — from the experience server(s):
//! the Reverb multi-server deployment shape (Cassirer et al., 2021)
//! the service module was built toward.
//!
//! std-only: `std::os::unix::net` / `std::net` streams carrying
//! length-prefixed frames in the same magic/CRC discipline as the
//! on-disk [`crate::util::blob`] format.
//!
//! * [`transport`] — [`Endpoint`] / [`RpcListener`] / [`RpcStream`]:
//!   one listener/dialer pair over UDS and TCP; the protocol above it
//!   is transport-blind.
//! * [`frame`] — wire framing (`PALRPC02` magic + length + payload +
//!   crc32); every malformed input is a descriptive error, never a
//!   panic.
//! * [`proto`] — the RPC surface: `Hello`, `Append`, `Sample`,
//!   `UpdatePriorities`, `Stats`, `Checkpoint`, `Restore`, `Shutdown`,
//!   `Mass`, plus the chunked state-transfer stream
//!   (`CheckpointChunked`, `ChunkBegin`/`Chunk`/`ChunkEnd`).
//! * [`server`] — [`ReplayServer`]: accept loop + resumable sessions
//!   (server-side writers, sampling RNGs, request-sequence reply
//!   caches).
//! * [`client`] — [`RemoteClient`] plus the [`RemoteWriter`] /
//!   [`RemoteSampler`] handles implementing
//!   [`crate::service::ExperienceWriter`] /
//!   [`crate::service::ExperienceSampler`], so `actor.rs` /
//!   `learner.rs` switch transports at the trait level only.
//! * [`mesh`] — [`MeshWriter`] / [`MeshSampler`]: client-side routing
//!   of ONE logical table over N replay servers (actor → server by
//!   affinity; two-level sampling that picks a server by advertised
//!   priority mass, then samples within — the
//!   [`crate::replay::ShardedPrioritizedReplay`] shape, across hosts).
//! * [`membership`] — [`Membership`]: the per-server health ladder
//!   (Up → Suspect → Down → Rejoining) both mesh handles drive from
//!   their RPC outcomes, with seeded-jitter recovery probes; what makes
//!   the mesh degrade (and heal) instead of stalling on a dead member.
//! * [`backoff`] — the shared reconnect schedule (exponential, seeded
//!   jitter, overall deadline) every supervised handle retries under.
//! * [`chaos`] — a seeded fault-injecting proxy ([`ChaosProxy`]) for
//!   the chaos soaks and the CI restart drill, on both transports.
//!
//! Rate limiters keep their semantics across the wire: a stalled
//! sample is a retriable `WouldStall` frame, a stalled insert a short
//! `Appended` frame — connections never block on admission.
//!
//! The data path is built for throughput: writers batch steps
//! client-side (one `Append` RPC per `--remote-batch` chunk), samplers
//! pipeline one batch in flight behind every priority update, and both
//! sides of the socket reuse their framing and encode/decode buffers —
//! the client allocates nothing per RPC in steady state; the server
//! allocates only the owned `WriterStep`s an `Append` delivers into
//! storage (`benches/fig_remote.rs` measures all of it).
//!
//! And it is built to survive faults: every connection is supervised
//! (backoff + deadline reconnects), every session is resumable, and
//! sequenced requests are exactly-once across reconnects via the
//! server's reply cache — see the module docs of [`client`] and
//! [`server`] for the contract, and [`chaos`] for how it is tortured
//! in CI.

pub mod backoff;
pub mod chaos;
pub mod client;
pub mod frame;
pub mod membership;
pub mod mesh;
pub mod proto;
pub mod server;
pub mod transport;

pub use backoff::{Backoff, BackoffPolicy};
pub use chaos::{ChaosConfig, ChaosProxy};
pub use client::{
    ConnectionPolicy, RemoteClient, RemoteSampler, RemoteWriter, DEFAULT_REMOTE_BATCH,
    DEFAULT_RPC_TIMEOUT, DEFAULT_SPILL_CAP,
};
pub use frame::{read_frame, read_frame_into, write_frame, FRAME_MAGIC, MAX_FRAME_LEN};
pub use membership::{HealthPolicy, HealthState, Membership};
pub use mesh::{parse_endpoint_list, MeshSampler, MeshSamplerCounters, MeshWriter};
pub use proto::{Request, Response, StallReason, TableInfo};
pub use server::ReplayServer;
pub use transport::{Endpoint, RpcListener, RpcStream};
