//! Remote replay front-end: a Unix-domain-socket transport in front of
//! the in-process [`crate::service::ReplayService`], so parallel
//! actors and parallel learners can live in **separate processes** from
//! the experience server — the Reverb server shape (Cassirer et al.,
//! 2021) the service module was built toward.
//!
//! std-only: `std::os::unix::net` streams carrying length-prefixed
//! frames in the same magic/CRC discipline as the on-disk
//! [`crate::util::blob`] format.
//!
//! * [`frame`] — wire framing (`PALRPC01` magic + length + payload +
//!   crc32); every malformed input is a descriptive error, never a
//!   panic.
//! * [`proto`] — the RPC surface: `Append`, `Sample`,
//!   `UpdatePriorities`, `Stats`, `Checkpoint`, `Restore`, `Shutdown`.
//! * [`server`] — [`ReplayServer`]: accept loop + per-connection
//!   server-side writers and sampling RNGs.
//! * [`client`] — [`RemoteClient`] plus the [`RemoteWriter`] /
//!   [`RemoteSampler`] handles implementing
//!   [`crate::service::ExperienceWriter`] /
//!   [`crate::service::ExperienceSampler`], so `actor.rs` /
//!   `learner.rs` switch transports at the trait level only.
//!
//! Rate limiters keep their semantics across the wire: a stalled
//! sample is a retriable `WouldStall` frame, a stalled insert a short
//! `Appended` frame — connections never block on admission.
//!
//! The data path is built for throughput: writers batch steps
//! client-side (one `Append` RPC per `--remote-batch` chunk), samplers
//! pipeline one batch in flight behind every priority update, and both
//! sides of the socket reuse their framing and encode/decode buffers —
//! the client allocates nothing per RPC in steady state; the server
//! allocates only the owned `WriterStep`s an `Append` delivers into
//! storage (`benches/fig_remote.rs` measures all of it).

pub mod client;
pub mod frame;
pub mod proto;
pub mod server;

pub use client::{RemoteClient, RemoteSampler, RemoteWriter, DEFAULT_REMOTE_BATCH};
pub use frame::{read_frame, read_frame_into, write_frame, FRAME_MAGIC, MAX_FRAME_LEN};
pub use proto::{Request, Response, StallReason, TableInfo};
pub use server::ReplayServer;
