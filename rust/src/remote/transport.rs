//! Transport abstraction for the remote replay front-end: one
//! listener/dialer pair over Unix-domain sockets AND TCP, so the exact
//! same `PALRPC02` frames, sessions and reply-cache semantics run
//! cross-host with no protocol change (the framing layer is already
//! generic over `Read`/`Write` — this module only abstracts where the
//! bytes come from).
//!
//! * [`Endpoint`] — a parsed server address: a filesystem socket path
//!   (`Uds`) or a `host:port` pair (`Tcp`). The CLI grammar is
//!   `tcp://HOST:PORT` (or `uds://PATH` for symmetry); a bare string is
//!   a UDS path, which keeps every existing `--remote PATH` invocation
//!   working unchanged.
//! * [`RpcStream`] — one connected byte stream behind `Read`/`Write`
//!   plus the timeout/shutdown surface the client and server supervise
//!   connections with. TCP streams set `TCP_NODELAY`: frames are small
//!   and latency-sensitive (a sample round-trip sits on the learner's
//!   critical path), so Nagle batching would serialize the pipeline.
//! * [`RpcListener`] — a bound, nonblocking acceptor. The UDS arm owns
//!   the stale-socket dance (probe a leftover socket file for a live
//!   server before unlinking it) and removes its socket file on
//!   cleanup; the TCP arm reports the actual bound address so `:0`
//!   (ephemeral port) binds are test-friendly.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

/// A replay-server address: Unix-domain socket path or TCP `host:port`.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Endpoint {
    Uds(PathBuf),
    Tcp(String),
}

impl Endpoint {
    /// Parse an endpoint string: `tcp://HOST:PORT` dials TCP,
    /// `uds://PATH` (or any bare string) is a Unix socket path.
    pub fn parse(s: &str) -> Result<Self> {
        let s = s.trim();
        if s.is_empty() {
            bail!("empty endpoint");
        }
        if let Some(addr) = s.strip_prefix("tcp://") {
            return Self::tcp(addr);
        }
        if let Some(path) = s.strip_prefix("uds://") {
            if path.is_empty() {
                bail!("endpoint `{s}` has an empty socket path");
            }
            return Ok(Endpoint::Uds(PathBuf::from(path)));
        }
        Ok(Endpoint::Uds(PathBuf::from(s)))
    }

    /// A TCP endpoint from a `host:port` address (validated to contain
    /// a port — `TcpStream::connect` errors on a bare host are cryptic).
    pub fn tcp(addr: &str) -> Result<Self> {
        let addr = addr.trim();
        match addr.rsplit_once(':') {
            Some((host, port)) if !host.is_empty() && port.parse::<u16>().is_ok() => {
                Ok(Endpoint::Tcp(addr.to_string()))
            }
            _ => bail!("TCP endpoint `{addr}` must be HOST:PORT"),
        }
    }

    /// Dial the endpoint, returning a connected stream (TCP with
    /// `TCP_NODELAY` set — see module docs).
    pub fn dial(&self) -> std::io::Result<RpcStream> {
        match self {
            Endpoint::Uds(path) => UnixStream::connect(path).map(RpcStream::Unix),
            Endpoint::Tcp(addr) => {
                let s = TcpStream::connect(addr.as_str())?;
                s.set_nodelay(true)?;
                Ok(RpcStream::Tcp(s))
            }
        }
    }
}

impl std::fmt::Display for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Endpoint::Uds(p) => write!(f, "{}", p.display()),
            Endpoint::Tcp(a) => write!(f, "tcp://{a}"),
        }
    }
}

impl From<&Path> for Endpoint {
    fn from(p: &Path) -> Self {
        Endpoint::Uds(p.to_path_buf())
    }
}

impl From<PathBuf> for Endpoint {
    fn from(p: PathBuf) -> Self {
        Endpoint::Uds(p)
    }
}

/// One connected RPC byte stream (either transport) behind the exact
/// surface the client/server code supervises connections with.
#[derive(Debug)]
pub enum RpcStream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl RpcStream {
    pub fn set_read_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            RpcStream::Unix(s) => s.set_read_timeout(d),
            RpcStream::Tcp(s) => s.set_read_timeout(d),
        }
    }

    pub fn set_write_timeout(&self, d: Option<Duration>) -> std::io::Result<()> {
        match self {
            RpcStream::Unix(s) => s.set_write_timeout(d),
            RpcStream::Tcp(s) => s.set_write_timeout(d),
        }
    }

    pub fn set_nonblocking(&self, on: bool) -> std::io::Result<()> {
        match self {
            RpcStream::Unix(s) => s.set_nonblocking(on),
            RpcStream::Tcp(s) => s.set_nonblocking(on),
        }
    }

    pub fn shutdown(&self, how: Shutdown) -> std::io::Result<()> {
        match self {
            RpcStream::Unix(s) => s.shutdown(how),
            RpcStream::Tcp(s) => s.shutdown(how),
        }
    }

    pub fn try_clone(&self) -> std::io::Result<Self> {
        Ok(match self {
            RpcStream::Unix(s) => RpcStream::Unix(s.try_clone()?),
            RpcStream::Tcp(s) => RpcStream::Tcp(s.try_clone()?),
        })
    }
}

impl Read for RpcStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            RpcStream::Unix(s) => s.read(buf),
            RpcStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for RpcStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            RpcStream::Unix(s) => s.write(buf),
            RpcStream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            RpcStream::Unix(s) => s.flush(),
            RpcStream::Tcp(s) => s.flush(),
        }
    }
}

/// A bound, nonblocking acceptor on either transport.
pub enum RpcListener {
    Unix { listener: UnixListener, path: PathBuf },
    Tcp { listener: TcpListener, addr: String },
}

impl RpcListener {
    /// Bind an endpoint for serving. UDS refuses to clobber a live
    /// server (a leftover socket file is probed with a connect before
    /// being unlinked) and refuses non-socket files outright; TCP is a
    /// plain bind, with the ACTUAL bound address recorded so `:0`
    /// (ephemeral-port) binds report where they landed.
    pub fn bind(endpoint: &Endpoint) -> Result<Self> {
        match endpoint {
            Endpoint::Uds(path) => {
                match std::fs::symlink_metadata(path) {
                    Ok(md) if !md.file_type().is_socket() => bail!(
                        "refusing to serve on {}: exists and is not a socket",
                        path.display()
                    ),
                    Ok(_) => {
                        // A socket file is either a live server (error:
                        // never steal its clients) or a stale leftover
                        // from a crash (unlink and move in).
                        if UnixStream::connect(path).is_ok() {
                            bail!(
                                "a replay server is already listening on {}",
                                path.display()
                            );
                        }
                        std::fs::remove_file(path).with_context(|| {
                            format!("removing stale socket {}", path.display())
                        })?;
                    }
                    Err(_) => {}
                }
                let listener = UnixListener::bind(path)
                    .with_context(|| format!("binding {}", path.display()))?;
                listener.set_nonblocking(true)?;
                Ok(RpcListener::Unix { listener, path: path.clone() })
            }
            Endpoint::Tcp(addr) => {
                let listener = TcpListener::bind(addr.as_str())
                    .with_context(|| format!("binding tcp://{addr}"))?;
                listener.set_nonblocking(true)?;
                let addr = listener
                    .local_addr()
                    .map(|a| a.to_string())
                    .unwrap_or_else(|_| addr.clone());
                Ok(RpcListener::Tcp { listener, addr })
            }
        }
    }

    /// Accept one pending connection (nonblocking — `WouldBlock` when
    /// none is waiting). TCP connections get `TCP_NODELAY`.
    pub fn accept(&self) -> std::io::Result<RpcStream> {
        match self {
            RpcListener::Unix { listener, .. } => {
                listener.accept().map(|(s, _)| RpcStream::Unix(s))
            }
            RpcListener::Tcp { listener, .. } => {
                let (s, _) = listener.accept()?;
                s.set_nodelay(true).ok();
                Ok(RpcStream::Tcp(s))
            }
        }
    }

    /// The endpoint this listener is actually serving on (for TCP, the
    /// resolved bound address — meaningful after an ephemeral bind).
    pub fn endpoint(&self) -> Endpoint {
        match self {
            RpcListener::Unix { path, .. } => Endpoint::Uds(path.clone()),
            RpcListener::Tcp { addr, .. } => Endpoint::Tcp(addr.clone()),
        }
    }

    /// Release transport resources a closed listener leaves behind: the
    /// UDS socket file (best-effort — the bind-time stale probe handles
    /// a missed unlink). TCP has nothing to clean.
    pub fn cleanup(&self) {
        if let RpcListener::Unix { path, .. } = self {
            std::fs::remove_file(path).ok();
        }
    }
}

// `is_socket` on symlink_metadata needs the unix FileTypeExt.
use std::os::unix::fs::FileTypeExt as _;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_grammar() {
        assert_eq!(
            Endpoint::parse("/tmp/replay.sock").unwrap(),
            Endpoint::Uds(PathBuf::from("/tmp/replay.sock"))
        );
        assert_eq!(
            Endpoint::parse("uds:///run/pal.sock").unwrap(),
            Endpoint::Uds(PathBuf::from("/run/pal.sock"))
        );
        assert_eq!(
            Endpoint::parse("tcp://127.0.0.1:7777").unwrap(),
            Endpoint::Tcp("127.0.0.1:7777".to_string())
        );
        assert!(Endpoint::parse("").is_err());
        assert!(Endpoint::parse("tcp://").is_err());
        assert!(Endpoint::parse("tcp://nohost").is_err());
        assert!(Endpoint::parse("tcp://:99999").is_err());
        assert!(Endpoint::parse("uds://").is_err());
        // Display round-trips through parse for both transports.
        for s in ["/tmp/a.sock", "tcp://127.0.0.1:8080"] {
            let e = Endpoint::parse(s).unwrap();
            assert_eq!(Endpoint::parse(&e.to_string()).unwrap(), e);
        }
    }

    #[test]
    fn tcp_listener_accepts_and_streams_bytes() {
        let l = RpcListener::bind(&Endpoint::Tcp("127.0.0.1:0".into())).unwrap();
        let ep = l.endpoint();
        // The ephemeral bind must report a concrete port.
        match &ep {
            Endpoint::Tcp(a) => assert!(!a.ends_with(":0"), "{a}"),
            other => panic!("tcp bind reported {other:?}"),
        }
        let mut client = ep.dial().unwrap();
        let mut server = loop {
            match l.accept() {
                Ok(s) => break s,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1))
                }
                Err(e) => panic!("accept: {e}"),
            }
        };
        client.write_all(b"ping").unwrap();
        let mut buf = [0u8; 4];
        server.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"ping");
        server.write_all(b"pong").unwrap();
        client.read_exact(&mut buf).unwrap();
        assert_eq!(&buf, b"pong");
    }

    #[test]
    fn uds_listener_keeps_stale_socket_semantics() {
        let dir = std::env::temp_dir().join(format!("pal_transport_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.sock");
        // A non-socket file at the path is refused.
        std::fs::write(&path, b"junk").unwrap();
        assert!(RpcListener::bind(&Endpoint::Uds(path.clone())).is_err());
        std::fs::remove_file(&path).unwrap();
        // A live listener blocks a second bind; a stale file does not.
        let l = RpcListener::bind(&Endpoint::Uds(path.clone())).unwrap();
        assert!(RpcListener::bind(&Endpoint::Uds(path.clone())).is_err());
        drop(l); // the socket FILE stays (stale) — next bind reclaims it
        let l2 = RpcListener::bind(&Endpoint::Uds(path.clone())).unwrap();
        l2.cleanup();
        drop(l2);
        assert!(!path.exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
