//! Client-side replay mesh: ONE logical table spread over N replay
//! servers, behind the same [`ExperienceWriter`] / [`ExperienceSampler`]
//! traits the single-server handles implement — the actor and learner
//! loops cannot tell whether their table lives in-process, behind one
//! socket, or across a mesh of hosts.
//!
//! The routing mirrors [`crate::replay::ShardedPrioritizedReplay`]
//! exactly, with servers in place of shards:
//!
//! * **Insert routing** — actor affinity: actor `a` writes server
//!   `a % N` ([`MeshWriter`]), the cross-host image of
//!   `insert_from`'s `actor_id % S` shard routing. One actor keeps one
//!   connection; concurrent actors fan out over disjoint servers.
//! * **Two-level sampling** — [`MeshSampler`] polls every server's
//!   item count and total priority mass (the lightweight `Mass` RPC),
//!   picks one server per batch proportional to its advertised mass
//!   (skipping zero-mass servers while tracking the last positive one,
//!   like the in-process level-1 scan), then samples the whole batch
//!   within that server: P(server) · P(item | server) keeps the draw
//!   proportional to priority across the mesh. Importance weights are
//!   computed server-locally (each server normalizes by its own total
//!   and length) — a documented v1 approximation that matches the
//!   sharded buffer up to the cross-shard weight normalization.
//! * **Priority feedback** — sampled indices are *global*
//!   (`local + server · stride`); [`MeshSampler::update_priorities`]
//!   groups them by server and ships one update RPC per server
//!   touched, best-effort: one failed server does not void the other
//!   servers' feedback.
//!
//! Global index `g` maps to server `g / stride`, local slot
//! `g % stride`, where `stride` is the per-server table capacity —
//! validated uniform across the mesh at connect time.
//!
//! # Health, degraded mode, and failover
//!
//! Both handles drive a shared-nothing [`Membership`] ladder
//! (`Up → Suspect → Down → Rejoining`) from their own RPC outcomes —
//! there is no gossip and no background prober:
//!
//! * The sampler's per-draw RPCs use one non-blocking redial-and-retry
//!   instead of the blocking backoff loop, so a dead server costs a
//!   draw one timeout, never a stalled learner. A server that keeps
//!   failing goes Down: its advertised mass reads as zero, the
//!   survivors renormalize (degraded mode), and it is re-probed on the
//!   membership's seeded-jitter schedule — one cheap probe per
//!   interval, not one timeout per batch. One probe success rejoins it
//!   into the draw.
//! * The writer fails over: when its server's outage has saturated the
//!   spill queue (or a blocking `flush` exhausts its reconnect
//!   deadline), every unacked step and the unreported drop count move
//!   to the next dialable server in affinity order
//!   ([`RemoteWriter::take_unacked`] → `adopt_pending`). Cross-server
//!   failover is at-least-once — the in-flight chunk's ack never
//!   arrived, so it re-ships and may duplicate items the dying server
//!   already absorbed — while spill drops still land in exactly one
//!   server's accounting. A displaced writer periodically probes its
//!   home server and fails back once its queue is idle (no unacked
//!   chunk → no duplicate risk on the way back).
//!
//! Level-1 mass adverts can be cached ([`MeshSampler::with_mass_ttl`])
//! to amortize the per-draw probe fan-out; the default TTL is zero
//! (probe every draw), which the lockstep determinism tests rely on.
//!
//! Checkpoint/restore fan out per server ([`MeshSampler::checkpoint_states`]
//! / [`MeshSampler::restore_states`]): each server's state is its own
//! artifact, moved over the chunked transfer stream, so a mesh save is
//! N bounded streams instead of one giant frame.

use super::client::{is_transport_error, ConnectionPolicy, RemoteClient, RemoteWriter};
use super::membership::{HealthPolicy, HealthState, Membership};
use super::transport::Endpoint;
use crate::replay::SampleBatch;
use crate::service::{
    ExperienceSampler, ExperienceWriter, SampleOutcome, ServiceState, WriterStep,
};
use crate::util::rng::{Rng, SplitMix64};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::time::{Duration, Instant};

/// Mass-cache draw budget: even within the TTL, a cached advert is
/// dropped after this many draws so a hot learner cannot sample against
/// arbitrarily stale masses.
pub const MASS_TTL_DRAWS: u32 = 64;

/// How many delegated writer ops between route probes (failover
/// retries while every candidate is down, fail-back attempts while
/// displaced) — bounds the dial rate an outage can induce.
const ROUTE_PROBE_EVERY: u64 = 64;

/// Parse a comma-separated endpoint list (`uds://PATH`, `tcp://HOST:PORT`,
/// or a bare socket path), rejecting empty entries and duplicates — a
/// duplicated endpoint would silently double-dial one server and skew
/// both affinity routing and the mass-proportional draw.
pub fn parse_endpoint_list(s: &str) -> Result<Vec<Endpoint>> {
    let mut endpoints: Vec<Endpoint> = Vec::new();
    for (i, part) in s.split(',').enumerate() {
        let part = part.trim();
        ensure!(!part.is_empty(), "endpoint list entry {i} is empty (in `{s}`)");
        let ep = Endpoint::parse(part).with_context(|| format!("endpoint list entry {i}"))?;
        if let Some(prev) = endpoints.iter().position(|e| *e == ep) {
            bail!("endpoint `{ep}` appears twice in the list (entries {prev} and {i})");
        }
        endpoints.push(ep);
    }
    Ok(endpoints)
}

/// The sampling seed one mesh client hands server `server` in its
/// `Hello`: derived from the mesh seed so each server draws an
/// independent stream, and exposed so an in-process twin (tests, the
/// smoke drill) can mirror every server's RNG exactly.
pub fn server_seed(seed: u64, server: usize) -> u64 {
    SplitMix64::new(seed ^ (server as u64).wrapping_mul(0xA076_1D64_78BD_642F)).next_u64()
}

/// Run one RPC with a single supervised reconnect-and-retry on a
/// transport failure (the mesh RPCs here are unsequenced and
/// idempotent-enough: a retried `Stats` re-reads, a retried checkpoint
/// restreams). Used by the admin paths, where blocking under the
/// backoff schedule is acceptable; the sampling hot path uses a
/// non-blocking single redial instead.
fn call_retry<T>(
    client: &mut RemoteClient,
    mut f: impl FnMut(&mut RemoteClient) -> Result<T>,
) -> Result<T> {
    match f(client) {
        Err(e) if is_transport_error(&e) => {
            client.reconnect()?;
            f(client)
        }
        other => other,
    }
}

/// Actor-side mesh handle: one [`RemoteWriter`] dialed to the server
/// this actor's id routes to (`actor_id % N`), with failover — when
/// that server stays unreachable, the unacked queue moves to the next
/// dialable server in affinity order, and fails back home once it
/// recovers. Everything else — batching, spill, supervision,
/// exactly-once appends within one server — is the wrapped writer's,
/// untouched.
pub struct MeshWriter {
    inner: RemoteWriter,
    endpoints: Vec<Endpoint>,
    policy: ConnectionPolicy,
    actor_id: u64,
    /// Builder settings replayed onto every replacement writer.
    batch: Option<usize>,
    spill_cap: Option<usize>,
    /// The affinity route (`actor_id % N`) …
    home: usize,
    /// … and the server the writer currently feeds.
    current: usize,
    failovers: u64,
    /// Delegated ops since connect; schedules route probes.
    ops: u64,
    next_probe_ops: u64,
    /// Counter snapshots of connections already torn down, so the
    /// mesh-level totals survive a failover.
    base_emitted: u64,
    base_dropped: u64,
    base_reconnects: u64,
}

impl MeshWriter {
    /// Dial the server `actor_id` routes to; if it refuses, start on
    /// the next dialable server in affinity order (the same failover
    /// path a live writer takes, minus the carried queue).
    pub fn connect(
        endpoints: &[Endpoint],
        actor_id: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        ensure!(!endpoints.is_empty(), "mesh writer needs at least one endpoint");
        let n = endpoints.len();
        let home = (actor_id % n as u64) as usize;
        let mut last: Option<anyhow::Error> = None;
        for k in 0..n {
            let server = (home + k) % n;
            match RemoteWriter::connect_endpoint_with(&endpoints[server], actor_id, policy.clone())
            {
                Ok(inner) => {
                    if server != home {
                        eprintln!(
                            "[pal] mesh writer for actor {actor_id}: home server {home} \
                             unreachable, starting on server {server}"
                        );
                    }
                    return Ok(Self {
                        inner,
                        endpoints: endpoints.to_vec(),
                        policy,
                        actor_id,
                        batch: None,
                        spill_cap: None,
                        home,
                        current: server,
                        failovers: u64::from(server != home),
                        ops: 0,
                        next_probe_ops: 0,
                        base_emitted: 0,
                        base_dropped: 0,
                        base_reconnects: 0,
                    });
                }
                Err(e) => {
                    last = Some(e.context(format!(
                        "mesh writer for actor {actor_id} dialing server {server}"
                    )));
                }
            }
        }
        Err(last.expect("at least one endpoint was tried"))
    }

    /// Which server (index into the endpoint list) this writer
    /// currently feeds — its home route unless failed over.
    pub fn server(&self) -> usize {
        self.current
    }

    /// The affinity route `actor_id % N` this writer fails back to.
    pub fn home_server(&self) -> usize {
        self.home
    }

    /// Route changes so far (failovers plus fail-backs).
    pub fn failovers(&self) -> u64 {
        self.failovers
    }

    /// See [`RemoteWriter::with_batch`].
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = Some(batch);
        self.inner = self.inner.with_batch(batch);
        self
    }

    /// See [`RemoteWriter::with_spill_cap`].
    pub fn with_spill_cap(mut self, cap: usize) -> Self {
        self.spill_cap = Some(cap);
        self.inner = self.inner.with_spill_cap(cap);
        self
    }

    pub fn items_emitted(&self) -> u64 {
        self.base_emitted + self.inner.items_emitted()
    }

    pub fn pending_len(&self) -> usize {
        self.inner.pending_len()
    }

    pub fn steps_dropped(&self) -> u64 {
        self.base_dropped + self.inner.steps_dropped()
    }

    pub fn reconnects(&self) -> u64 {
        self.base_reconnects + self.inner.reconnects()
    }

    /// Dial one server with this writer's settings replayed.
    fn dial(&self, server: usize) -> Result<RemoteWriter> {
        let mut w = RemoteWriter::connect_endpoint_with(
            &self.endpoints[server],
            self.actor_id,
            self.policy.clone(),
        )?;
        if let Some(b) = self.batch {
            w = w.with_batch(b);
        }
        if let Some(c) = self.spill_cap {
            w = w.with_spill_cap(c);
        }
        Ok(w)
    }

    /// Swap `next` in for the current writer, carrying every unacked
    /// step and the unreported drop count across (and rolling the dying
    /// connection's counters into the bases, so the mesh-level totals
    /// survive the swap).
    fn migrate_to(&mut self, mut next: RemoteWriter, server: usize) -> usize {
        self.base_emitted += self.inner.items_emitted();
        self.base_dropped += self.inner.steps_dropped();
        self.base_reconnects += self.inner.reconnects();
        let (pending, dropped) = self.inner.take_unacked();
        let moved = pending.len();
        next.adopt_pending(pending, dropped);
        self.inner = next;
        self.current = server;
        self.failovers += 1;
        moved
    }

    /// Move the unacked queue to the next dialable server in affinity
    /// order. At-least-once across the switch: the in-flight chunk's
    /// ack never arrived, so it re-ships to the new server and may
    /// duplicate items the dying server already absorbed — the
    /// documented failover trade, versus losing the chunk. If no
    /// candidate answers, the current writer is left untouched (still
    /// spilling) and the original cause is returned.
    fn fail_over(&mut self, cause: anyhow::Error) -> Result<()> {
        let n = self.endpoints.len();
        if n < 2 {
            return Err(cause);
        }
        let mut last = cause;
        for k in 1..n {
            let cand = (self.current + k) % n;
            match self.dial(cand) {
                Ok(next) => {
                    let from = self.current;
                    let moved = self.migrate_to(next, cand);
                    eprintln!(
                        "[pal] mesh writer for actor {}: failed over from server {from} to \
                         {cand} carrying {moved} unacked step(s)",
                        self.actor_id
                    );
                    return Ok(());
                }
                Err(e) => last = e.context(format!("failover dial to mesh server {cand}")),
            }
        }
        Err(last)
    }

    /// One cheap dial home; on success the displaced writer migrates
    /// back to its affinity server. Only called with an idle queue —
    /// no unacked chunk means no duplicate risk on the way back.
    fn try_fail_back(&mut self) {
        if let Ok(next) = self.dial(self.home) {
            let from = self.current;
            self.migrate_to(next, self.home);
            eprintln!(
                "[pal] mesh writer for actor {}: home server {} is back, failing back from \
                 server {from}",
                self.actor_id, self.home
            );
        }
    }

    /// Opportunistic route maintenance after a delegated op: fail over
    /// when the current server's outage has saturated the spill queue
    /// (waiting longer only drops more steps), fail back home once the
    /// displaced writer's queue is idle. Probes are paced by op count
    /// so an all-dead mesh induces a bounded dial rate, and a failed
    /// probe is swallowed — the inner writer keeps spilling, exactly
    /// as it would with no mesh at all.
    fn tend_route(&mut self) {
        if self.endpoints.len() < 2 {
            return;
        }
        self.ops += 1;
        if self.ops < self.next_probe_ops {
            return;
        }
        if self.inner.in_saturated_outage() {
            self.next_probe_ops = self.ops + ROUTE_PROBE_EVERY;
            if let Err(e) =
                self.fail_over(anyhow!("spill queue saturated while disconnected"))
            {
                eprintln!(
                    "[pal] mesh writer for actor {}: failover found no live server ({e:#}); \
                     continuing to spill",
                    self.actor_id
                );
            }
        } else if self.current != self.home && self.inner.pending_len() == 0 {
            self.next_probe_ops = self.ops + ROUTE_PROBE_EVERY;
            self.try_fail_back();
        }
    }
}

impl ExperienceWriter for MeshWriter {
    fn throttled(&mut self) -> Result<bool> {
        let throttled = self.inner.throttled()?;
        self.tend_route();
        Ok(throttled)
    }

    fn append(&mut self, step: WriterStep) -> Result<usize> {
        let emitted = self.inner.append(step)?;
        self.tend_route();
        Ok(emitted)
    }

    /// A blocking flush that exhausts its reconnect deadline is the
    /// hard failover trigger: the barrier must deliver somewhere, so
    /// the queue moves to the next live server and flushes there.
    fn flush(&mut self) -> Result<usize> {
        match self.inner.flush() {
            Err(e) if is_transport_error(&e) && self.endpoints.len() > 1 => {
                self.fail_over(e)?;
                self.inner.flush()
            }
            other => other,
        }
    }
}

/// Point-in-time RPC and health counters of a [`MeshSampler`] — the
/// observability surface the benches and the chaos drills read.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MeshSamplerCounters {
    /// `Mass` probes actually sent (mass-cache hits send none).
    pub mass_rpcs: u64,
    /// Whole-batch `Sample` RPCs sent (retries included).
    pub sample_rpcs: u64,
    /// Draws taken while at least one server was Down (renormalized
    /// over the survivors).
    pub degraded_draws: u64,
    /// Up/Suspect → Down transitions observed.
    pub downs: u64,
    /// Down/Rejoining → Up recoveries observed.
    pub rejoins: u64,
}

/// Learner-side mesh handle: one connection per server, two-level
/// sampling across them (see the module docs). Sampled indices are
/// global (`local + server · stride`), so priority feedback needs no
/// API change — [`Self::update_priorities`] routes each index back to
/// the server it came from.
pub struct MeshSampler {
    clients: Vec<RemoteClient>,
    table: String,
    /// Per-server table capacity (uniform across the mesh): the
    /// local↔global index stride.
    stride: usize,
    /// Client-side level-1 RNG (the server pick); within-server draws
    /// use each server's session RNG, seeded via [`server_seed`].
    rng: Rng,
    /// Reused per-sample scratch: each server's advertised (len, mass).
    masses: Vec<(u64, f32)>,
    /// Reused update-routing buckets, one per server.
    buckets: Vec<(Vec<usize>, Vec<f32>)>,
    /// Per-server health ladder, driven by this sampler's RPC outcomes.
    membership: Membership,
    /// How long a refreshed `masses` scratch stays valid (zero = probe
    /// every draw).
    mass_ttl: Duration,
    /// When `masses` was last refreshed (`None` = invalidated).
    last_refresh: Option<Instant>,
    /// Draws taken against the current refresh (see [`MASS_TTL_DRAWS`]).
    draws_since_refresh: u32,
    mass_rpcs: u64,
    sample_rpcs: u64,
    degraded_draws: u64,
}

impl MeshSampler {
    /// Connect to every server in the mesh and bind a named table on
    /// each; validates the table exists everywhere with one uniform
    /// capacity (the index stride).
    pub fn connect(
        endpoints: &[Endpoint],
        table: impl Into<String>,
        rng_seed: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        Self::connect_inner(endpoints, Some(table.into()), rng_seed, policy)
    }

    /// Connect binding every server's default (first) table — they must
    /// all agree on its name.
    pub fn connect_default(
        endpoints: &[Endpoint],
        rng_seed: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        Self::connect_inner(endpoints, None, rng_seed, policy)
    }

    fn connect_inner(
        endpoints: &[Endpoint],
        table: Option<String>,
        rng_seed: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        ensure!(!endpoints.is_empty(), "mesh sampler needs at least one endpoint");
        let explicit = table.is_some();
        let mut clients = Vec::with_capacity(endpoints.len());
        let mut table = table;
        for (s, ep) in endpoints.iter().enumerate() {
            let mut client = RemoteClient::connect_endpoint_with(ep, policy.clone())
                .with_context(|| format!("mesh sampler dialing server {s}"))?;
            let default_table = client
                .hello(server_seed(rng_seed, s))
                .with_context(|| format!("mesh sampler hello to server {s} ({ep})"))?;
            if !explicit {
                match &table {
                    None => {
                        ensure!(
                            !default_table.is_empty(),
                            "mesh server {s} ({ep}) reports no default table"
                        );
                        table = Some(default_table);
                    }
                    Some(t) => ensure!(
                        *t == default_table,
                        "mesh servers disagree on the default table: server 0 serves \
                         `{t}`, server {s} ({ep}) serves `{default_table}`"
                    ),
                }
            }
            clients.push(client);
        }
        let table = table.expect("table resolved by the first server");
        // Validate the table everywhere and derive the uniform stride.
        let mut stride = None;
        for (s, client) in clients.iter_mut().enumerate() {
            let tables = client
                .stats()
                .with_context(|| format!("mesh sampler reading server {s} stats"))?;
            let info = tables.iter().find(|t| t.name == table).with_context(|| {
                format!("mesh server {s} ({}) does not serve table `{table}`", endpoints[s])
            })?;
            let cap = info.capacity as usize;
            ensure!(cap > 0, "mesh server {s} reports zero capacity for table `{table}`");
            match stride {
                None => stride = Some(cap),
                Some(prev) => ensure!(
                    prev == cap,
                    "mesh servers disagree on table `{table}` capacity: server 0 has {prev}, \
                     server {s} has {cap} — the mesh needs a uniform per-server capacity to \
                     map local indices to global ones"
                ),
            }
        }
        let n = clients.len();
        Ok(Self {
            clients,
            table,
            stride: stride.expect("at least one server"),
            rng: Rng::new(rng_seed),
            masses: Vec::with_capacity(n),
            buckets: (0..n).map(|_| (Vec::new(), Vec::new())).collect(),
            membership: Membership::new(n, HealthPolicy::default()),
            mass_ttl: Duration::ZERO,
            last_refresh: None,
            draws_since_refresh: 0,
            mass_rpcs: 0,
            sample_rpcs: 0,
            degraded_draws: 0,
        })
    }

    /// Cache the level-1 mass adverts for `ttl` (and at most
    /// [`MASS_TTL_DRAWS`] draws), trading per-draw probe fan-out for a
    /// slightly stale server pick. `Duration::ZERO` (the default)
    /// disables the cache: every draw re-polls, which the lockstep
    /// determinism tests rely on. Any failover or data-starved outcome
    /// invalidates the cache immediately.
    pub fn with_mass_ttl(mut self, ttl: Duration) -> Self {
        self.mass_ttl = ttl;
        self
    }

    /// Replace the health thresholds/probe pacing (connect-time
    /// builder: resets every server to Up).
    pub fn with_health_policy(mut self, policy: HealthPolicy) -> Self {
        self.membership = Membership::new(self.clients.len(), policy);
        self
    }

    pub fn table(&self) -> &str {
        &self.table
    }

    /// Number of servers in the mesh.
    pub fn server_count(&self) -> usize {
        self.clients.len()
    }

    /// The local↔global index stride (per-server table capacity).
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Total successful redials across all server connections.
    pub fn reconnects(&self) -> u64 {
        self.clients.iter().map(RemoteClient::reconnects).sum()
    }

    /// One server's position on the health ladder.
    pub fn health(&self, server: usize) -> HealthState {
        self.membership.state(server)
    }

    /// The mesh's health bookkeeping (read-only).
    pub fn membership(&self) -> &Membership {
        &self.membership
    }

    /// RPC and health counters (see [`MeshSamplerCounters`]).
    pub fn counters(&self) -> MeshSamplerCounters {
        MeshSamplerCounters {
            mass_rpcs: self.mass_rpcs,
            sample_rpcs: self.sample_rpcs,
            degraded_draws: self.degraded_draws,
            downs: self.membership.downs(),
            rejoins: self.membership.rejoins(),
        }
    }

    /// Direct access to one server's connection (tests, admin tooling).
    pub fn client_mut(&mut self, server: usize) -> &mut RemoteClient {
        &mut self.clients[server]
    }

    /// Every server's per-table stats, mesh order.
    pub fn stats(&mut self) -> Result<Vec<Vec<super::proto::TableInfo>>> {
        self.clients
            .iter_mut()
            .enumerate()
            .map(|(s, c)| {
                call_retry(c, RemoteClient::stats)
                    .with_context(|| format!("mesh stats from server {s}"))
            })
            .collect()
    }

    /// Fan-out checkpoint: every server's full state (chunk-streamed),
    /// mesh order. Each entry restores to the *same* server slot.
    pub fn checkpoint_states(&mut self) -> Result<Vec<ServiceState>> {
        self.clients
            .iter_mut()
            .enumerate()
            .map(|(s, c)| {
                call_retry(c, RemoteClient::checkpoint_state)
                    .with_context(|| format!("mesh checkpoint from server {s}"))
            })
            .collect()
    }

    /// Fan-out restore: one previously captured state per server, mesh
    /// order (the inverse of [`Self::checkpoint_states`]).
    pub fn restore_states(&mut self, states: &[ServiceState]) -> Result<()> {
        ensure!(
            states.len() == self.clients.len(),
            "mesh restore got {} state(s) for {} server(s)",
            states.len(),
            self.clients.len()
        );
        for (s, (client, state)) in self.clients.iter_mut().zip(states).enumerate() {
            call_retry(client, |c| c.restore_state(state))
                .with_context(|| format!("mesh restore into server {s}"))?;
        }
        Ok(())
    }

    /// Is the cached `masses` scratch still usable at `now`?
    fn masses_fresh(&self, now: Instant) -> bool {
        self.masses.len() == self.clients.len()
            && self.draws_since_refresh < MASS_TTL_DRAWS
            && self
                .last_refresh
                .is_some_and(|at| now.duration_since(at) < self.mass_ttl)
    }

    /// Drop the cached mass adverts: the next draw re-polls.
    fn invalidate_masses(&mut self) {
        self.last_refresh = None;
    }

    /// Level 1 of the two-level draw: refresh every server's advertised
    /// (len, mass) into the reused scratch, best-effort. An unreachable
    /// server contributes zero mass (recorded against its health) and a
    /// Down server is skipped entirely until its seeded probe comes
    /// due; only non-transport errors (a server-side refusal) abort.
    fn refresh_masses(&mut self, now: Instant) -> Result<()> {
        self.masses.clear();
        let table = std::mem::take(&mut self.table);
        let mut fatal: Option<anyhow::Error> = None;
        for s in 0..self.clients.len() {
            let was_down = self.membership.state(s) == HealthState::Down;
            if was_down {
                if !self.membership.probe_due(s, now) {
                    self.masses.push((0, 0.0));
                    continue;
                }
                // Probe due: one cheap redial decides rejoin vs re-arm.
                self.membership.begin_rejoin(s, now);
                if self.clients[s].try_redial().is_err() {
                    self.membership.probe_failed(s);
                    self.masses.push((0, 0.0));
                    continue;
                }
            }
            self.mass_rpcs += 1;
            let mut res = self.clients[s].mass(&table);
            if !was_down {
                // One non-blocking redial-and-retry — never the
                // blocking backoff loop, so a dead server cannot
                // stall the whole level-1 scan.
                let transport = matches!(&res, Err(e) if is_transport_error(e));
                if transport && self.clients[s].try_redial().is_ok() {
                    self.mass_rpcs += 1;
                    res = self.clients[s].mass(&table);
                }
            }
            match res {
                Ok(lm) => {
                    self.membership.record_success(s);
                    self.masses.push(lm);
                }
                Err(e) if is_transport_error(&e) => {
                    if was_down {
                        self.membership.probe_failed(s);
                    } else {
                        self.membership.record_failure(s, now);
                    }
                    self.masses.push((0, 0.0));
                }
                Err(e) => {
                    fatal = Some(e.context(format!("mesh mass probe to server {s}")));
                    break;
                }
            }
        }
        self.table = table;
        if let Some(e) = fatal {
            return Err(e);
        }
        self.last_refresh = Some(now);
        self.draws_since_refresh = 0;
        Ok(())
    }

    /// Pick the server whose mass interval contains `x`, skipping
    /// zero-mass servers while tracking the last positive one — the
    /// mesh image of the sharded buffer's level-1 prefix scan. The
    /// accumulator runs in f64 (as does the draw), so a wide mesh of
    /// f32 adverts cannot lose low-mass servers to rounding.
    fn pick_server(&self, x: f64) -> Option<usize> {
        let mut sel = None;
        let mut acc = 0.0f64;
        for (k, &(_, m)) in self.masses.iter().enumerate() {
            let m = f64::from(m);
            if m > 0.0 {
                sel = Some(k);
                if acc + m >= x {
                    break;
                }
            }
            acc += m;
        }
        sel
    }

    /// One whole-batch `Sample` against server `sel`, with a single
    /// non-blocking redial-and-retry on a transport failure.
    fn sample_from(
        &mut self,
        sel: usize,
        batch: usize,
        out: &mut SampleBatch,
    ) -> Result<SampleOutcome> {
        let table = std::mem::take(&mut self.table);
        self.sample_rpcs += 1;
        let mut res = self.clients[sel].sample(&table, batch, out);
        let transport = matches!(&res, Err(e) if is_transport_error(e));
        if transport && self.clients[sel].try_redial().is_ok() {
            self.sample_rpcs += 1;
            res = self.clients[sel].sample(&table, batch, out);
        }
        self.table = table;
        res
    }
}

impl ExperienceSampler for MeshSampler {
    /// Two-level mesh sampling: a (possibly cached) `Mass` scan, one
    /// mass-proportional server pick in f64, one whole-batch `Sample`
    /// within the picked server, indices remapped local → global. A
    /// picked server that fails at the transport is recorded against
    /// its health, zeroed out of the scan, and the draw repicks from
    /// the renormalized survivors — a dead server degrades the mesh
    /// instead of stalling the learner.
    fn try_sample(
        &mut self,
        batch: usize,
        _rng: &mut Rng,
        out: &mut SampleBatch,
    ) -> Result<SampleOutcome> {
        let now = Instant::now();
        if !self.masses_fresh(now) {
            self.refresh_masses(now)?;
        }
        for attempt in 0..=self.clients.len() {
            let len: u64 = self.masses.iter().map(|&(l, _)| l).sum();
            let total: f64 = self.masses.iter().map(|&(_, m)| f64::from(m)).sum();
            if len == 0 || total <= 0.0 || total.is_nan() {
                self.invalidate_masses();
                return Ok(SampleOutcome::NotEnoughData);
            }
            if attempt == 0 && self.membership.live_count() < self.server_count() {
                self.degraded_draws += 1;
            }
            let x = self.rng.f64() * total;
            let Some(sel) = self.pick_server(x) else {
                self.invalidate_masses();
                return Ok(SampleOutcome::NotEnoughData);
            };
            match self.sample_from(sel, batch, out) {
                Ok(outcome) => {
                    self.membership.record_success(sel);
                    self.draws_since_refresh += 1;
                    if outcome == SampleOutcome::Sampled {
                        let base = sel * self.stride;
                        for idx in &mut out.indices {
                            *idx += base;
                        }
                    } else {
                        // The advert was stale (throttle, drain, or a
                        // raced eviction): drop the cache so the next
                        // call re-polls instead of re-picking the same
                        // server from stale masses.
                        self.invalidate_masses();
                    }
                    return Ok(outcome);
                }
                Err(e) if is_transport_error(&e) => {
                    self.membership.record_failure(sel, now);
                    self.masses[sel] = (0, 0.0);
                    self.invalidate_masses();
                    eprintln!(
                        "[pal] mesh sample from server {sel} failed at the transport; \
                         renormalizing this draw over the survivors"
                    );
                }
                Err(e) => return Err(e.context(format!("mesh sample from server {sel}"))),
            }
        }
        // Every positive-mass server failed this draw; surface the
        // retriable outcome (their health is already marked).
        Ok(SampleOutcome::NotEnoughData)
    }

    /// Route each global index back to its server and ship one update
    /// RPC per server touched — best-effort: every live server gets its
    /// bucket even when another fails, and the aggregate error names
    /// the servers whose feedback was lost.
    fn update_priorities(&mut self, indices: &[usize], td_abs: &[f32]) -> Result<()> {
        ensure!(
            indices.len() == td_abs.len(),
            "priority update has {} indices but {} values",
            indices.len(),
            td_abs.len()
        );
        for (idx_bucket, td_bucket) in &mut self.buckets {
            idx_bucket.clear();
            td_bucket.clear();
        }
        for (&idx, &td) in indices.iter().zip(td_abs) {
            let s = idx / self.stride;
            ensure!(
                s < self.clients.len(),
                "priority index {idx} outside the mesh (stride {}, {} servers)",
                self.stride,
                self.clients.len()
            );
            self.buckets[s].0.push(idx - s * self.stride);
            self.buckets[s].1.push(td);
        }
        let now = Instant::now();
        let table = std::mem::take(&mut self.table);
        let mut failed: Vec<String> = Vec::new();
        for s in 0..self.clients.len() {
            if self.buckets[s].0.is_empty() {
                continue;
            }
            if !self.membership.is_live(s) {
                failed.push(format!("server {s}: down"));
                continue;
            }
            let (idx_bucket, td_bucket) = (&self.buckets[s].0, &self.buckets[s].1);
            let mut res = self.clients[s].update_priorities(&table, idx_bucket, td_bucket);
            let transport = matches!(&res, Err(e) if is_transport_error(e));
            if transport && self.clients[s].try_redial().is_ok() {
                res = self.clients[s].update_priorities(&table, idx_bucket, td_bucket);
            }
            match res {
                Ok(()) => self.membership.record_success(s),
                Err(e) => {
                    if is_transport_error(&e) {
                        self.membership.record_failure(s, now);
                        self.invalidate_masses();
                    }
                    failed.push(format!("server {s}: {e:#}"));
                }
            }
        }
        self.table = table;
        if !failed.is_empty() {
            bail!(
                "mesh priority update failed on {} of {} server(s), the rest were shipped: {}",
                failed.len(),
                self.clients.len(),
                failed.join("; ")
            );
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_list_parses_mixed_transports() {
        let eps =
            parse_endpoint_list("uds:///tmp/a.sock, tcp://127.0.0.1:7001 ,/tmp/b.sock").unwrap();
        assert_eq!(eps.len(), 3);
        assert_eq!(eps[0], Endpoint::from(std::path::Path::new("/tmp/a.sock")));
        assert_eq!(eps[1], Endpoint::parse("tcp://127.0.0.1:7001").unwrap());
        assert_eq!(eps[2], Endpoint::from(std::path::Path::new("/tmp/b.sock")));
    }

    #[test]
    fn endpoint_list_rejects_duplicates_and_empties() {
        let e = parse_endpoint_list("/tmp/a.sock,/tmp/a.sock").unwrap_err();
        assert!(format!("{e:#}").contains("appears twice"), "{e:#}");
        // A bare path and its uds:// spelling are the same endpoint.
        let e = parse_endpoint_list("/tmp/a.sock,uds:///tmp/a.sock").unwrap_err();
        assert!(format!("{e:#}").contains("appears twice"), "{e:#}");
        let e = parse_endpoint_list("tcp://127.0.0.1:1,,tcp://127.0.0.1:2").unwrap_err();
        assert!(format!("{e:#}").contains("entry 1 is empty"), "{e:#}");
    }

    #[test]
    fn server_seeds_are_distinct_and_stable() {
        let a = server_seed(42, 0);
        let b = server_seed(42, 1);
        let c = server_seed(42, 2);
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert_ne!(a, c);
        // Stable across calls (twins depend on replaying these).
        assert_eq!(a, server_seed(42, 0));
    }

    /// A connection-less sampler for the pure-logic tests.
    fn bare(masses: Vec<(u64, f32)>, mass_ttl: Duration) -> MeshSampler {
        MeshSampler {
            clients: Vec::new(),
            table: "t".into(),
            stride: 8,
            rng: Rng::new(1),
            masses,
            buckets: Vec::new(),
            membership: Membership::new(0, HealthPolicy::default()),
            mass_ttl,
            last_refresh: None,
            draws_since_refresh: 0,
            mass_rpcs: 0,
            sample_rpcs: 0,
            degraded_draws: 0,
        }
    }

    #[test]
    fn pick_server_skips_zero_mass_like_the_sharded_scan() {
        let mesh = bare(vec![(0, 0.0), (4, 2.0), (0, 0.0), (4, 2.0)], Duration::ZERO);
        // x in the first positive interval → server 1; past it → 3.
        assert_eq!(mesh.pick_server(0.0), Some(1));
        assert_eq!(mesh.pick_server(1.9), Some(1));
        assert_eq!(mesh.pick_server(2.5), Some(3));
        // Past the total mass clamps to the last positive server.
        assert_eq!(mesh.pick_server(100.0), Some(3));
    }

    #[test]
    fn pick_server_accumulates_in_f64() {
        // 2^24 of f32 mass followed by a 1.0 server: an f32 prefix
        // accumulator saturates (2^24 + 1 == 2^24 in f32) and could
        // never land in the tail server's interval below the total.
        let mesh = bare(vec![(1, 16_777_216.0), (1, 1.0)], Duration::ZERO);
        assert_eq!(mesh.pick_server(16_777_216.5), Some(1));
        assert_eq!(mesh.pick_server(16_777_216.0), Some(0));
    }

    #[test]
    fn mass_cache_ttl_and_draw_budget() {
        let now = Instant::now();
        let mut mesh = bare(Vec::new(), Duration::from_secs(5));
        assert!(!mesh.masses_fresh(now), "nothing cached before the first refresh");
        mesh.last_refresh = Some(now);
        assert!(mesh.masses_fresh(now + Duration::from_millis(1)));
        assert!(!mesh.masses_fresh(now + Duration::from_secs(6)), "TTL expired");
        mesh.draws_since_refresh = MASS_TTL_DRAWS;
        assert!(
            !mesh.masses_fresh(now + Duration::from_millis(1)),
            "the draw budget caps a hot learner inside the TTL"
        );
        mesh.draws_since_refresh = 0;
        mesh.invalidate_masses();
        assert!(!mesh.masses_fresh(now + Duration::from_millis(1)));

        // Zero TTL (the default) disables the cache entirely.
        let mut zero = bare(Vec::new(), Duration::ZERO);
        zero.last_refresh = Some(now);
        assert!(!zero.masses_fresh(now));
    }
}
