//! Client-side replay mesh: ONE logical table spread over N replay
//! servers, behind the same [`ExperienceWriter`] / [`ExperienceSampler`]
//! traits the single-server handles implement — the actor and learner
//! loops cannot tell whether their table lives in-process, behind one
//! socket, or across a mesh of hosts.
//!
//! The routing mirrors [`crate::replay::ShardedPrioritizedReplay`]
//! exactly, with servers in place of shards:
//!
//! * **Insert routing** — actor affinity: actor `a` writes server
//!   `a % N` ([`MeshWriter`]), the cross-host image of
//!   `insert_from`'s `actor_id % S` shard routing. One actor keeps one
//!   connection; concurrent actors fan out over disjoint servers.
//! * **Two-level sampling** — [`MeshSampler`] polls every server's
//!   item count and total priority mass (the lightweight `Mass` RPC),
//!   picks one server per batch proportional to its advertised mass
//!   (skipping zero-mass servers while tracking the last positive one,
//!   like the in-process level-1 scan), then samples the whole batch
//!   within that server: P(server) · P(item | server) keeps the draw
//!   proportional to priority across the mesh. Importance weights are
//!   computed server-locally (each server normalizes by its own total
//!   and length) — a documented v1 approximation that matches the
//!   sharded buffer up to the cross-shard weight normalization.
//! * **Priority feedback** — sampled indices are *global*
//!   (`local + server · stride`); [`MeshSampler::update_priorities`]
//!   groups them by server and ships one update RPC per server
//!   touched, the wire image of `update_priorities_batched`.
//!
//! Global index `g` maps to server `g / stride`, local slot
//! `g % stride`, where `stride` is the per-server table capacity —
//! validated uniform across the mesh at connect time.
//!
//! Checkpoint/restore fan out per server ([`MeshSampler::checkpoint_states`]
//! / [`MeshSampler::restore_states`]): each server's state is its own
//! artifact, moved over the chunked transfer stream, so a mesh save is
//! N bounded streams instead of one giant frame.

use super::client::{is_transport_error, ConnectionPolicy, RemoteClient, RemoteWriter};
use super::transport::Endpoint;
use crate::replay::SampleBatch;
use crate::service::{
    ExperienceSampler, ExperienceWriter, SampleOutcome, ServiceState, WriterStep,
};
use crate::util::rng::{Rng, SplitMix64};
use anyhow::{bail, ensure, Context, Result};

/// Parse a comma-separated endpoint list (`uds://PATH`, `tcp://HOST:PORT`,
/// or a bare socket path), rejecting empty entries and duplicates — a
/// duplicated endpoint would silently double-dial one server and skew
/// both affinity routing and the mass-proportional draw.
pub fn parse_endpoint_list(s: &str) -> Result<Vec<Endpoint>> {
    let mut endpoints: Vec<Endpoint> = Vec::new();
    for (i, part) in s.split(',').enumerate() {
        let part = part.trim();
        ensure!(!part.is_empty(), "endpoint list entry {i} is empty (in `{s}`)");
        let ep = Endpoint::parse(part).with_context(|| format!("endpoint list entry {i}"))?;
        if let Some(prev) = endpoints.iter().position(|e| *e == ep) {
            bail!("endpoint `{ep}` appears twice in the list (entries {prev} and {i})");
        }
        endpoints.push(ep);
    }
    Ok(endpoints)
}

/// The sampling seed one mesh client hands server `server` in its
/// `Hello`: derived from the mesh seed so each server draws an
/// independent stream, and exposed so an in-process twin (tests, the
/// smoke drill) can mirror every server's RNG exactly.
pub fn server_seed(seed: u64, server: usize) -> u64 {
    SplitMix64::new(seed ^ (server as u64).wrapping_mul(0xA076_1D64_78BD_642F)).next_u64()
}

/// Run one RPC with a single supervised reconnect-and-retry on a
/// transport failure (the mesh RPCs here are unsequenced and
/// idempotent-enough: a retried `Mass`/`Stats` re-reads, a retried
/// `Sample` re-draws, a retried update re-applies the same priorities).
fn call_retry<T>(
    client: &mut RemoteClient,
    mut f: impl FnMut(&mut RemoteClient) -> Result<T>,
) -> Result<T> {
    match f(client) {
        Err(e) if is_transport_error(&e) => {
            client.reconnect()?;
            f(client)
        }
        other => other,
    }
}

/// Actor-side mesh handle: one [`RemoteWriter`] dialed to the server
/// this actor's id routes to (`actor_id % N`). Everything else —
/// batching, spill, supervision, exactly-once appends — is the wrapped
/// writer's, untouched.
pub struct MeshWriter {
    inner: RemoteWriter,
    server: usize,
}

impl MeshWriter {
    /// Dial the server `actor_id` routes to.
    pub fn connect(
        endpoints: &[Endpoint],
        actor_id: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        ensure!(!endpoints.is_empty(), "mesh writer needs at least one endpoint");
        let server = (actor_id % endpoints.len() as u64) as usize;
        let inner = RemoteWriter::connect_endpoint_with(&endpoints[server], actor_id, policy)
            .with_context(|| {
                format!("mesh writer for actor {actor_id} dialing server {server}")
            })?;
        Ok(Self { inner, server })
    }

    /// Which server (index into the endpoint list) this writer feeds.
    pub fn server(&self) -> usize {
        self.server
    }

    /// See [`RemoteWriter::with_batch`].
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.inner = self.inner.with_batch(batch);
        self
    }

    /// See [`RemoteWriter::with_spill_cap`].
    pub fn with_spill_cap(mut self, cap: usize) -> Self {
        self.inner = self.inner.with_spill_cap(cap);
        self
    }

    pub fn items_emitted(&self) -> u64 {
        self.inner.items_emitted()
    }

    pub fn pending_len(&self) -> usize {
        self.inner.pending_len()
    }

    pub fn steps_dropped(&self) -> u64 {
        self.inner.steps_dropped()
    }

    pub fn reconnects(&self) -> u64 {
        self.inner.reconnects()
    }
}

impl ExperienceWriter for MeshWriter {
    fn throttled(&mut self) -> Result<bool> {
        self.inner.throttled()
    }

    fn append(&mut self, step: WriterStep) -> Result<usize> {
        self.inner.append(step)
    }

    fn flush(&mut self) -> Result<usize> {
        self.inner.flush()
    }
}

/// Learner-side mesh handle: one connection per server, two-level
/// sampling across them (see the module docs). Sampled indices are
/// global (`local + server · stride`), so priority feedback needs no
/// API change — [`Self::update_priorities`] routes each index back to
/// the server it came from.
pub struct MeshSampler {
    clients: Vec<RemoteClient>,
    table: String,
    /// Per-server table capacity (uniform across the mesh): the
    /// local↔global index stride.
    stride: usize,
    /// Client-side level-1 RNG (the server pick); within-server draws
    /// use each server's session RNG, seeded via [`server_seed`].
    rng: Rng,
    /// Reused per-sample scratch: each server's advertised (len, mass).
    masses: Vec<(u64, f32)>,
    /// Reused update-routing buckets, one per server.
    buckets: Vec<(Vec<usize>, Vec<f32>)>,
}

impl MeshSampler {
    /// Connect to every server in the mesh and bind a named table on
    /// each; validates the table exists everywhere with one uniform
    /// capacity (the index stride).
    pub fn connect(
        endpoints: &[Endpoint],
        table: impl Into<String>,
        rng_seed: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        Self::connect_inner(endpoints, Some(table.into()), rng_seed, policy)
    }

    /// Connect binding every server's default (first) table — they must
    /// all agree on its name.
    pub fn connect_default(
        endpoints: &[Endpoint],
        rng_seed: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        Self::connect_inner(endpoints, None, rng_seed, policy)
    }

    fn connect_inner(
        endpoints: &[Endpoint],
        table: Option<String>,
        rng_seed: u64,
        policy: ConnectionPolicy,
    ) -> Result<Self> {
        ensure!(!endpoints.is_empty(), "mesh sampler needs at least one endpoint");
        let explicit = table.is_some();
        let mut clients = Vec::with_capacity(endpoints.len());
        let mut table = table;
        for (s, ep) in endpoints.iter().enumerate() {
            let mut client = RemoteClient::connect_endpoint_with(ep, policy.clone())
                .with_context(|| format!("mesh sampler dialing server {s}"))?;
            let default_table = client
                .hello(server_seed(rng_seed, s))
                .with_context(|| format!("mesh sampler hello to server {s} ({ep})"))?;
            if !explicit {
                match &table {
                    None => {
                        ensure!(
                            !default_table.is_empty(),
                            "mesh server {s} ({ep}) reports no default table"
                        );
                        table = Some(default_table);
                    }
                    Some(t) => ensure!(
                        *t == default_table,
                        "mesh servers disagree on the default table: server 0 serves \
                         `{t}`, server {s} ({ep}) serves `{default_table}`"
                    ),
                }
            }
            clients.push(client);
        }
        let table = table.expect("table resolved by the first server");
        // Validate the table everywhere and derive the uniform stride.
        let mut stride = None;
        for (s, client) in clients.iter_mut().enumerate() {
            let tables = client
                .stats()
                .with_context(|| format!("mesh sampler reading server {s} stats"))?;
            let info = tables.iter().find(|t| t.name == table).with_context(|| {
                format!("mesh server {s} ({}) does not serve table `{table}`", endpoints[s])
            })?;
            let cap = info.capacity as usize;
            ensure!(cap > 0, "mesh server {s} reports zero capacity for table `{table}`");
            match stride {
                None => stride = Some(cap),
                Some(prev) => ensure!(
                    prev == cap,
                    "mesh servers disagree on table `{table}` capacity: server 0 has {prev}, \
                     server {s} has {cap} — the mesh needs a uniform per-server capacity to \
                     map local indices to global ones"
                ),
            }
        }
        let n = clients.len();
        Ok(Self {
            clients,
            table,
            stride: stride.expect("at least one server"),
            rng: Rng::new(rng_seed),
            masses: Vec::with_capacity(n),
            buckets: (0..n).map(|_| (Vec::new(), Vec::new())).collect(),
        })
    }

    pub fn table(&self) -> &str {
        &self.table
    }

    /// Number of servers in the mesh.
    pub fn server_count(&self) -> usize {
        self.clients.len()
    }

    /// The local↔global index stride (per-server table capacity).
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Total successful redials across all server connections.
    pub fn reconnects(&self) -> u64 {
        self.clients.iter().map(RemoteClient::reconnects).sum()
    }

    /// Direct access to one server's connection (tests, admin tooling).
    pub fn client_mut(&mut self, server: usize) -> &mut RemoteClient {
        &mut self.clients[server]
    }

    /// Every server's per-table stats, mesh order.
    pub fn stats(&mut self) -> Result<Vec<Vec<super::proto::TableInfo>>> {
        self.clients
            .iter_mut()
            .enumerate()
            .map(|(s, c)| {
                call_retry(c, RemoteClient::stats)
                    .with_context(|| format!("mesh stats from server {s}"))
            })
            .collect()
    }

    /// Fan-out checkpoint: every server's full state (chunk-streamed),
    /// mesh order. Each entry restores to the *same* server slot.
    pub fn checkpoint_states(&mut self) -> Result<Vec<ServiceState>> {
        self.clients
            .iter_mut()
            .enumerate()
            .map(|(s, c)| {
                call_retry(c, RemoteClient::checkpoint_state)
                    .with_context(|| format!("mesh checkpoint from server {s}"))
            })
            .collect()
    }

    /// Fan-out restore: one previously captured state per server, mesh
    /// order (the inverse of [`Self::checkpoint_states`]).
    pub fn restore_states(&mut self, states: &[ServiceState]) -> Result<()> {
        ensure!(
            states.len() == self.clients.len(),
            "mesh restore got {} state(s) for {} server(s)",
            states.len(),
            self.clients.len()
        );
        for (s, (client, state)) in self.clients.iter_mut().zip(states).enumerate() {
            call_retry(client, |c| c.restore_state(state))
                .with_context(|| format!("mesh restore into server {s}"))?;
        }
        Ok(())
    }

    /// Level 1 of the two-level draw: refresh every server's advertised
    /// (len, mass) into the reused scratch and return the totals.
    fn refresh_masses(&mut self) -> Result<(u64, f32)> {
        self.masses.clear();
        let table = std::mem::take(&mut self.table);
        let mut result = Ok(());
        for (s, client) in self.clients.iter_mut().enumerate() {
            match call_retry(client, |c| c.mass(&table)) {
                Ok(lm) => self.masses.push(lm),
                Err(e) => {
                    result = Err(e.context(format!("mesh mass probe to server {s}")));
                    break;
                }
            }
        }
        self.table = table;
        result?;
        let len: u64 = self.masses.iter().map(|&(l, _)| l).sum();
        let mass: f32 = self.masses.iter().map(|&(_, m)| m).sum();
        Ok((len, mass))
    }

    /// Pick the server whose mass interval contains `x`, skipping
    /// zero-mass servers while tracking the last positive one — the
    /// mesh image of the sharded buffer's level-1 prefix scan.
    fn pick_server(&self, x: f32) -> Option<usize> {
        let mut sel = None;
        let mut acc = 0.0f32;
        for (k, &(_, m)) in self.masses.iter().enumerate() {
            if m > 0.0 {
                sel = Some(k);
                if acc + m >= x {
                    break;
                }
            }
            acc += m;
        }
        sel
    }
}

impl ExperienceSampler for MeshSampler {
    /// Two-level mesh sampling: one `Mass` probe per server, one
    /// mass-proportional server pick, one whole-batch `Sample` within
    /// the picked server, indices remapped local → global. A throttled
    /// or data-starved server surfaces as the usual retriable outcome.
    fn try_sample(
        &mut self,
        batch: usize,
        _rng: &mut Rng,
        out: &mut SampleBatch,
    ) -> Result<SampleOutcome> {
        let (len, mass) = self.refresh_masses()?;
        if len == 0 || !(mass > 0.0) {
            return Ok(SampleOutcome::NotEnoughData);
        }
        let x = self.rng.f32() * mass;
        let Some(sel) = self.pick_server(x) else {
            return Ok(SampleOutcome::NotEnoughData);
        };
        let table = std::mem::take(&mut self.table);
        let outcome =
            call_retry(&mut self.clients[sel], |c| c.sample(&table, batch, out));
        self.table = table;
        let outcome = outcome.with_context(|| format!("mesh sample from server {sel}"))?;
        if outcome == SampleOutcome::Sampled {
            let base = sel * self.stride;
            for idx in &mut out.indices {
                *idx += base;
            }
        }
        Ok(outcome)
    }

    /// Route each global index back to its server and ship one update
    /// RPC per server touched (the wire image of the sharded buffer's
    /// batched, grouped priority feedback).
    fn update_priorities(&mut self, indices: &[usize], td_abs: &[f32]) -> Result<()> {
        ensure!(
            indices.len() == td_abs.len(),
            "priority update has {} indices but {} values",
            indices.len(),
            td_abs.len()
        );
        for (idx_bucket, td_bucket) in &mut self.buckets {
            idx_bucket.clear();
            td_bucket.clear();
        }
        for (&idx, &td) in indices.iter().zip(td_abs) {
            let s = idx / self.stride;
            ensure!(
                s < self.clients.len(),
                "priority index {idx} outside the mesh (stride {}, {} servers)",
                self.stride,
                self.clients.len()
            );
            self.buckets[s].0.push(idx - s * self.stride);
            self.buckets[s].1.push(td);
        }
        let table = std::mem::take(&mut self.table);
        let mut result = Ok(());
        for (s, (client, (idx_bucket, td_bucket))) in
            self.clients.iter_mut().zip(&self.buckets).enumerate()
        {
            if idx_bucket.is_empty() {
                continue;
            }
            if let Err(e) =
                call_retry(client, |c| c.update_priorities(&table, idx_bucket, td_bucket))
            {
                result = Err(e.context(format!("mesh priority update to server {s}")));
                break;
            }
        }
        self.table = table;
        result
    }

    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_list_parses_mixed_transports() {
        let eps =
            parse_endpoint_list("uds:///tmp/a.sock, tcp://127.0.0.1:7001 ,/tmp/b.sock").unwrap();
        assert_eq!(eps.len(), 3);
        assert_eq!(eps[0], Endpoint::from(std::path::Path::new("/tmp/a.sock")));
        assert_eq!(eps[1], Endpoint::parse("tcp://127.0.0.1:7001").unwrap());
        assert_eq!(eps[2], Endpoint::from(std::path::Path::new("/tmp/b.sock")));
    }

    #[test]
    fn endpoint_list_rejects_duplicates_and_empties() {
        let e = parse_endpoint_list("/tmp/a.sock,/tmp/a.sock").unwrap_err();
        assert!(format!("{e:#}").contains("appears twice"), "{e:#}");
        // A bare path and its uds:// spelling are the same endpoint.
        let e = parse_endpoint_list("/tmp/a.sock,uds:///tmp/a.sock").unwrap_err();
        assert!(format!("{e:#}").contains("appears twice"), "{e:#}");
        let e = parse_endpoint_list("tcp://127.0.0.1:1,,tcp://127.0.0.1:2").unwrap_err();
        assert!(format!("{e:#}").contains("entry 1 is empty"), "{e:#}");
    }

    #[test]
    fn server_seeds_are_distinct_and_stable() {
        let a = server_seed(42, 0);
        let b = server_seed(42, 1);
        let c = server_seed(42, 2);
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert_ne!(a, c);
        // Stable across calls (twins depend on replaying these).
        assert_eq!(a, server_seed(42, 0));
    }

    #[test]
    fn pick_server_skips_zero_mass_like_the_sharded_scan() {
        let mesh = MeshSampler {
            clients: Vec::new(),
            table: "t".into(),
            stride: 8,
            rng: Rng::new(1),
            masses: vec![(0, 0.0), (4, 2.0), (0, 0.0), (4, 2.0)],
            buckets: Vec::new(),
        };
        // x in the first positive interval → server 1; past it → 3.
        assert_eq!(mesh.pick_server(0.0), Some(1));
        assert_eq!(mesh.pick_server(1.9), Some(1));
        assert_eq!(mesh.pick_server(2.5), Some(3));
        // Past the total mass clamps to the last positive server.
        assert_eq!(mesh.pick_server(100.0), Some(3));
    }
}
