//! Loader for `artifacts/manifest.json` produced by `python/compile/aot.py`.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One parameter tensor: name, shape, flat offset into the param vector.
#[derive(Clone, Debug)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// One lowered graph: file + positional signature.
#[derive(Clone, Debug)]
pub struct GraphInfo {
    pub file: PathBuf,
    /// (name, shape) per positional input. Names are `p:<param>`,
    /// `t:<param>`, or batch roles (`obs`, `action`, ..., `noise`).
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<String>,
    /// Half-open slice of the param table covered by the grad outputs.
    pub grad_slice: Option<(usize, usize)>,
}

/// One (algo, env) artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub id: String,
    pub algo: String,
    pub env: String,
    pub obs_dim: usize,
    pub flat_act_dim: usize,
    pub n_actions: Option<usize>,
    pub act_dim: Option<usize>,
    pub act_high: f32,
    pub discrete: bool,
    pub hidden: Vec<usize>,
    pub batch_size: usize,
    pub gamma: f32,
    pub params_file: PathBuf,
    pub total_param_size: usize,
    pub params: Vec<ParamInfo>,
    pub graphs: BTreeMap<String, GraphInfo>,
}

impl ArtifactInfo {
    /// Load the initial parameters blob (little-endian f32).
    pub fn load_initial_params(&self) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&self.params_file)
            .with_context(|| format!("reading {}", self.params_file.display()))?;
        if bytes.len() != self.total_param_size * 4 {
            bail!(
                "param blob {} has {} bytes, manifest says {}",
                self.params_file.display(),
                bytes.len(),
                self.total_param_size * 4
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

fn req<'a>(j: &'a Json, key: &str, ctx: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("manifest: missing `{key}` in {ctx}"))
}

fn usize_of(j: &Json, key: &str, ctx: &str) -> Result<usize> {
    req(j, key, ctx)?
        .as_usize()
        .ok_or_else(|| anyhow!("manifest: `{key}` in {ctx} not a usize"))
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
        .collect()
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;

        let mut artifacts = BTreeMap::new();
        for a in req(&j, "artifacts", "root")?
            .as_arr()
            .ok_or_else(|| anyhow!("artifacts not an array"))?
        {
            let id = req(a, "id", "artifact")?
                .as_str()
                .ok_or_else(|| anyhow!("id not a string"))?
                .to_string();
            let ctx = id.clone();

            let mut params = Vec::new();
            for p in req(a, "params", &ctx)?.as_arr().unwrap_or(&[]) {
                params.push(ParamInfo {
                    name: req(p, "name", &ctx)?.as_str().unwrap_or("").to_string(),
                    shape: shape_of(req(p, "shape", &ctx)?)?,
                    offset: usize_of(p, "offset", &ctx)?,
                    size: usize_of(p, "size", &ctx)?,
                });
            }

            let mut graphs = BTreeMap::new();
            if let Some(Json::Obj(gm)) = a.get("graphs") {
                for (gname, g) in gm {
                    let mut inputs = Vec::new();
                    for i in req(g, "inputs", &ctx)?.as_arr().unwrap_or(&[]) {
                        inputs.push((
                            req(i, "name", &ctx)?.as_str().unwrap_or("").to_string(),
                            shape_of(req(i, "shape", &ctx)?)?,
                        ));
                    }
                    let outputs = req(g, "outputs", &ctx)?
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|o| o.as_str().map(str::to_string))
                        .collect();
                    let grad_slice = match g.get("grad_slice") {
                        Some(Json::Arr(v)) if v.len() == 2 => Some((
                            v[0].as_usize().ok_or_else(|| anyhow!("bad grad_slice"))?,
                            v[1].as_usize().ok_or_else(|| anyhow!("bad grad_slice"))?,
                        )),
                        _ => None,
                    };
                    graphs.insert(
                        gname.clone(),
                        GraphInfo {
                            file: dir.join(
                                req(g, "file", &ctx)?.as_str().unwrap_or_default(),
                            ),
                            inputs,
                            outputs,
                            grad_slice,
                        },
                    );
                }
            }

            let info = ArtifactInfo {
                algo: req(a, "algo", &ctx)?.as_str().unwrap_or("").to_string(),
                env: req(a, "env", &ctx)?.as_str().unwrap_or("").to_string(),
                obs_dim: usize_of(a, "obs_dim", &ctx)?,
                flat_act_dim: usize_of(a, "flat_act_dim", &ctx)?,
                n_actions: a.get("n_actions").and_then(Json::as_usize),
                act_dim: a.get("act_dim").and_then(Json::as_usize),
                act_high: req(a, "act_high", &ctx)?.as_f64().unwrap_or(1.0) as f32,
                discrete: req(a, "discrete", &ctx)?.as_bool().unwrap_or(false),
                hidden: req(a, "hidden", &ctx)?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
                batch_size: usize_of(a, "batch_size", &ctx)?,
                gamma: req(a, "gamma", &ctx)?.as_f64().unwrap_or(0.99) as f32,
                params_file: dir.join(req(a, "params_file", &ctx)?.as_str().unwrap_or("")),
                total_param_size: usize_of(a, "total_param_size", &ctx)?,
                params,
                graphs,
                id: id.clone(),
            };

            // Sanity: offsets must tile [0, total).
            let mut expect = 0usize;
            for p in &info.params {
                if p.offset != expect || p.size != p.shape.iter().product::<usize>() {
                    bail!("manifest {id}: param table inconsistent at `{}`", p.name);
                }
                expect += p.size;
            }
            if expect != info.total_param_size {
                bail!("manifest {id}: params sum {expect} != total {}", info.total_param_size);
            }

            artifacts.insert(id, info);
        }
        Ok(Self { dir, artifacts })
    }

    pub fn get(&self, id: &str) -> Result<&ArtifactInfo> {
        self.artifacts.get(id).ok_or_else(|| {
            anyhow!(
                "artifact `{id}` not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Find the artifact for an (algo, env) pair.
    pub fn find(&self, algo: &str, env: &str) -> Result<&ArtifactInfo> {
        self.get(&format!("{algo}_{env}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Needs `make artifacts` (skips otherwise) — validates the real file.
    #[test]
    fn loads_real_manifest_when_present() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !Path::new(dir).join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert!(!m.artifacts.is_empty());
        for info in m.artifacts.values() {
            assert!(info.graphs.contains_key("act"), "{}", info.id);
            let p0 = info.load_initial_params().unwrap();
            assert_eq!(p0.len(), info.total_param_size);
            assert!(p0.iter().all(|v| v.is_finite()));
            // Learn graphs must declare grad slices within the param table.
            for (g, gi) in &info.graphs {
                if g.starts_with("learn") {
                    let (lo, hi) = gi.grad_slice.expect("learn graph needs grad_slice");
                    assert!(lo < hi && hi <= info.params.len());
                    // grads outputs must align with the slice.
                    let n_grads = gi.outputs.iter().filter(|o| o.starts_with("g:")).count();
                    assert_eq!(n_grads, hi - lo, "{}:{g}", info.id);
                }
            }
        }
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load("/nonexistent/dir").is_err());
    }
}
