//! PJRT runtime: load AOT-compiled HLO text and execute it on the CPU
//! client from the rust hot path (python is never invoked here).
//!
//! Thread model: the `xla` crate's wrappers hold raw pointers and are not
//! `Send`, so each worker thread owns its own [`Runtime`] (client +
//! compiled executables). Model weights cross threads only as plain
//! `Vec<f32>` via the parameter server, never as PJRT objects.

pub mod manifest;

pub use manifest::{ArtifactInfo, GraphInfo, Manifest, ParamInfo};

use anyhow::{anyhow, bail, Context, Result};
use std::cell::Cell;
use std::collections::BTreeMap;
use std::rc::Rc;

/// A PJRT CPU client plus execution accounting.
pub struct Runtime {
    client: xla::PjRtClient,
    /// Cumulative executions (metrics / perf accounting).
    exec_count: Cell<u64>,
}

impl Runtime {
    pub fn cpu() -> Result<Rc<Self>> {
        Ok(Rc::new(Self {
            client: xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?,
            exec_count: Cell::new(0),
        }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn executions(&self) -> u64 {
        self.exec_count.get()
    }

    /// Compile one HLO-text file into an executable.
    pub fn load_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {}", path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
    }

    /// Compile every graph of an artifact into a [`Model`].
    pub fn load_model(self: &Rc<Self>, info: &ArtifactInfo) -> Result<Model> {
        let mut graphs = BTreeMap::new();
        for (name, g) in &info.graphs {
            let exe = self
                .load_hlo_text(&g.file)
                .with_context(|| format!("graph {}:{name}", info.id))?;
            graphs.insert(
                name.clone(),
                Graph { exe, info: g.clone(), rt: Rc::clone(self) },
            );
        }
        Ok(Model { info: info.clone(), graphs })
    }
}

/// One positional graph input: either host data (uploaded per call) or a
/// device-resident buffer (uploaded once, reused across calls — the §Perf
/// fast path for parameters that only change on version bumps).
pub enum Input<'a> {
    Host(&'a [f32]),
    Device(&'a xla::PjRtBuffer),
}

/// One compiled graph with its positional signature.
pub struct Graph {
    exe: xla::PjRtLoadedExecutable,
    pub info: GraphInfo,
    rt: Rc<Runtime>,
}

impl Graph {
    /// Number of positional inputs.
    pub fn arity(&self) -> usize {
        self.info.inputs.len()
    }

    /// Upload one input to the device (shape from the manifest signature).
    pub fn upload(&self, input_idx: usize, data: &[f32]) -> Result<xla::PjRtBuffer> {
        let (name, shape) = &self.info.inputs[input_idx];
        let expect: usize = shape.iter().product();
        if data.len() != expect {
            bail!("upload `{name}`: {} elements, shape {shape:?} wants {expect}", data.len());
        }
        self.rt
            .client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(|e| anyhow!("upload `{name}`: {e:?}"))
    }

    /// Execute with a mix of device-resident and host inputs. Host inputs
    /// are uploaded on the fly; device inputs are reused as-is.
    pub fn run_mixed(&self, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "graph {}: got {} inputs, signature has {}",
                self.info.file.display(),
                inputs.len(),
                self.info.inputs.len()
            );
        }
        // Keep uploads alive for the call duration.
        let mut uploaded: Vec<xla::PjRtBuffer> = Vec::new();
        let mut order: Vec<(bool, usize)> = Vec::with_capacity(inputs.len()); // (is_device, idx)
        let mut device_refs: Vec<&xla::PjRtBuffer> = Vec::new();
        for (i, inp) in inputs.iter().enumerate() {
            match inp {
                Input::Device(b) => {
                    order.push((true, device_refs.len()));
                    device_refs.push(b);
                }
                Input::Host(data) => {
                    order.push((false, uploaded.len()));
                    uploaded.push(self.upload(i, data)?);
                }
            }
        }
        let args: Vec<&xla::PjRtBuffer> = order
            .iter()
            .map(|&(dev, j)| if dev { device_refs[j] } else { &uploaded[j] })
            .collect();
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(|e| anyhow!("execute_b {}: {e:?}", self.info.file.display()))?;
        self.rt.exec_count.set(self.rt.exec_count.get() + 1);
        self.collect_outputs(result)
    }

    fn collect_outputs(&self, result: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Vec<f32>>> {
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = root.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != self.info.outputs.len() {
            bail!(
                "graph {}: {} outputs, manifest says {}",
                self.info.file.display(),
                parts.len(),
                self.info.outputs.len()
            );
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("output to_vec: {e:?}")))
            .collect()
    }

    /// Execute with positional f32 buffers; shapes come from the manifest.
    /// Returns one `Vec<f32>` per declared output.
    pub fn run(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.info.inputs.len() {
            bail!(
                "graph {}: got {} inputs, signature has {}",
                self.info.file.display(),
                inputs.len(),
                self.info.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, (name, shape)) in inputs.iter().zip(&self.info.inputs) {
            let expect: usize = shape.iter().product();
            if buf.len() != expect {
                bail!(
                    "input `{name}`: {} elements, shape {shape:?} wants {expect}",
                    buf.len()
                );
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape `{name}`: {e:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.info.file.display()))?;
        self.rt.exec_count.set(self.rt.exec_count.get() + 1);
        // aot.py lowers with return_tuple=True: root is always a tuple.
        self.collect_outputs(result)
    }
}

/// A fully-compiled model: all graphs of one artifact on one runtime.
pub struct Model {
    pub info: ArtifactInfo,
    graphs: BTreeMap<String, Graph>,
}

impl Model {
    pub fn graph(&self, name: &str) -> Result<&Graph> {
        self.graphs.get(name).ok_or_else(|| {
            anyhow!(
                "model {} has no graph `{name}` (have {:?})",
                self.info.id,
                self.graphs.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn has_graph(&self, name: &str) -> bool {
        self.graphs.contains_key(name)
    }

    /// Split a flat parameter vector into per-parameter slices in the
    /// manifest's declared order (matching graph input positions).
    pub fn param_slices<'a>(&self, flat: &'a [f32]) -> Result<Vec<&'a [f32]>> {
        if flat.len() != self.info.total_param_size {
            bail!(
                "param vector has {} elems, manifest wants {}",
                flat.len(),
                self.info.total_param_size
            );
        }
        Ok(self
            .info
            .params
            .iter()
            .map(|p| &flat[p.offset..p.offset + p.size])
            .collect())
    }
}
