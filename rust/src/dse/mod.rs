//! Design-space exploration (paper §V-D, Eq. 5, Fig 12).
//!
//! Given profiled throughput curves f_a(x) (collection vs cores) and
//! f_l(x) (consumption vs cores), pick the core split (x_a, x_l) with
//! x_a + x_l <= M whose throughputs satisfy
//! `f_a(x_a) = update_interval * f_l(x_l)` as closely as possible,
//! breaking ties toward higher throughput. Exhaustive O(M²) search, as in
//! the paper (§VI-G).
//!
//! Curves come from the DES ([`crate::sim`]) driven by a [`CostProfile`]:
//! either measured live on this machine ([`CostProfile::measure`]) or the
//! representative values recorded from those measurements.

use crate::replay::{PrioritizedConfig, PrioritizedReplay, ReplayBuffer, SampleBatch, Transition};
use crate::sim::OpCosts;
use crate::util::rng::Rng;
use std::time::Instant;

/// Per-operation costs driving the throughput curves.
#[derive(Clone, Copy, Debug)]
pub struct CostProfile {
    pub costs: OpCosts,
    /// Use the lazy-writing/two-lock task shapes (true) or the global-lock
    /// baseline shapes (false).
    pub pal_design: bool,
    /// Replay shards S in the modeled buffer (PAL design only): actor
    /// inserts route to `actor % S`, learner sample/update critical
    /// sections split across the S shard locks. Part of the explored
    /// design space — see [`CostProfile::shard_sweep`].
    pub shards: usize,
    /// Model the accelerator as one exclusive device (the paper's GPU) or
    /// as per-thread compute (this host's PJRT-CPU learners).
    pub serialized_accel: bool,
    /// Concurrent batches the accelerator overlaps before saturating
    /// (GPUs pipeline a few learners' batches; only meaningful when
    /// `serialized_accel`).
    pub accel_slots: usize,
    /// Extra interpreted-framework cost per actor step / learn step and a
    /// serialized coordination section per step (the RLlib-substitute
    /// baseline of Fig 8; zeros for PAL).
    pub framework_actor_ns: u64,
    pub framework_learn_ns: u64,
    pub framework_sync_ns: u64,
    /// Replay-service rate limiter in the modeled pipeline: σ samples
    /// per insert (`SampleToInsertRatio`), 0.0 = no limiter. The DES
    /// runs limiter-free; the coupling and its stall terms are applied
    /// to its result ([`crate::sim::SimResult::rate_limited`]).
    pub samples_per_insert: f64,
}

impl CostProfile {
    /// Representative costs for (algo, env) pairs, recorded from
    /// `CostProfile::measure` runs on this container (see EXPERIMENTS.md).
    /// Used when a quick answer is wanted without a measurement pass.
    pub fn representative(algo: &str, env: &str) -> Self {
        // Measured on this host (quickstart / continuous_control runs):
        // one PJRT act execution on a (64,64) MLP ≈ 250 µs dominated by
        // dispatch; learn graphs ≈ 1.3–2.6 ms depending on graph count.
        let act_ns = match env {
            "LunarLanderLite-v0" => 280_000,
            "Pendulum-v1" => 260_000,
            _ => 250_000,
        };
        let env_ns = match env {
            "LunarLanderLite-v0" => 1_500,
            "Acrobot-v1" => 4_000,
            _ => 700,
        };
        let learn_ns = match algo {
            "sac" | "td3" => 2_600_000,
            "ddpg" => 1_800_000,
            _ => 1_300_000,
        };
        Self {
            costs: OpCosts {
                act_ns,
                env_ns,
                insert_lock_ns: 700,
                insert_copy_ns: 300,
                sample_lock_ns: 30_000,
                batch_copy_ns: 15_000,
                learn_ns,
                update_lock_ns: 25_000,
                server_ns: 40_000,
            },
            pal_design: true,
            shards: 1,
            serialized_accel: false,
            accel_slots: 1,
            framework_actor_ns: 0,
            framework_learn_ns: 0,
            framework_sync_ns: 0,
            samples_per_insert: 0.0,
        }
    }

    /// An RLlib-substitute baseline profile: same algorithm costs, but the
    /// global-lock buffer design plus interpreted-framework overheads —
    /// per-step Python loop cost, per-learn serialization cost, and a
    /// synchronized (PAAC-style) coordination section every actor step.
    /// Constants are conservative CPython/Ray magnitudes (DESIGN.md §4).
    pub fn rllib_like(algo: &str, env: &str) -> Self {
        let mut p = Self::representative(algo, env);
        p.pal_design = false;
        p.framework_actor_ns = 400_000;   // python actor loop + obs boxing
        p.framework_learn_ns = 2_000_000; // sample-batch assembly, IPC
        p.framework_sync_ns = 800_000;    // centralized driver section per
                                          // learn step (Ray coordination)
        p
    }

    /// Measure buffer-op costs live on this machine (µ-bench each op).
    /// `act_ns`/`learn_ns` must still be supplied by the caller (they
    /// depend on the compiled model; the trainer measures them).
    pub fn measure(act_ns: u64, env_ns: u64, learn_ns: u64) -> Self {
        let buf = PrioritizedReplay::new(PrioritizedConfig {
            capacity: 100_000,
            obs_dim: 8,
            act_dim: 2,
            fanout: 64,
            alpha: 0.6,
            beta: 0.4,
            lazy_writing: true,
            shards: 1,
        });
        let tr = Transition {
            obs: vec![0.5; 8],
            action: vec![0.1; 2],
            next_obs: vec![0.6; 8],
            reward: 1.0,
            done: false,
        };
        for _ in 0..50_000 {
            buf.insert(&tr);
        }
        let mut rng = Rng::new(1);

        // Insert cost split: measure with timing instrumentation.
        buf.stats.enable_timing();
        for _ in 0..5_000 {
            buf.insert(&tr);
        }
        let snap = buf.stats.snapshot();
        let insert_lock_ns = (snap.global_held_ns / snap.global_acquisitions.max(1)).max(50);
        let insert_copy_ns = (snap.storage_copy_ns / 5_000).max(50);

        // Sampling cost: descent under lock + row copies.
        let mut out = SampleBatch::default();
        let t0 = Instant::now();
        for _ in 0..2_000 {
            buf.sample(64, &mut rng, &mut out);
        }
        let sample_total = t0.elapsed().as_nanos() as u64 / 2_000;

        // Priority update cost.
        let idx: Vec<usize> = (0..64).map(|_| rng.below_usize(50_000)).collect();
        let tds = vec![0.5f32; 64];
        let t1 = Instant::now();
        for _ in 0..2_000 {
            buf.update_priorities(&idx, &tds);
        }
        let update_ns = t1.elapsed().as_nanos() as u64 / 2_000;

        Self {
            costs: OpCosts {
                act_ns,
                env_ns,
                insert_lock_ns,
                insert_copy_ns,
                // Rough split: descent is ~60% of a batched sample here.
                sample_lock_ns: sample_total * 6 / 10,
                batch_copy_ns: sample_total * 4 / 10,
                learn_ns,
                update_lock_ns: update_ns,
                server_ns: 40_000,
            },
            pal_design: true,
            shards: 1,
            serialized_accel: false,
            accel_slots: 1,
            framework_actor_ns: 0,
            framework_learn_ns: 0,
            framework_sync_ns: 0,
            samples_per_insert: 0.0,
        }
    }

    fn tasks(&self, actors: usize, learners: usize) -> Vec<crate::sim::Task> {
        use crate::sim::{Lock, Segment};
        let mut tasks = if self.pal_design {
            self.costs.pal_tasks_sharded(
                actors,
                learners,
                self.shards.max(1),
                self.serialized_accel,
            )
        } else {
            self.costs.baseline_tasks_accel(actors, learners, self.serialized_accel)
        };
        // Framework overheads (RLlib-substitute baseline).
        for (i, t) in tasks.iter_mut().enumerate() {
            let is_actor = i < actors;
            if is_actor && self.framework_actor_ns > 0 {
                t.segments.push(Segment::cpu(self.framework_actor_ns));
            }
            if is_actor && self.framework_sync_ns > 0 {
                // Synchronized collection: a short serialized section.
                t.segments.push(Segment::locked(self.framework_sync_ns / 16, Lock::Server));
            }
            if !is_actor && self.framework_learn_ns > 0 {
                t.segments.push(Segment::cpu(self.framework_learn_ns));
            }
            if !is_actor && self.framework_sync_ns > 0 {
                // Centralized driver/object-store coordination per learn
                // step — the scaling bottleneck of the Python framework.
                t.segments.push(Segment::locked(self.framework_sync_ns, Lock::Server));
            }
        }
        tasks
    }

    fn run(&self, tasks: &[crate::sim::Task], cores: usize) -> crate::sim::SimResult {
        crate::sim::simulate_with(tasks, cores, self.accel_slots, 200_000_000)
    }

    /// Joint simulation with the configured rate limiter's coupling (and
    /// stall terms) applied; identical to [`Self::joint`] when
    /// `samples_per_insert` is 0.
    pub fn limited_joint(
        &self,
        actors: usize,
        learners: usize,
        cores: usize,
    ) -> crate::sim::SimResult {
        let r = self.run(&self.tasks(actors, learners), cores);
        if self.samples_per_insert > 0.0 {
            r.rate_limited(self.samples_per_insert)
        } else {
            r
        }
    }

    /// Rate-limiter stall terms at a split: the fraction of each side's
    /// free-run throughput the limiter burns, `(actor, learner)`.
    pub fn limiter_stalls(&self, actors: usize, learners: usize, cores: usize) -> (f64, f64) {
        let r = self.limited_joint(actors, learners, cores);
        (r.actor_stall_frac, r.learner_stall_frac)
    }

    /// Balanced training throughput of a split at `cores` cores under the
    /// ratio constraint: min(collect, ratio × consume), after any
    /// configured rate limiter has coupled the two sides. This is what
    /// the paper's end-to-end figures effectively measure (convergence
    /// speed follows the paced pipeline's slower side).
    pub fn balanced(&self, actors: usize, learners: usize, cores: usize, ratio: f64) -> f64 {
        let r = self.limited_joint(actors, learners, cores);
        r.collect_per_sec.min(ratio * r.consume_per_sec)
    }

    /// Best split by balanced throughput (exhaustive, O(M²) like Eq. 5).
    pub fn best_balanced(&self, cores: usize, ratio: f64) -> (usize, usize, f64) {
        let mut best = (1, 1, 0.0f64);
        for xa in 1..cores.max(2) {
            for xl in 1..=(cores.saturating_sub(xa)).max(1) {
                let b = self.balanced(xa, xl, cores, ratio);
                if b > best.2 {
                    best = (xa, xl, b);
                }
            }
        }
        best
    }

    /// f_a(x): collection throughput with x actor cores (steps/sec).
    pub fn f_a(&self, x: usize) -> f64 {
        if x == 0 {
            return 0.0;
        }
        self.run(&self.tasks(x, 0), x).collect_per_sec
    }

    /// f_l(x): consumption throughput with x learner cores (batches/sec).
    pub fn f_l(&self, x: usize) -> f64 {
        if x == 0 {
            return 0.0;
        }
        self.run(&self.tasks(0, x), x).consume_per_sec
    }

    /// Joint simulation of a concrete split on M cores.
    pub fn joint(&self, actors: usize, learners: usize, cores: usize) -> crate::sim::SimResult {
        self.run(&self.tasks(actors, learners), cores)
    }

    /// Extended design space: for each candidate shard count S, the best
    /// balanced throughput over all core splits (Eq. 5 search run per S).
    /// Returns `(S, throughput)` rows in candidate order; candidates are
    /// clamped to ≥ 1, and the row reports the clamped value actually
    /// simulated (the training path cannot honor S=0 either).
    pub fn shard_sweep(
        &self,
        cores: usize,
        ratio: f64,
        candidates: &[usize],
    ) -> Vec<(usize, f64)> {
        candidates
            .iter()
            .map(|&s| {
                let s = s.max(1);
                let mut p = *self;
                p.shards = s;
                let (_, _, tput) = p.best_balanced(cores, ratio);
                (s, tput)
            })
            .collect()
    }

    /// Fold the winning row out of [`Self::shard_sweep`] output — the
    /// planner's choice for the S knob.
    pub fn pick_best_shards(sweep: &[(usize, f64)]) -> (usize, f64) {
        sweep
            .iter()
            .fold((1, 0.0f64), |best, &(s, t)| if t > best.1 { (s, t) } else { best })
    }

    /// The shard count (and its throughput) maximizing balanced training
    /// throughput at `cores`. Convenience wrapper; callers that already
    /// ran [`Self::shard_sweep`] should fold its rows with
    /// [`Self::pick_best_shards`] instead of paying the sweep twice.
    pub fn best_shards(&self, cores: usize, ratio: f64, candidates: &[usize]) -> (usize, f64) {
        Self::pick_best_shards(&self.shard_sweep(cores, ratio, candidates))
    }
}

/// Chosen core allocation.
#[derive(Clone, Copy, Debug)]
pub struct Plan {
    pub actors: usize,
    pub learners: usize,
    pub collect_throughput: f64,
    pub consume_throughput: f64,
    /// |f_a - ratio·f_l| / max(...) at the chosen point.
    pub mismatch: f64,
}

/// Exhaustive search of Eq. 5: x_a + x_l <= M.
pub fn explore(profile: &CostProfile, cores: usize, update_interval: f64) -> Plan {
    let mut fa = vec![0.0; cores + 1];
    let mut fl = vec![0.0; cores + 1];
    for x in 1..=cores {
        fa[x] = profile.f_a(x);
        fl[x] = profile.f_l(x);
    }
    let mut best: Option<Plan> = None;
    for xa in 1..cores {
        for xl in 1..=(cores - xa) {
            let collect = fa[xa];
            let consume = fl[xl];
            let target = update_interval * consume;
            let mismatch = (collect - target).abs() / collect.max(target).max(1e-9);
            let better = match &best {
                None => true,
                Some(b) => {
                    // Primary: ratio match. Secondary: total throughput.
                    mismatch < b.mismatch - 1e-9
                        || (mismatch < b.mismatch + 1e-9
                            && collect + consume
                                > b.collect_throughput + b.consume_throughput)
                }
            };
            if better {
                best = Some(Plan {
                    actors: xa,
                    learners: xl,
                    collect_throughput: collect,
                    consume_throughput: consume,
                    mismatch,
                });
            }
        }
    }
    best.expect("cores >= 2 required")
}

/// ASCII rendering of the two profile curves (Fig 12 shape).
pub fn render_curves(profile: &CostProfile, cores: usize) -> String {
    let mut s = String::from("cores  f_a(collect/s)  f_l(consume/s)\n");
    for x in 1..=cores {
        s.push_str(&format!("{:5}  {:14.0}  {:14.0}\n", x, profile.f_a(x), profile.f_l(x)));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn curves_monotone_nondecreasing() {
        let p = CostProfile::representative("dqn", "CartPole-v1");
        let mut prev = 0.0;
        for x in 1..=8 {
            let v = p.f_a(x);
            assert!(v >= prev * 0.99, "f_a({x}) = {v} < {prev}");
            prev = v;
        }
    }

    #[test]
    fn learner_curve_saturates_on_accelerator() {
        let mut p = CostProfile::representative("sac", "Pendulum-v1");
        p.serialized_accel = true; // the paper's single-GPU model
        let f1 = p.f_l(1);
        let f8 = p.f_l(8);
        assert!(f8 < 2.0 * f1, "accelerator must bound learners: {f1} -> {f8}");
    }

    #[test]
    fn explore_respects_core_budget_and_ratio() {
        let p = CostProfile::representative("dqn", "CartPole-v1");
        for ratio in [1.0, 4.0] {
            let plan = explore(&p, 8, ratio);
            assert!(plan.actors + plan.learners <= 8);
            assert!(plan.actors >= 1 && plan.learners >= 1);
            // The selected mismatch should beat a naive half split.
            let naive = (p.f_a(4) - ratio * p.f_l(4)).abs()
                / p.f_a(4).max(ratio * p.f_l(4));
            assert!(plan.mismatch <= naive + 1e-9, "ratio {ratio}");
        }
    }

    #[test]
    fn shard_sweep_explores_and_pays_off_when_lock_bound() {
        // Buffer-bound profile: cheap act/learn leaves the tree lock as
        // the S=1 bottleneck at 8 cores, so the planner must pick S>1 and
        // gain real balanced throughput from it.
        let mut p = CostProfile::representative("dqn", "CartPole-v1");
        p.costs.act_ns = 2_000;
        p.costs.learn_ns = 20_000;
        p.costs.sample_lock_ns = 40_000;
        p.costs.update_lock_ns = 30_000;
        p.costs.server_ns = 10_000;
        let sweep = p.shard_sweep(8, 1.0, &[1, 2, 4, 8]);
        assert_eq!(sweep.len(), 4);
        let t1 = sweep[0].1;
        assert!(t1 > 0.0);
        let (best_s, best_t) = CostProfile::pick_best_shards(&sweep);
        assert!(best_s >= 2, "planner stuck at S=1");
        assert!(
            best_t >= 1.5 * t1,
            "sharding gain only {:.2}x",
            best_t / t1
        );
    }

    #[test]
    fn rate_limiter_stall_terms_couple_the_pipeline() {
        let mut p = CostProfile::representative("dqn", "CartPole-v1");
        // No limiter: no stall terms, limited_joint == joint.
        let free = p.limited_joint(4, 2, 8);
        assert_eq!(free.actor_stall_frac, 0.0);
        assert_eq!(free.learner_stall_frac, 0.0);
        // σ = 8 samples per insert: cheap acting vastly outruns
        // 8·consume, so the limiter stalls the actors hard.
        p.samples_per_insert = 8.0;
        let ltd = p.limited_joint(4, 2, 8);
        assert!(ltd.collect_per_sec < free.collect_per_sec);
        let (actor_stall, learner_stall) = p.limiter_stalls(4, 2, 8);
        assert!(actor_stall > 0.5, "actor stall {actor_stall}");
        assert_eq!(learner_stall, 0.0);
        // The balanced objective must reflect the coupled pipeline.
        assert!(p.balanced(4, 2, 8, 1.0) <= free.collect_per_sec);
        // A tiny σ flips the stall to the learner side.
        p.samples_per_insert = 1e-6;
        let (a2, l2) = p.limiter_stalls(4, 2, 8);
        assert_eq!(a2, 0.0);
        assert!(l2 > 0.5, "learner stall {l2}");
    }

    #[test]
    fn measured_profile_is_sane() {
        let p = CostProfile::measure(40_000, 1_000, 1_000_000);
        let c = p.costs;
        assert!(c.insert_lock_ns > 0 && c.insert_lock_ns < 1_000_000);
        assert!(c.sample_lock_ns > 0);
        assert!(c.update_lock_ns > 0);
        // A measured profile must produce a usable plan.
        let plan = explore(&p, 4, 1.0);
        assert!(plan.actors + plan.learners <= 4);
    }
}
