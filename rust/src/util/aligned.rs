//! Cache-line-aligned heap buffers.
//!
//! The paper's data-layout contribution (§IV-C4, Fig 6) requires every
//! group of K sibling nodes to start on a cache-line boundary. Rust's `Vec`
//! only guarantees element alignment, so we allocate explicitly with a
//! 64-byte-aligned `Layout`.

use std::alloc::{alloc_zeroed, dealloc, Layout};
use std::ops::{Deref, DerefMut};

/// Cache line size assumed throughout the crate (bytes).
pub const CACHE_LINE: usize = 64;

/// A heap slice of `T` whose first element sits on a 64-byte boundary.
///
/// Memory is zero-initialised, which is a valid bit-pattern for every `T`
/// we store (f32 bits / atomics over integer words).
pub struct AlignedBox<T> {
    ptr: *mut T,
    len: usize,
}

// Safety: AlignedBox owns its allocation exclusively; `T: Send/Sync`
// transfers as for Box<[T]>.
unsafe impl<T: Send> Send for AlignedBox<T> {}
unsafe impl<T: Sync> Sync for AlignedBox<T> {}

impl<T> AlignedBox<T> {
    /// Allocate `len` zeroed elements aligned to the cache line.
    ///
    /// Panics if `len == 0` allocations are requested with a zero-sized `T`
    /// or if the allocator fails.
    pub fn zeroed(len: usize) -> Self {
        assert!(std::mem::size_of::<T>() > 0, "ZSTs not supported");
        if len == 0 {
            return Self { ptr: std::ptr::NonNull::dangling().as_ptr(), len: 0 };
        }
        let align = CACHE_LINE.max(std::mem::align_of::<T>());
        let layout = Layout::from_size_align(len * std::mem::size_of::<T>(), align)
            .expect("bad layout");
        // Safety: layout has non-zero size (checked above).
        let ptr = unsafe { alloc_zeroed(layout) } as *mut T;
        assert!(!ptr.is_null(), "allocation failure of {} bytes", layout.size());
        Self { ptr, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn as_ptr(&self) -> *const T {
        self.ptr
    }
}

impl<T> Deref for AlignedBox<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        // Safety: ptr/len describe our exclusive allocation.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl<T> DerefMut for AlignedBox<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        // Safety: &mut self gives exclusive access.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

impl<T> Drop for AlignedBox<T> {
    fn drop(&mut self) {
        if self.len == 0 {
            return;
        }
        let align = CACHE_LINE.max(std::mem::align_of::<T>());
        let layout = Layout::from_size_align(self.len * std::mem::size_of::<T>(), align)
            .expect("bad layout");
        // Safety: allocated with the identical layout in `zeroed`.
        unsafe { dealloc(self.ptr as *mut u8, layout) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_to_cache_line() {
        for len in [1usize, 3, 16, 17, 1024] {
            let b = AlignedBox::<f32>::zeroed(len);
            assert_eq!(b.as_ptr() as usize % CACHE_LINE, 0);
            assert_eq!(b.len(), len);
            assert!(b.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn zero_len_ok() {
        let b = AlignedBox::<u64>::zeroed(0);
        assert!(b.is_empty());
    }

    #[test]
    fn mutation_roundtrip() {
        let mut b = AlignedBox::<u32>::zeroed(100);
        for (i, v) in b.iter_mut().enumerate() {
            *v = i as u32;
        }
        assert_eq!(b[99], 99);
        assert_eq!(b.iter().sum::<u32>(), 4950);
    }

    #[test]
    fn atomics_supported() {
        use std::sync::atomic::{AtomicU32, Ordering};
        let b = AlignedBox::<AtomicU32>::zeroed(64);
        b[5].store(7, Ordering::Relaxed);
        assert_eq!(b[5].load(Ordering::Relaxed), 7);
        assert_eq!(b[6].load(Ordering::Relaxed), 0);
    }
}
