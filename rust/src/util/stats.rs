//! Small statistics helpers shared by the bench harness and metrics.

/// Running mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Exponential moving average with smoothing factor `alpha`.
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(v) => v + self.alpha * (x - v),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert_eq!(r.count(), 5);
        assert!((r.mean() - 6.2).abs() < 1e-12);
        let naive_var =
            xs.iter().map(|x| (x - 6.2) * (x - 6.2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((r.var() - naive_var).abs() < 1e-9);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 16.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 51.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 101.0);
        assert_eq!(percentile(&xs, 99.0), 100.0);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..40 {
            e.push(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-6);
    }
}
