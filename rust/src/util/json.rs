//! Minimal JSON parser (offline substitute for serde_json).
//!
//! Supports the full JSON grammar; numbers parse to f64. Used to read
//! `artifacts/manifest.json` and config overrides — small documents, so
//! clarity beats throughput here.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// `obj["a"]["b"][2]`-style path access: keys and indices.
    pub fn path(&self, parts: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in parts {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(v) => v.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    /// Serialize (compact). Round-trips everything we parse.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("short \\u"))?;
                            let code = u16::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| self.err("short surrogate"))?;
                                    let low = u16::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    let c = 0x10000
                                        + ((code as u32 - 0xD800) << 10)
                                        + (low as u32 - 0xDC00);
                                    char::from_u32(c).ok_or_else(|| self.err("bad pair"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                char::from_u32(code as u32)
                                    .ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| self.err("truncated utf8"))?;
                        s.push_str(
                            std::str::from_utf8(bytes).map_err(|_| self.err("bad utf8"))?,
                        );
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "version": 1,
          "artifacts": [
            {"id": "dqn_CartPole-v1", "gamma": 0.99, "discrete": true,
             "n_actions": 2, "act_dim": null,
             "params": [{"name": "q/w0", "shape": [4, 64], "offset": 0}]}
          ]
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.path(&["version"]).unwrap().as_usize(), Some(1));
        let a0 = j.path(&["artifacts", "0"]).unwrap();
        assert_eq!(a0.get("id").unwrap().as_str(), Some("dqn_CartPole-v1"));
        assert_eq!(a0.get("gamma").unwrap().as_f64(), Some(0.99));
        assert_eq!(a0.get("discrete").unwrap().as_bool(), Some(true));
        assert!(a0.get("act_dim").unwrap().is_null());
        assert_eq!(
            a0.path(&["params", "0", "shape", "1"]).unwrap().as_usize(),
            Some(64)
        );
    }

    #[test]
    fn escapes_and_unicode() {
        let j = Json::parse(r#""a\n\"b\"é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\"b\"é😀"));
    }

    #[test]
    fn raw_utf8_in_strings() {
        let j = Json::parse("\"héllo😀\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo😀"));
    }

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-1.5", -1.5), ("2e3", 2000.0), ("1.25e-2", 0.0125)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "\"abc", "tru", "1.2.3", "{\"a\" 1}", "[1] x"] {
            assert!(Json::parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,null,true,"x\ny"],"b":{"c":-3}}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn as_usize_rejects_negative_and_fractional() {
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
    }
}
