//! Shared on-disk blob format for every PAL checkpoint file.
//!
//! One file = `magic (8 bytes) + payload + crc32(payload)`. The magic
//! identifies the file *kind* (weights vs replay state); versioning of
//! the payload layout is the payload's own first field, so a bumped
//! format is reported as a version mismatch rather than "not a
//! checkpoint". Writes go through a temp file + rename so a crash
//! mid-write can never leave a half-written file under the final name —
//! readers either see the previous complete blob or the new one.
//!
//! [`ByteWriter`] / [`ByteReader`] are the little-endian encode/decode
//! cursors used on top of the payload: every read is bounds-checked and
//! fails with a descriptive error naming the field, so corrupt or
//! truncated payloads surface as clean `Err`s, never panics.

use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Table-free CRC-32 (IEEE), enough for corruption detection.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Write `magic + payload + crc` atomically: the bytes land in
/// `<path>.tmp` first and are renamed into place only after a full
/// flush, so `path` always holds a complete blob.
pub fn write_blob(path: impl AsRef<Path>, magic: &[u8; 8], payload: &[u8]) -> Result<()> {
    let path = path.as_ref();
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(magic)?;
        f.write_all(payload)?;
        f.write_all(&crc32(payload).to_le_bytes())?;
        f.sync_all()
            .with_context(|| format!("flushing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

/// Read a blob back, validating length, magic and checksum. Returns the
/// payload bytes.
pub fn read_blob(path: impl AsRef<Path>, magic: &[u8; 8]) -> Result<Vec<u8>> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    if bytes.len() < magic.len() + 4 {
        bail!(
            "{}: {} bytes is too short to be a PAL blob",
            path.display(),
            bytes.len()
        );
    }
    if &bytes[..magic.len()] != magic {
        bail!(
            "{}: bad magic (want `{}`)",
            path.display(),
            String::from_utf8_lossy(magic)
        );
    }
    let payload = &bytes[magic.len()..bytes.len() - 4];
    let stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    if crc32(payload) != stored {
        bail!("{}: corrupted (crc mismatch)", path.display());
    }
    Ok(payload.to_vec())
}

/// As [`read_blob`], but accepting any of several file-kind magics —
/// for formats whose magic carries a major revision (e.g. `PALSTAT1` /
/// `PALSTAT2`), where old files must keep loading. Returns the payload
/// and the index of the magic that matched.
pub fn read_blob_any(path: impl AsRef<Path>, magics: &[&[u8; 8]]) -> Result<(Vec<u8>, usize)> {
    let path = path.as_ref();
    let bytes = std::fs::read(path).with_context(|| format!("opening {}", path.display()))?;
    if bytes.len() < 8 + 4 {
        bail!(
            "{}: {} bytes is too short to be a PAL blob",
            path.display(),
            bytes.len()
        );
    }
    let which = match magics.iter().position(|m| &bytes[..8] == m.as_slice()) {
        Some(i) => i,
        None => bail!(
            "{}: bad magic (want one of {})",
            path.display(),
            magics
                .iter()
                .map(|m| format!("`{}`", String::from_utf8_lossy(*m)))
                .collect::<Vec<_>>()
                .join(" | ")
        ),
    };
    let payload = &bytes[8..bytes.len() - 4];
    let stored = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
    if crc32(payload) != stored {
        bail!("{}: corrupted (crc mismatch)", path.display());
    }
    Ok((payload.to_vec(), which))
}

/// Little-endian payload encoder.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear the payload but keep the allocation — the reuse hook the
    /// remote hot paths lean on: one `ByteWriter` per connection,
    /// `reset()` per RPC, zero steady-state allocation.
    pub fn reset(&mut self) {
        self.buf.clear();
    }

    /// The encoded payload so far (borrowed; pair with [`Self::reset`]
    /// to reuse the writer instead of consuming it via `finish`).
    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string (u32 length).
    pub fn str_(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed f32 slice (u64 length).
    pub fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed raw byte slice (u64 length) — nested payloads
    /// (e.g. an encoded `ServiceState` inside an RPC frame).
    pub fn bytes(&mut self, v: &[u8]) {
        self.u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Append pre-encoded bytes verbatim (no length prefix) — replaying
    /// an already-encoded payload, e.g. a cached RPC reply.
    pub fn raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed u64 slice (u64 length).
    pub fn u64s(&mut self, v: &[u64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    /// Length-prefixed u32 slice (u64 length).
    pub fn u32s(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian payload decoder; every read is bounds-checked and
/// errors name the field being read.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "truncated payload: wanted {n} bytes for `{what}` at offset {}, only {} left",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    pub fn f32(&mut self, what: &str) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    pub fn str_(&mut self, what: &str) -> Result<String> {
        let n = self.u32(what)? as usize;
        let bytes = self.take(n, what)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| anyhow::anyhow!("`{what}` is not valid UTF-8"))
    }

    pub fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.f32s_into(what, &mut out)?;
        Ok(out)
    }

    /// As [`Self::f32s`], but into a caller-owned vector (cleared
    /// first) so steady-state decoding reuses one allocation.
    pub fn f32s_into(&mut self, what: &str, out: &mut Vec<f32>) -> Result<()> {
        let n = self.u64(what)? as usize;
        // Guard the allocation against a corrupted length before trusting it.
        let fits = match n.checked_mul(4).and_then(|b| self.pos.checked_add(b)) {
            Some(end) => end <= self.buf.len(),
            None => false,
        };
        if !fits {
            bail!(
                "truncated payload: `{what}` claims {n} f32s but only {} bytes remain",
                self.buf.len() - self.pos
            );
        }
        out.clear();
        out.reserve(n);
        for _ in 0..n {
            out.push(f32::from_le_bytes(self.take(4, what)?.try_into().unwrap()));
        }
        Ok(())
    }

    /// Length-prefixed raw byte slice written by [`ByteWriter::bytes`].
    pub fn bytes(&mut self, what: &str) -> Result<Vec<u8>> {
        let n = self.u64(what)? as usize;
        if n > self.buf.len() - self.pos {
            bail!(
                "truncated payload: `{what}` claims {n} bytes but only {} remain",
                self.buf.len() - self.pos
            );
        }
        Ok(self.take(n, what)?.to_vec())
    }

    /// Length-prefixed u64 slice written by [`ByteWriter::u64s`].
    pub fn u64s(&mut self, what: &str) -> Result<Vec<u64>> {
        let n = self.u64(what)? as usize;
        let fits = match n.checked_mul(8).and_then(|b| self.pos.checked_add(b)) {
            Some(end) => end <= self.buf.len(),
            None => false,
        };
        if !fits {
            bail!(
                "truncated payload: `{what}` claims {n} u64s but only {} bytes remain",
                self.buf.len() - self.pos
            );
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()));
        }
        Ok(out)
    }

    /// Length-prefixed u32 slice written by [`ByteWriter::u32s`].
    pub fn u32s(&mut self, what: &str) -> Result<Vec<u32>> {
        let n = self.u64(what)? as usize;
        let fits = match n.checked_mul(4).and_then(|b| self.pos.checked_add(b)) {
            Some(end) => end <= self.buf.len(),
            None => false,
        };
        if !fits {
            bail!(
                "truncated payload: `{what}` claims {n} u32s but only {} bytes remain",
                self.buf.len() - self.pos
            );
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()));
        }
        Ok(out)
    }

    /// Error if any bytes remain unread (catches layout drift).
    pub fn expect_end(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "{} trailing bytes after the last field (format drift or corruption)",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_reader_roundtrip() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.u64(1 << 40);
        w.f32(-2.5);
        w.str_("hello");
        w.f32s(&[1.0, 2.0, 3.0]);
        let bytes = w.finish();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8("a").unwrap(), 7);
        assert_eq!(r.u32("b").unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64("c").unwrap(), 1 << 40);
        assert_eq!(r.f32("d").unwrap(), -2.5);
        assert_eq!(r.str_("e").unwrap(), "hello");
        assert_eq!(r.f32s("f").unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(r.expect_end().is_ok());
    }

    #[test]
    fn bytes_and_u64s_roundtrip_and_reject_bogus_lengths() {
        let mut w = ByteWriter::new();
        w.bytes(b"nested payload");
        w.u64s(&[3, 1 << 40, 0]);
        w.u32s(&[7, 0, 42]);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.bytes("blob").unwrap(), b"nested payload");
        assert_eq!(r.u64s("indices").unwrap(), vec![3, 1 << 40, 0]);
        assert_eq!(r.u32s("counts").unwrap(), vec![7, 0, 42]);
        assert!(r.expect_end().is_ok());

        let mut w = ByteWriter::new();
        w.u64(u64::MAX); // claims 2^64 bytes / u64s
        let buf = w.finish();
        assert!(ByteReader::new(&buf).bytes("blob").is_err());
        assert!(ByteReader::new(&buf).u64s("indices").is_err());
        assert!(ByteReader::new(&buf).u32s("counts").is_err());
    }

    #[test]
    fn read_blob_any_matches_either_magic() {
        let dir = std::env::temp_dir();
        let path = dir.join("pal_blob_any_test.bin");
        write_blob(&path, b"PALTEST1", b"old payload").unwrap();
        let (payload, which) = read_blob_any(&path, &[b"PALTEST2", b"PALTEST1"]).unwrap();
        assert_eq!(payload, b"old payload");
        assert_eq!(which, 1);
        write_blob(&path, b"PALTEST2", b"new payload").unwrap();
        let (payload, which) = read_blob_any(&path, &[b"PALTEST2", b"PALTEST1"]).unwrap();
        assert_eq!(payload, b"new payload");
        assert_eq!(which, 0);
        assert!(read_blob_any(&path, &[b"PALOTHER"]).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reader_rejects_truncation_with_field_name() {
        let mut w = ByteWriter::new();
        w.u32(5);
        let bytes = w.finish();
        let mut r = ByteReader::new(&bytes);
        let err = r.u64("cursor").unwrap_err().to_string();
        assert!(err.contains("cursor"), "{err}");
    }

    #[test]
    fn reader_rejects_bogus_slice_length() {
        let mut w = ByteWriter::new();
        w.u64(u64::MAX); // claims 2^64 f32s
        let bytes = w.finish();
        let mut r = ByteReader::new(&bytes);
        assert!(r.f32s("priorities").is_err());
    }

    #[test]
    fn reader_flags_trailing_bytes() {
        let bytes = vec![0u8; 4];
        let mut r = ByteReader::new(&bytes);
        r.u8("x").unwrap();
        assert!(r.expect_end().is_err());
    }

    #[test]
    fn blob_roundtrip_and_rejections() {
        let dir = std::env::temp_dir();
        let path = dir.join("pal_blob_test.bin");
        write_blob(&path, b"PALTEST1", b"payload bytes").unwrap();
        assert_eq!(read_blob(&path, b"PALTEST1").unwrap(), b"payload bytes");
        // Wrong magic.
        assert!(read_blob(&path, b"PALOTHER").is_err());
        // Flipped payload byte -> crc mismatch.
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[10] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(read_blob(&path, b"PALTEST1").is_err());
        // Too short.
        std::fs::write(&path, b"PAL").unwrap();
        assert!(read_blob(&path, b"PALTEST1").is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_leaves_no_tmp_behind() {
        let dir = std::env::temp_dir();
        let path = dir.join("pal_blob_atomic.bin");
        write_blob(&path, b"PALTEST1", &[1, 2, 3]).unwrap();
        assert!(path.exists());
        assert!(!path.with_extension("tmp").exists());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn crc_matches_known_vector() {
        // CRC-32/IEEE of "123456789" is 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }
}
