//! Minimal property-testing harness (offline substitute for proptest).
//!
//! `check` runs a property over `cases` random inputs from a generator;
//! on failure it performs greedy shrinking via the generator's
//! `shrink` candidates and reports the minimal failing input with the
//! seed needed to replay it.

use crate::util::rng::Rng;

/// A generator of random values with optional shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;

    fn generate(&self, rng: &mut Rng) -> Self::Value;

    /// Candidate simpler values (default: no shrinking).
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Uniform usize in [lo, hi] with halving shrink toward lo.
pub struct UsizeIn {
    pub lo: usize,
    pub hi: usize,
}

impl Gen for UsizeIn {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        self.lo + rng.below_usize(self.hi - self.lo + 1)
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Vec of f32 in [lo, hi) with length in [min_len, max_len]; shrinks by
/// halving the vector and zeroing elements.
pub struct VecF32 {
    pub min_len: usize,
    pub max_len: usize,
    pub lo: f32,
    pub hi: f32,
}

impl Gen for VecF32 {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Rng) -> Vec<f32> {
        let len = self.min_len + rng.below_usize(self.max_len - self.min_len + 1);
        (0..len).map(|_| rng.range_f32(self.lo, self.hi)).collect()
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..self.min_len.max(v.len() / 2)].to_vec());
        }
        if v.iter().any(|&x| x != self.lo) {
            out.push(vec![self.lo; v.len()]);
        }
        out
    }
}

/// Pair of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Result of a property check.
#[derive(Debug)]
pub enum PropResult<V> {
    Ok { cases: usize },
    Failed { seed: u64, original: V, minimal: V, shrinks: usize, message: String },
}

/// Run `prop` over `cases` generated inputs. Panics with a replayable
/// report on failure (standard test integration).
pub fn check<G: Gen>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    match check_quiet(seed, cases, gen, &prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { seed, original, minimal, shrinks, message } => {
            panic!(
                "property `{name}` failed (seed {seed}):\n  original: {original:?}\n  \
                 minimal ({shrinks} shrinks): {minimal:?}\n  error: {message}"
            );
        }
    }
}

/// Non-panicking variant (used to test the harness itself).
pub fn check_quiet<G: Gen>(
    seed: u64,
    cases: usize,
    gen: &G,
    prop: &impl Fn(&G::Value) -> Result<(), String>,
) -> PropResult<G::Value> {
    let mut rng = Rng::new(seed);
    for _ in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // Greedy shrink.
            let original = v.clone();
            let mut cur = v;
            let mut cur_msg = msg;
            let mut shrinks = 0usize;
            'outer: loop {
                for cand in gen.shrink(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        shrinks += 1;
                        if shrinks < 200 {
                            continue 'outer;
                        }
                    }
                }
                break;
            }
            return PropResult::Failed {
                seed,
                original,
                minimal: cur,
                shrinks,
                message: cur_msg,
            };
        }
    }
    PropResult::Ok { cases }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-nonneg", 1, 200, &VecF32 { min_len: 0, max_len: 20, lo: 0.0, hi: 5.0 },
              |v| {
                  if v.iter().sum::<f32>() >= 0.0 {
                      Ok(())
                  } else {
                      Err("negative sum".into())
                  }
              });
    }

    #[test]
    fn failing_property_shrinks() {
        let gen = UsizeIn { lo: 0, hi: 1000 };
        let r = check_quiet(7, 500, &gen, &|&v| {
            if v < 100 {
                Ok(())
            } else {
                Err(format!("{v} >= 100"))
            }
        });
        match r {
            PropResult::Failed { minimal, .. } => {
                // Greedy shrink should land near the boundary.
                assert!(minimal >= 100 && minimal <= 550, "minimal {minimal}");
            }
            PropResult::Ok { .. } => panic!("property should fail"),
        }
    }

    #[test]
    fn pair_generator_shrinks_both_sides() {
        let gen = Pair(UsizeIn { lo: 1, hi: 64 }, UsizeIn { lo: 1, hi: 64 });
        let mut rng = Rng::new(3);
        let v = gen.generate(&mut rng);
        assert!((1..=64).contains(&v.0) && (1..=64).contains(&v.1));
        let shrunk = gen.shrink(&(32, 32));
        assert!(shrunk.iter().any(|&(a, _)| a < 32));
        assert!(shrunk.iter().any(|&(_, b)| b < 32));
    }
}
