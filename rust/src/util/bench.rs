//! Minimal benchmark harness (offline substitute for criterion).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`bench_fn`] / [`Table`]. Reports mean, std, p50 and p99 over timed
//! iterations after a warmup phase.

use crate::util::stats::{percentile, Running};
use std::time::Instant;

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        1e9 / self.mean_ns.max(1e-9)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12} {:>12} {:>12}  ({} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
            self.iters
        )
    }
}

/// Human duration formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Time `f` (one logical operation per call). Auto-chooses iteration
/// count so total measured time ≈ `budget_ms`.
pub fn bench_fn<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    let mut calib_iters = 0usize;
    while t0.elapsed().as_millis() < (budget_ms / 4).max(5) as u128 {
        f();
        calib_iters += 1;
    }
    let per_iter = t0.elapsed().as_nanos() as f64 / calib_iters.max(1) as f64;
    let target = ((budget_ms as f64 * 1e6) / per_iter.max(1.0)).ceil() as usize;
    let iters = target.clamp(10, 1_000_000);

    // Measured phase: sample in chunks to keep timer overhead low.
    let chunk = (iters / 50).max(1);
    let mut samples = Vec::with_capacity(iters / chunk + 1);
    let mut stats = Running::new();
    let mut done = 0usize;
    while done < iters {
        let n = chunk.min(iters - done);
        let t = Instant::now();
        for _ in 0..n {
            f();
        }
        let per = t.elapsed().as_nanos() as f64 / n as f64;
        samples.push(per);
        stats.push(per);
        done += n;
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: stats.mean(),
        std_ns: stats.std(),
        p50_ns: percentile(&samples, 50.0),
        p99_ns: percentile(&samples, 99.0),
    }
}

/// Header for bench output blocks.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!("{:<44} {:>12} {:>12} {:>12}", "benchmark", "mean", "p50", "p99");
}

/// Simple aligned table printer for figure-regeneration benches.
pub struct Table {
    cols: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(cols: &[&str]) -> Self {
        Self { cols: cols.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.cols.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut w: Vec<usize> = self.cols.iter().map(|c| c.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.cols));
        println!("{}", w.iter().map(|n| "-".repeat(*n)).collect::<Vec<_>>().join("  "));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleepless_work() {
        let mut x = 0u64;
        let r = bench_fn("spin", 20, || {
            for i in 0..100 {
                x = x.wrapping_add(i);
            }
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
        assert!(r.iters >= 10);
        std::hint::black_box(x);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }
}
