//! Tiny CLI argument parser (offline substitute for clap).
//!
//! Supports `--key value`, `--key=value`, boolean flags (`--flag`),
//! repeated keys, and positional arguments, with typed accessors and an
//! unknown-flag check against a declared option list.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse a raw argument list (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates flag parsing.
                    out.positional.extend(it);
                    break;
                }
                let (key, val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => {
                        // Value is the next token unless it looks like a flag.
                        let take = it
                            .peek()
                            .map(|n| !n.starts_with("--"))
                            .unwrap_or(false);
                        let v = if take { it.next() } else { None };
                        (rest.to_string(), v)
                    }
                };
                out.flags
                    .entry(key)
                    .or_default()
                    .push(val.unwrap_or_else(|| "true".to_string()));
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(String::as_str)
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .get(key)
            .map(|v| v.iter().map(String::as_str).collect())
            .unwrap_or_default()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|_| anyhow!("--{key}: cannot parse `{s}`")),
        }
    }

    /// Parse a comma-separated list of usizes (sweep flags such as
    /// `--shards 1,2,4,8,16` or `--threads 1,2,4,8`); `default` when the
    /// flag is absent.
    pub fn usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        if !self.has(key) {
            return Ok(default.to_vec());
        }
        self.str_list(key)
            .iter()
            .map(|p| {
                p.parse::<usize>()
                    .map_err(|_| anyhow!("--{key}: cannot parse `{p}` in list"))
            })
            .collect()
    }

    /// Parse a comma-separated list of raw strings (composite flags
    /// such as `--tables replay=1step,multi=nstep:3`); empty when the
    /// flag is absent. Entries are trimmed; empty entries dropped.
    pub fn str_list(&self, key: &str) -> Vec<String> {
        match self.get(key) {
            None => Vec::new(),
            Some(s) => s
                .split(',')
                .map(str::trim)
                .filter(|p| !p.is_empty())
                .map(str::to_string)
                .collect(),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
            || (self.has(key) && self.get(key).is_none())
    }

    /// Parse a duration flag given in (possibly fractional) seconds,
    /// validated positive and finite — timeout/deadline flags such as
    /// `--rpc-timeout 2.5` or `--reconnect-deadline 30`; `default` when
    /// absent.
    pub fn seconds_or(&self, key: &str, default: f64) -> Result<std::time::Duration> {
        let secs = self.parse_or(key, default)?;
        if !secs.is_finite() || secs <= 0.0 {
            bail!("--{key}: expected a positive number of seconds, got `{secs}`");
        }
        Ok(std::time::Duration::from_secs_f64(secs))
    }

    /// Error on flags not in `allowed` (catches typos).
    pub fn check_known(&self, allowed: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!("unknown flag --{k} (allowed: {allowed:?})");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn mixed_styles() {
        let a = args("train --algo dqn --steps=5000 --verbose --seed 7 extra");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("algo"), Some("dqn"));
        assert_eq!(a.parse_or("steps", 0usize).unwrap(), 5000);
        assert!(a.flag("verbose"));
        assert_eq!(a.parse_or("seed", 0u64).unwrap(), 7);
        assert_eq!(a.parse_or("missing", 42i32).unwrap(), 42);
    }

    #[test]
    fn repeated_and_double_dash() {
        let a = args("--x 1 --x 2 -- --not-a-flag");
        assert_eq!(a.get_all("x"), vec!["1", "2"]);
        assert_eq!(a.get("x"), Some("2"));
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn unknown_flag_detected() {
        let a = args("--algo dqn --typo 3");
        assert!(a.check_known(&["algo"]).is_err());
        assert!(a.check_known(&["algo", "typo"]).is_ok());
    }

    #[test]
    fn parse_error_reported() {
        let a = args("--steps abc");
        assert!(a.parse_or("steps", 0usize).is_err());
    }

    #[test]
    fn str_list_splits_and_trims() {
        let a = args("--tables replay=1step,multi=nstep:3");
        assert_eq!(a.str_list("tables"), vec!["replay=1step", "multi=nstep:3"]);
        assert!(a.str_list("missing").is_empty());
        let b = Args::parse(vec!["--tables".to_string(), " a , ,b ".to_string()]).unwrap();
        assert_eq!(b.str_list("tables"), vec!["a", "b"]);
    }

    #[test]
    fn seconds_accept_fractions_and_reject_nonpositive() {
        let a = args("--rpc-timeout 2.5");
        assert_eq!(
            a.seconds_or("rpc-timeout", 120.0).unwrap(),
            std::time::Duration::from_millis(2_500)
        );
        // Absent flag → default.
        assert_eq!(
            a.seconds_or("reconnect-deadline", 30.0).unwrap(),
            std::time::Duration::from_secs(30)
        );
        for bad in ["0", "-1", "nan", "inf", "soon"] {
            let b = Args::parse(vec!["--t".to_string(), bad.to_string()]).unwrap();
            assert!(
                b.seconds_or("t", 1.0).is_err(),
                "`--t {bad}` must be rejected"
            );
        }
    }

    #[test]
    fn usize_list_parses_and_defaults() {
        let a = args("--shards 1,2,4,8");
        assert_eq!(a.usize_list("shards", &[1]).unwrap(), vec![1, 2, 4, 8]);
        assert_eq!(a.usize_list("threads", &[1, 2]).unwrap(), vec![1, 2]);
        let bad = args("--shards 1,x");
        assert!(bad.usize_list("shards", &[1]).is_err());
    }
}
