//! Deterministic PRNGs for the framework (no external `rand` crate offline).
//!
//! `SplitMix64` seeds `Xoshiro256pp`, the workhorse generator used by
//! actors, samplers and tests. Distribution helpers cover everything the
//! framework needs: uniform ints/floats, Gaussians (Box–Muller) and
//! Bernoulli draws.

/// SplitMix64: tiny, solid seeder (Steele et al.).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality 64-bit generator (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Derive an independent stream (e.g. per worker thread).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) using Lemire's method (no modulo bias).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform in [lo, hi) as f32.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Bernoulli draw with probability p.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard Gaussian via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = std::f64::consts::TAU * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Gaussian with the given mean and std-dev, as f32.
    #[inline]
    pub fn gaussian_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian() as f32
    }

    /// Fill a slice with standard Gaussians.
    pub fn fill_gaussian(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gaussian() as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "counts {counts:?}"
            );
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
