//! Substrate utilities (offline-friendly stand-ins for common crates).
pub mod aligned;
pub mod bench;
pub mod blob;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
