//! # Parallel Actors and Learners (PAL)
//!
//! Reproduction of "Parallel Actors and Learners: A Framework for
//! Generating Scalable RL Implementations" (Zhang, Kuppannagari &
//! Prasanna, 2021) as a three-layer rust + JAX/Pallas system:
//!
//! * [`replay`] — the paper's core contribution: a K-ary sum-tree
//!   prioritized replay buffer with cache-aligned layout, lazy writing
//!   and two-lock synchronization, plus every baseline it is compared
//!   against.
//! * [`service`] — the replay service in front of those buffers:
//!   named tables, rate limiters owning the sample-to-insert ratio,
//!   and actor-side N-step / sequence trajectory writers (Reverb's
//!   server shape, in-process).
//! * [`remote`] — the socket front-end over that service: a
//!   Unix-domain-socket `ReplayServer` plus `RemoteWriter` /
//!   `RemoteSampler` client handles, so actors and learners can run in
//!   separate processes from the experience server.
//! * [`coordinator`] — parallel actors + parallel learners + parameter
//!   server training loop (Fig 7).
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   graphs (`python/compile/`, built once by `make artifacts`).
//! * [`env`] — pure-Rust OpenAI-gym-semantics environments.
//! * [`dse`] — design-space exploration (Eq. 5): choose actor/learner
//!   core counts from profiled throughput curves.
//! * [`sim`] — discrete-event multicore simulator used to project
//!   scalability beyond this machine's core count.
pub mod actor;
pub mod agent;
pub mod coordinator;
pub mod dse;
pub mod env;
pub mod learner;
pub mod metrics;
pub mod params;
pub mod remote;
pub mod replay;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod util;
